//! Offline stand-in for the `anyhow` crate: the build environment has no
//! crates.io access, so this shim provides exactly the surface the main
//! crate uses — a string-backed [`Error`], the [`Result`] alias, the
//! [`anyhow!`] macro, and the [`Context`] extension trait.

use std::fmt;

/// A boxed, message-carrying error (no backtrace, no downcasting).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow::Error, this type deliberately does NOT implement
// std::error::Error, which keeps the blanket conversion below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, ()> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        Err(anyhow!("boom {}", 7))
    }

    #[test]
    fn macro_and_display() {
        let e = fail().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
        assert_eq!(format!("{e:?}"), "boom 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("denied {}", 7);
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(f(true).unwrap_err().to_string(), "denied 7");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting"));
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }
}
