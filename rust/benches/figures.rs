//! `cargo bench` target: regenerates every paper table/figure series in
//! quick mode (criterion is not in the offline vendor set; this is a plain
//! `harness = false` benchmark binary that prints the TSV series plus
//! microbenchmark timings for the L3 hot paths).

use singa::utils::timer::time_iters;

/// Run the steady-state allocation/throughput probes — the single-process
/// model loops AND the distributed `run_job` loop across sandblaster/
/// downpour/hogwild topologies — and write the `BENCH_alloc.json` artifact
/// at the repo root. With `check`, assert the acceptance bar: zero blob /
/// pack / executor-scratch allocations per model step and zero blob
/// allocations per worker group per distributed step after warm-up (the CI
/// alloc-regression job runs this under `PALLAS_NUM_THREADS=1` and `=4`).
fn emit_alloc_probe(check: bool) {
    let models = singa::bench::alloc_probe(20);
    let dist = singa::bench::distributed_alloc_probe(3, 12);
    let json = singa::bench::alloc_probe_json_from(&models, &dist);
    println!("==== steady-state allocation probe ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_alloc.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if check {
        for p in &models {
            assert_eq!(
                p.steady_allocs_per_step, 0.0,
                "{}: steady-state blob allocations must be zero",
                p.model
            );
            assert_eq!(
                p.steady_pack_allocs_per_step, 0.0,
                "{}: steady-state pack allocations must be zero",
                p.model
            );
            assert_eq!(
                p.steady_exec_allocs_per_step, 0.0,
                "{}: steady-state executor-scratch allocations must be zero",
                p.model
            );
        }
        for d in &dist {
            for (g, &a) in d.steady_allocs.iter().enumerate() {
                assert_eq!(
                    a, 0,
                    "{}: worker group {g} allocated {a} blobs after warm-up",
                    d.topology
                );
            }
        }
        println!(
            "alloc check passed: {} models and {} run_job topologies allocation-free",
            models.len(),
            dist.len()
        );
    }
}

/// Run the serial-vs-parallel GEMM scaling probe and write the
/// `BENCH_gemm.json` artifact at the repo root. With `check`, assert the
/// acceptance bars: bit-identical output and ≥1.5x thread speedup on 256^3
/// (the CI smoke step runs this under `PALLAS_NUM_THREADS=4`), plus — on
/// AVX2+FMA hosts — ≥1.5x single-threaded simd-over-scalar GFLOP/s on
/// 256^3 (the kernel-dispatch gate; skipped with a notice elsewhere).
fn emit_gemm_probe(check: bool) {
    let threads = singa::runtime::threads();
    println!("[bench] {}", singa::runtime::manifest::kernel_line(singa::runtime::kernel_choice()));
    let probes = singa::bench::gemm_scaling_probe(&[64, 128, 256], threads, 1, 5);
    let json = singa::bench::gemm_probes_json(threads, &probes);
    println!("==== gemm scaling probe ({threads} threads) ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_gemm.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if check {
        let p = probes.iter().find(|p| p.n == 256).expect("256^3 probe present");
        assert!(p.bit_identical, "parallel gemm output must be bit-identical to serial");
        assert!(
            p.speedup >= 1.5,
            "expected >=1.5x speedup at {threads} threads on 256^3, got {:.2}x \
             (serial {:.3} ms vs parallel {:.3} ms)",
            p.speedup,
            p.serial_ms,
            p.parallel_ms
        );
        for p in &probes {
            assert!(p.simd_close, "n={}: simd gemm must approximate the scalar oracle", p.n);
        }
        if singa::tensor::kernel::simd_supported() {
            assert!(
                p.simd_speedup >= 1.5,
                "expected >=1.5x simd-over-scalar on 256^3 single-threaded, got {:.2}x \
                 (scalar {:.3} ms / {:.2} GFLOP/s vs simd {:.3} ms / {:.2} GFLOP/s)",
                p.simd_speedup,
                p.scalar_ms,
                p.scalar_gflops,
                p.simd_ms,
                p.simd_gflops
            );
            println!(
                "gemm smoke check passed: {:.2}x at {threads} threads, \
                 simd {:.2}x over scalar on 256^3",
                p.speedup, p.simd_speedup
            );
        } else {
            println!(
                "NOTICE: AVX2+FMA not detected on this runner; simd >=1.5x gate skipped \
                 (scalar fallback in effect, simd_speedup recorded as {:.2}x)",
                p.simd_speedup
            );
            println!(
                "gemm smoke check passed: {:.2}x at {threads} threads on 256^3",
                p.speedup
            );
        }
    }
}

/// Run the serial-vs-parallel conv/im2col scaling probe and write the
/// `BENCH_conv.json` artifact at the repo root. Always asserts the
/// correctness half of the contract — bit-identical parallel outputs,
/// bit-identical simd transforms, simd conv within FMA tolerance;
/// throughput is recorded, not gated.
fn emit_conv_probe() {
    let threads = singa::runtime::threads();
    println!("[bench] {}", singa::runtime::manifest::kernel_line(singa::runtime::kernel_choice()));
    let probes = singa::bench::conv_scaling_probe(threads, 1, 3);
    let json = singa::bench::conv_probes_json(threads, &probes);
    println!("==== conv/im2col scaling probe ({threads} threads) ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_conv.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    for p in &probes {
        assert!(p.bit_identical, "{}: parallel conv output must equal serial", p.name);
        assert!(
            p.transforms_simd_exact,
            "{}: simd im2col/col2im must be bit-identical to scalar",
            p.name
        );
        assert!(p.conv_simd_close, "{}: simd conv must approximate scalar", p.name);
    }
}

/// Run the sequential-vs-overlapped exchange probe (MLP + convnet jobs ×
/// cluster/lan/local cost models for `raw`; the f16/int8 wire codecs on
/// the comm-bound cluster model) and write the `BENCH_overlap.json`
/// artifact at the repo root. With `check`, assert the acceptance bars:
/// the convnet job's overlapped virtual step time beats sequential on the
/// cluster link under `raw` (ratio < 1.0); each compressed entry's
/// wire-byte ratio lands in its codec's band (f16 ≈ ½, int8 ≈ ¼ of raw);
/// and the comm-bound MLP job's *sequential* virtual step gets faster
/// under both codecs — the CI codec job runs this.
fn emit_overlap_probe(check: bool) {
    let probes = singa::bench::overlap_probe(6);
    let json = singa::bench::overlap_probes_json(&probes);
    println!("==== overlapped-exchange probe ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_overlap.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if check {
        let entry = |job: &str, cost: &str, codec: &str| {
            probes
                .iter()
                .find(|p| p.job == job && p.cost == cost && p.codec == codec)
                .unwrap_or_else(|| panic!("{job}/{cost}/{codec} probe present"))
        };
        let conv = entry("convnet", "cluster", "raw");
        assert!(
            conv.virt_ratio < 1.0,
            "overlap must beat sequential for convnet on cluster: ratio {:.4} \
             (seq {:.4} ms vs overlap {:.4} ms per step)",
            conv.virt_ratio,
            conv.seq_virt_step_ms,
            conv.overlap_virt_step_ms
        );
        // Wire-byte shrink per codec, on both jobs' cluster entries: the
        // encoded flush must land near the codec's element shrink (chunk
        // headers + the uncompressed Msg headers keep it off the ideal
        // 0.5 / 0.25).
        for job in ["mlp", "convnet"] {
            let f16 = entry(job, "cluster", "f16");
            assert!(
                f16.wire_ratio_vs_raw > 0.4 && f16.wire_ratio_vs_raw < 0.60,
                "{job}: f16 wire ratio {:.4} outside (0.4, 0.60)",
                f16.wire_ratio_vs_raw
            );
            let int8 = entry(job, "cluster", "int8");
            assert!(
                int8.wire_ratio_vs_raw > 0.15 && int8.wire_ratio_vs_raw < 0.35,
                "{job}: int8 wire ratio {:.4} outside (0.15, 0.35)",
                int8.wire_ratio_vs_raw
            );
        }
        // Comm-bound gain: the MLP on the 1 Gbps cluster link is dominated
        // by transfer time, so its sequential virtual step (compute + comm
        // sum — the deterministic accounting) must improve under both
        // codecs.
        let raw = entry("mlp", "cluster", "raw");
        for codec in ["f16", "int8"] {
            let c = entry("mlp", "cluster", codec);
            assert!(
                c.seq_virt_step_ms < raw.seq_virt_step_ms,
                "mlp/cluster: {codec} sequential virtual step {:.4} ms must beat \
                 raw {:.4} ms",
                c.seq_virt_step_ms,
                raw.seq_virt_step_ms
            );
        }
        println!(
            "overlap smoke check passed: convnet/cluster ratio {:.4} ({} buckets); \
             codec wire ratios within bands and mlp/cluster seq step faster compressed",
            conv.virt_ratio, conv.buckets
        );
    }
}

/// Run the fault-recovery probe (MLP + convnet jobs × cluster/lan cost
/// models × {baseline, checkpoint cadence, checkpoint + mid-run kill,
/// straggler, straggler + backup}) and write the `BENCH_faults.json`
/// artifact at the repo root. With `check`, assert the acceptance bar: no
/// scenario perturbs training values (bitwise), the kill scenario recovers
/// through the checkpoint with a strictly positive virtual recovery
/// charge, and backups rescue every delayed step — the CI faults job runs
/// this under `PALLAS_NUM_THREADS=1` and `=4`.
fn emit_faults_probe(check: bool) {
    let probes = singa::bench::faults_probe(24);
    let json = singa::bench::faults_probes_json(&probes);
    println!("==== fault-recovery probe ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_faults.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if check {
        for p in &probes {
            let tag = format!("{}/{}/{}", p.job, p.cost, p.scenario);
            assert!(p.values_bitwise, "{tag}: faults must never perturb training values");
            match p.scenario {
                "ckpt+kill" => {
                    assert_eq!(p.fault_events, 1, "{tag}: the kill must be recovered");
                    assert!(p.checkpoints >= 1, "{tag}: recovery needs a checkpoint");
                    assert!(
                        p.recovery_virt_ms > 0.0 && p.overhead_ratio > 1.0,
                        "{tag}: recovery must cost virtual time \
                         (recovery {:.4} ms, ratio {:.4})",
                        p.recovery_virt_ms,
                        p.overhead_ratio
                    );
                }
                "straggler+backup" => {
                    assert!(p.backup_rescues >= 1, "{tag}: backups must rescue delayed steps");
                }
                _ => {}
            }
        }
        println!("faults check passed: {} scenarios, values bitwise-stable", probes.len());
    }
}

/// Run the wire-chaos probe (sandblaster(1,1) × raw/int8 codecs ×
/// {lossless, drop+retry, corrupt+retry, severed}) and write the
/// `BENCH_chaos.json` artifact at the repo root. With `check`, assert the
/// acceptance bars: the armed-but-lossless baseline wastes no bytes; every
/// eventually-delivered lossy scenario ends bitwise identical to the
/// lossless run while paying a strictly positive retransmit/overhead cost
/// (honest byte accounting: goodput < 1); and the severed scenario
/// completes with recorded bounded-staleness degradation instead of
/// hanging — the CI chaos job runs this under `PALLAS_NUM_THREADS=1` and
/// `=4`.
fn emit_chaos_probe(check: bool) {
    let probes = singa::bench::chaos_probe(12);
    let json = singa::bench::chaos_probes_json(&probes);
    println!("==== wire-chaos probe ====");
    print!("{json}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_chaos.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if check {
        for p in &probes {
            let tag = format!("{}/{}", p.codec, p.scenario);
            match p.scenario {
                "lossless" => {
                    assert_eq!(p.wasted_bytes, 0, "{tag}: lossless run must waste no bytes");
                    assert_eq!(p.goodput_ratio, 1.0, "{tag}: lossless goodput must be 1");
                    assert_eq!(p.degraded_steps, 0, "{tag}: lossless run must not degrade");
                }
                "drop+retry" | "corrupt+retry" => {
                    assert!(
                        p.values_bitwise,
                        "{tag}: eventual delivery must end bitwise identical to lossless"
                    );
                    assert!(p.retransmits > 0, "{tag}: the retry protocol must have fired");
                    assert_eq!(p.degraded_steps, 0, "{tag}: retries must prevent degradation");
                    assert!(
                        p.overhead_ratio > 1.0 && p.goodput_ratio < 1.0,
                        "{tag}: a lossy wire must cost virtual time and goodput \
                         (ratio {:.4}, goodput {:.4})",
                        p.overhead_ratio,
                        p.goodput_ratio
                    );
                }
                "severed" => {
                    assert!(
                        p.degraded_steps > 0 && p.staleness_adoptions > 0,
                        "{tag}: a severed link must degrade to recorded bounded staleness \
                         (degraded {} / adoptions {})",
                        p.degraded_steps,
                        p.staleness_adoptions
                    );
                }
                other => panic!("unexpected chaos scenario '{other}'"),
            }
        }
        println!(
            "chaos check passed: {} scenarios — lossy runs bitwise-stable under eventual \
             delivery, severed links degrade gracefully",
            probes.len()
        );
    }
}

fn main() {
    // `cargo bench --bench figures -- alloc [check]` runs only the
    // allocation probes (model loops + distributed run_job; the CI
    // alloc-regression job adds `check`); `-- gemm [check]` runs only the
    // gemm scaling probe (CI smoke adds `check`); `-- conv` runs only the
    // conv/im2col scaling probe; `-- overlap [check]` runs only the
    // sequential-vs-overlapped exchange probe (CI adds `check`);
    // `-- faults [check]` runs only the fault-recovery probe (CI adds
    // `check`); `-- chaos [check]` runs only the wire-chaos probe (CI adds
    // `check`); no argument runs everything.
    let args: Vec<String> = std::env::args().collect();
    let has = |s: &str| args.iter().any(|a| a == s);
    if has("gemm") {
        emit_gemm_probe(has("check"));
        return;
    }
    if has("conv") {
        emit_conv_probe();
        return;
    }
    if has("overlap") {
        emit_overlap_probe(has("check"));
        return;
    }
    if has("faults") {
        emit_faults_probe(has("check"));
        return;
    }
    if has("chaos") {
        emit_chaos_probe(has("check"));
        return;
    }
    emit_alloc_probe(has("check"));
    if has("alloc") {
        return;
    }
    emit_gemm_probe(false);
    emit_conv_probe();
    emit_overlap_probe(false);
    emit_faults_probe(false);
    emit_chaos_probe(false);

    println!("==== paper figures (quick mode) ====");
    let out = singa::bench::run_all(true);
    println!("{out}");

    println!("==== L3 microbenchmarks ====");
    // GEMM throughput (native backend hot path)
    for &n in &[64usize, 128, 256] {
        let mut rng = singa::utils::rng::Rng::new(1);
        let a = rng.uniform_vec(n * n, -1.0, 1.0);
        let b = rng.uniform_vec(n * n, -1.0, 1.0);
        let mut c = vec![0.0f32; n * n];
        let st = time_iters(2, 5, || {
            singa::tensor::gemm(
                singa::tensor::Transpose::No,
                singa::tensor::Transpose::No,
                n,
                n,
                n,
                1.0,
                &a,
                &b,
                0.0,
                &mut c,
            );
        });
        let gflops = 2.0 * (n as f64).powi(3) / (st.mean() / 1e3) / 1e9;
        println!("gemm {n}x{n}x{n}: {:.3} ms  ({gflops:.2} GFLOP/s)", st.mean());
    }
    // convnet iteration (the fig18 workload)
    let ms = singa::bench::measure_convnet_iter_ms(32, 1, 3);
    println!("cifar convnet batch=32 iteration: {ms:.1} ms");

    // XLA step execution if artifacts are present
    let dir = singa::runtime::XlaRuntime::default_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = singa::runtime::XlaRuntime::open(&dir).unwrap();
        let spec = rt.manifest.artifacts.get("mlp_step").unwrap().clone();
        let inputs: Vec<singa::tensor::Blob> = spec
            .inputs
            .iter()
            .map(|io| singa::tensor::Blob::full(&io.shape, 0.01))
            .collect();
        let refs: Vec<&singa::tensor::Blob> = inputs.iter().collect();
        rt.execute("mlp_step", &refs).unwrap(); // compile + warm
        let st = time_iters(1, 5, || {
            rt.execute("mlp_step", &refs).unwrap();
        });
        println!("xla mlp_step (batch 32, PJRT CPU): {:.2} ms", st.mean());
    } else {
        println!("(artifacts missing; run `make artifacts` for the XLA microbench)");
    }
}
