//! Integration tests across modules: partitioned distributed training end
//! to end, framework equivalences, native-vs-XLA numeric cross-checks, and
//! config-driven job runs.

use singa::cluster::ClusterTopology;
use singa::coordinator::{run_job, JobConf};
use singa::data::{DataSource, SyntheticDigits, SyntheticImages};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::partition::partition_net;
use singa::model::{NetBuilder, Phase};
use singa::tensor::{gemm_with_threads, ops, Blob, Transpose};
use singa::updater::UpdaterConf;
use singa::utils::rng::Rng;
use std::sync::Arc;

fn mlp(batch: usize, dim: usize, hidden: usize, classes: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: hidden, act: Activation::Relu, init_std: 0.08 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.08 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
}

/// Synchronous frameworks (Sandblaster vs AllReduce topologies) produce
/// identical training trajectories — both are a single worker group.
#[test]
fn sandblaster_and_allreduce_trajectories_match() {
    let run = |topo: ClusterTopology| {
        let mut conf = JobConf::new("t", mlp(16, 64, 32, 5));
        conf.iters = 25;
        conf.updater = UpdaterConf::sgd(0.2);
        conf.topology = topo;
        let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 3));
        run_job(&conf, data).log.snapshot()
    };
    let a = run(ClusterTopology::sandblaster(1, 1));
    let b = run(ClusterTopology::allreduce(4, 1)); // 1 group, 4 shards
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x.loss - y.loss).abs() < 1e-5, "step {}: {} vs {}", x.step, x.loss, y.loss);
    }
}

/// Full hybrid parallelism through the coordinator: conv layers dim-0, fc
/// dim-1, loss unsplit — the paper's recommended AlexNet scheme (§5.4.1).
#[test]
fn hybrid_partitioned_convnet_trains() {
    let batch = 8;
    let mut b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 3, 8, 8] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "conv1",
            LayerKind::Convolution { out_channels: 6, kernel: 3, stride: 1, pad: 1, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new("pool1", LayerKind::MaxPool { kernel: 2, stride: 2 }, &["conv1"]))
        .add(LayerConf::new("relu1", LayerKind::Activation { act: Activation::Relu }, &["pool1"]))
        .add(LayerConf::new(
            "fc",
            LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.1 },
            &["relu1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["fc", "label"]));
    for c in b.confs_mut().iter_mut() {
        match c.name.as_str() {
            "conv1" | "pool1" | "relu1" => c.partition_dim = Some(0),
            "fc" => c.partition_dim = Some(1),
            _ => {}
        }
    }
    let mut conf = JobConf::new("hybrid", b);
    conf.batch_size = batch;
    conf.iters = 60;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.topology = ClusterTopology::sandblaster(2, 1);
    conf.partition_within_group = true;
    let data: Arc<dyn DataSource> = Arc::new(SyntheticImages::new(4, 3, 8, 8, 0.25, 5));
    let report = run_job(&conf, data);
    let recs = report.log.snapshot();
    let first = recs.first().unwrap().loss;
    let last = recs.last().unwrap().loss;
    assert!(last < 0.7 * first, "hybrid training should learn: {first} -> {last}");
    // feature traffic (bridges) was accounted
    assert!(report.ledger.feature_bytes() > 0, "bridge traffic must be recorded");
}

/// Async Downpour with many groups reaches accuracy comparable to sync on
/// this separable task (the convergence property behind Fig 19).
#[test]
fn downpour_matches_sync_final_accuracy() {
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 9));
    let run = |topo: ClusterTopology, iters: u64| {
        let mut conf = JobConf::new("c", mlp(16, 64, 32, 5));
        conf.iters = iters;
        conf.updater = UpdaterConf::sgd(0.15);
        conf.topology = topo;
        let report = run_job(&conf, data.clone());
        let recs = report.log.snapshot();
        recs.iter().filter(|r| r.group == 0).last().unwrap().metric
    };
    let sync_acc = run(ClusterTopology::sandblaster(1, 1), 80);
    let async_acc = run(ClusterTopology::downpour(4, 1, 1), 80);
    assert!(sync_acc > 0.9, "sync {sync_acc}");
    assert!(async_acc > 0.8, "async {async_acc}");
}

/// A full MLP training step (the satellite acceptance probe for the pack
/// scratch): after two warm-up steps, further steps — including every gemm
/// the forward/backward passes issue at whatever `PALLAS_NUM_THREADS` the
/// process was launched with (CI runs this suite under both `=1` and `=4`)
/// — perform zero blob allocations and zero gemm pack allocations.
#[test]
fn mlp_train_step_allocates_nothing_after_warmup() {
    use singa::train::{bp::Bp, TrainOneBatch};
    let mut net = mlp(32, 256, 128, 10).build(&mut Rng::new(7));
    let data = SyntheticDigits::new(256, 10, 3);
    let inputs = data.batch(1, 32);
    let mut alg = Bp::new();
    let mut step = |net: &mut singa::model::NeuralNet, alg: &mut Bp| {
        net.zero_grads();
        alg.train_one_batch(net, &inputs);
        for p in net.params_mut() {
            p.sgd_step(0.01);
        }
    };
    for _ in 0..2 {
        step(&mut net, &mut alg);
    }
    let blobs = Blob::alloc_count();
    let packs = singa::tensor::gemm::pack_alloc_count();
    for _ in 0..5 {
        step(&mut net, &mut alg);
    }
    assert_eq!(Blob::alloc_count(), blobs, "train step must not allocate blobs");
    assert_eq!(
        singa::tensor::gemm::pack_alloc_count(),
        packs,
        "train step must not allocate gemm pack scratch"
    );
}

/// Training-shaped GEMM sequences (fc forward, weight-grad, input-grad at
/// batch 64, 512 features) are bit-identical between serial and 4-thread
/// execution — the determinism contract at the shapes the executor emits.
#[test]
fn training_shaped_gemms_are_thread_count_invariant() {
    let (batch, din, dout) = (64usize, 512usize, 512usize);
    let mut rng = Rng::new(99);
    let x = rng.uniform_vec(batch * din, -1.0, 1.0);
    let w = rng.uniform_vec(din * dout, -0.1, 0.1);
    let dy = rng.uniform_vec(batch * dout, -1.0, 1.0);
    // forward: y = x @ w
    let mut y1 = vec![0.0f32; batch * dout];
    let mut y4 = y1.clone();
    gemm_with_threads(Transpose::No, Transpose::No, batch, dout, din, 1.0, &x, &w, 0.0, &mut y1, 1);
    gemm_with_threads(Transpose::No, Transpose::No, batch, dout, din, 1.0, &x, &w, 0.0, &mut y4, 4);
    assert!(y1 == y4, "forward gemm differs across thread counts");
    // weight grad (accumulating): dw += x^T @ dy
    let mut dw1 = vec![0.01f32; din * dout];
    let mut dw4 = dw1.clone();
    gemm_with_threads(Transpose::Yes, Transpose::No, din, dout, batch, 1.0, &x, &dy, 1.0, &mut dw1, 1);
    gemm_with_threads(Transpose::Yes, Transpose::No, din, dout, batch, 1.0, &x, &dy, 1.0, &mut dw4, 4);
    assert!(dw1 == dw4, "weight-grad gemm differs across thread counts");
    // input grad: dx = dy @ w^T
    let mut dx1 = vec![0.0f32; batch * din];
    let mut dx4 = dx1.clone();
    gemm_with_threads(Transpose::No, Transpose::Yes, batch, din, dout, 1.0, &dy, &w, 0.0, &mut dx1, 1);
    gemm_with_threads(Transpose::No, Transpose::Yes, batch, din, dout, 1.0, &dy, &w, 0.0, &mut dx4, 4);
    assert!(dx1 == dx4, "input-grad gemm differs across thread counts");
}

/// Native backend vs XLA artifact: the same logical MLP forward/backward
/// cross-checked numerically (weights copied across, same batch).
#[test]
fn native_and_xla_mlp_agree() {
    let dir = singa::runtime::XlaRuntime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = singa::runtime::XlaRuntime::open(&dir).unwrap();
    let spec = rt.manifest.artifacts.get("mlp_step").unwrap().clone();
    // Artifact: 784 -> 256 (relu) -> 10, batch 32.
    let batch = 32;
    let mut rng = Rng::new(77);
    let w0 = Blob::gaussian(&[784, 256], 0.05, &mut rng);
    let b0 = Blob::zeros(&[256]);
    let w1 = Blob::gaussian(&[256, 10], 0.05, &mut rng);
    let b1 = Blob::zeros(&[10]);
    let x = Blob::from_vec(&[batch, 784], rng.uniform_vec(batch * 784, 0.0, 1.0));
    let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let mut y1h = Blob::zeros(&[batch, 10]);
    for (r, &l) in labels.iter().enumerate() {
        y1h.data_mut()[r * 10 + l] = 1.0;
    }

    // XLA side.
    let inputs = [&w0, &b0, &w1, &b1, &x, &y1h];
    assert_eq!(spec.inputs.len(), inputs.len());
    let outs = rt.execute("mlp_step", &inputs).unwrap();
    let xla_loss = outs[0].data()[0];

    // Native side: h = relu(x w0 + b0); logits = h w1 + b1.
    let mut h = ops::matmul(&x, &w0);
    ops::add_row_vec(&mut h, &b0);
    let h = ops::relu(&h);
    let mut logits = ops::matmul(&h, &w1);
    ops::add_row_vec(&mut logits, &b1);
    let (native_loss, native_grad) = ops::softmax_xent(&logits, &labels);

    assert!(
        (xla_loss - native_loss).abs() < 1e-3,
        "loss mismatch: xla {xla_loss} vs native {native_loss}"
    );
    // grad of w1 = h^T @ dlogits
    let gw1 = ops::matmul_tn(&h, &native_grad);
    let xla_gw1_idx = spec.output_index("grad:mlp/w1").unwrap();
    let xla_gw1 = &outs[xla_gw1_idx];
    for (a, b) in xla_gw1.data().iter().zip(gw1.data()).take(500) {
        assert!((a - b).abs() < 1e-3, "grad w1 mismatch: {a} vs {b}");
    }
}

/// Config-file driven job (the paper's "submit a job configuration" flow).
#[test]
fn config_file_job_runs() {
    let conf = singa::config::parse_job(
        r#"{
          "name": "cfg", "model": "mlp", "batch": 8, "iters": 10,
          "updater": {"algo": "adagrad", "lr": 0.1},
          "cluster": {"worker_groups": 2, "workers_per_group": 1,
                       "server_groups": 2, "servers_per_group": 1,
                       "sync_interval": 5}
        }"#,
    )
    .unwrap();
    assert_eq!(
        conf.topology.framework(),
        Some(singa::cluster::Framework::DistributedHogwild)
    );
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::mnist_like(1));
    let report = run_job(&conf, data);
    assert_eq!(report.log.snapshot().len(), 20); // 2 groups x 10 iters
}

/// Partitioned nets preserve forward semantics at larger scale (convnet,
/// dim-0 across 3 workers, real data).
#[test]
fn dim0_convnet_partition_preserves_loss() {
    let batch = 12;
    let data = SyntheticImages::new(4, 3, 8, 8, 0.2, 2);
    let b0 = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 3, 8, 8] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "conv",
            LayerKind::Convolution { out_channels: 4, kernel: 3, stride: 1, pad: 1, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "fc",
            LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.1 },
            &["conv"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["fc", "label"]));
    let mut b1 = b0.clone();
    for c in b1.confs_mut().iter_mut() {
        if ["conv", "fc", "loss"].contains(&c.name.as_str()) {
            c.partition_dim = Some(0);
        }
    }
    let (bp, _) = partition_net(&b1, 3);
    let mut net0 = b0.build(&mut Rng::new(42));
    let mut net1 = bp.build(&mut Rng::new(42));
    // sync replica params to reference values by logical name
    let reference: std::collections::HashMap<String, Blob> =
        net0.params().iter().map(|p| (p.name.clone(), p.data.clone())).collect();
    for p in net1.params_mut() {
        let logical = singa::model::partition::logical_param_name(&p.name);
        if let Some(v) = reference.get(&logical) {
            p.data = v.clone();
        }
    }
    let inputs = data.batch(0, batch);
    net0.set_input("data", inputs["data"].clone());
    net0.set_input("label", inputs["label"].clone());
    net0.forward(Phase::Train);
    net1.set_input("data", inputs["data"].clone());
    net1.set_input("label", inputs["label"].clone());
    net1.forward(Phase::Train);
    let full = net0.total_loss();
    let shards = net1.losses();
    let mean: f32 = shards.iter().map(|(_, l, _)| l).sum::<f32>() / shards.len() as f32;
    assert!((full - mean).abs() < 1e-4, "full {full} vs sharded mean {mean}");
}

/// Warm-up stage (paper §6.2.3): with `warmup_iters` set, group 0 runs
/// alone first; other groups' first records appear only after group 0 has
/// progressed through the warm-up.
#[test]
fn warmup_stage_delays_other_groups() {
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 13));
    let mut conf = JobConf::new("warmup", mlp(8, 64, 16, 5));
    conf.iters = 30;
    conf.warmup_iters = 15;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.topology = ClusterTopology::downpour(2, 1, 1);
    let report = run_job(&conf, data);
    let recs = report.log.snapshot();
    let g0_warm_done = recs
        .iter()
        .filter(|r| r.group == 0 && r.step == 14)
        .map(|r| r.wall_ms)
        .next()
        .expect("group 0 step 14 logged");
    let g1_first = recs
        .iter()
        .filter(|r| r.group == 1)
        .map(|r| r.wall_ms)
        .fold(f64::INFINITY, f64::min);
    assert!(
        g1_first >= g0_warm_done,
        "group 1 started at {g1_first} before warm-up finished at {g0_warm_done}"
    );
}

/// Checkpoint round-trips through the coordinator: train, save, restore
/// into a fresh net, and verify the restored net evaluates identically.
#[test]
fn checkpoint_restores_trained_state() {
    use singa::model::checkpoint::Checkpoint;
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 21));
    let mut conf = JobConf::new("ckpt", mlp(8, 64, 16, 5));
    conf.iters = 40;
    conf.updater = UpdaterConf::sgd(0.2);
    let report = run_job(&conf, data.clone());

    // Rebuild a net and load the trained server params into it.
    let mut net = mlp(8, 64, 16, 5).build(&mut Rng::new(999));
    for p in net.params_mut() {
        if let Some(v) = report.params.get(&p.name) {
            p.data = v.clone();
        }
    }
    let ckpt = Checkpoint::from_net(&net);
    let mut buf = Vec::new();
    ckpt.write_to(&mut buf).unwrap();
    let loaded = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
    let mut net2 = mlp(8, 64, 16, 5).build(&mut Rng::new(123));
    assert_eq!(loaded.restore(&mut net2), net.params().len());

    // Identical evaluation on a held-out batch.
    let batch = data.batch(9_999, 8);
    let s1 = singa::train::evaluate(&mut net, &batch);
    let s2 = singa::train::evaluate(&mut net2, &batch);
    assert_eq!(s1.total_loss(), s2.total_loss());
    assert!(s1.metric() > 0.8, "trained checkpoint should be accurate");
}
