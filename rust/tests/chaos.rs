//! Chaos acceptance suite for the unreliable-network plane and retry
//! protocol: deterministic wire faults (drop / corrupt / duplicate /
//! reorder) resolved from a seeded stream, CRC-framed flushes, deadline
//! retransmits on the virtual clock, and graceful degradation to bounded
//! staleness when a link is severed. The key pins:
//!
//! - a lossy run whose buckets all eventually deliver is bit-identical to
//!   the lossless run (retries move virtual time and wasted bytes, never
//!   values) — for `Codec::Raw` and `Codec::Int8`, both exchange modes;
//! - corruptions are detected by the CRC frame, duplicates and stale
//!   reorders are discarded by sequence number, and all of it is
//!   value-transparent;
//! - a severed link degrades to last-known values without hanging or
//!   panicking, the staleness is recorded, and healthy groups are
//!   bit-for-bit unaffected;
//! - probabilistic chaos replays bit-for-bit for a fixed `wire_seed`;
//! - a fault rule naming a group the job doesn't have fails loudly;
//! - the retry plane keeps the steady state allocation-free.
//!
//! CI runs this suite under `PALLAS_NUM_THREADS=1` and `=4`.

use singa::cluster::ClusterTopology;
use singa::comm::{Codec, FaultPlan, RetryConf, WireFault};
use singa::coordinator::{run_job, JobConf, JobReport};
use singa::data::{DataSource, SyntheticDigits};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::NetBuilder;
use singa::tensor::Blob;
use singa::updater::UpdaterConf;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

fn mlp(batch: usize, dim: usize, hidden: usize, classes: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: hidden, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
}

fn digits() -> Arc<dyn DataSource> {
    Arc::new(SyntheticDigits::new(64, 5, 77))
}

/// The last logged (loss, metric) bits per step for one group.
fn last_per_step(report: &JobReport, group: usize) -> BTreeMap<u64, (u32, u32)> {
    let mut m = BTreeMap::new();
    for r in report.log.snapshot() {
        if r.group == group {
            m.insert(r.step, (r.loss.to_bits(), r.metric.to_bits()));
        }
    }
    m
}

fn assert_params_bitwise_equal(a: &HashMap<String, Blob>, b: &HashMap<String, Blob>) {
    assert_eq!(a.len(), b.len(), "param count");
    for (name, va) in a {
        let vb = b.get(name).unwrap_or_else(|| panic!("missing param {name}"));
        assert_eq!(va.shape(), vb.shape(), "{name}");
        for (x, y) in va.data().iter().zip(vb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged");
        }
    }
}

fn healthy(report: &JobReport) {
    for (g, f) in report.group_failures.iter().enumerate() {
        assert!(f.is_none(), "group {g} failed: {f:?}");
    }
}

fn chaos_run(codec: Codec, overlap: bool, iters: u64, faults: FaultPlan) -> JobReport {
    let mut conf = JobConf::new("chaos", mlp(16, 64, 32, 5));
    conf.iters = iters;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.wire_codec = codec;
    conf.overlap_exchange = overlap;
    conf.alloc_probe_from = Some(3);
    conf.faults = faults;
    run_job(&conf, digits())
}

/// The headline pin: every flush's first copy is lost, every retransmit
/// delivers — the run must be bit-identical to the lossless run in both
/// trajectory and final params, for raw and quantized codecs and both
/// exchange modes. Losses cost virtual time and wasted (but honestly
/// charged) bytes, and the retry plane keeps the steady state
/// allocation-free.
#[test]
fn lossy_run_with_eventual_delivery_is_bitwise_identical_to_lossless() {
    let drop_first = FaultPlan::none().drop_nth(0, 0, 1_000, 0);
    for codec in [Codec::Raw, Codec::Int8] {
        for overlap in [false, true] {
            let clean = chaos_run(codec, overlap, 15, FaultPlan::none());
            let lossy = chaos_run(codec, overlap, 15, drop_first.clone());
            healthy(&clean);
            healthy(&lossy);
            let tag = format!("{} overlap={overlap}", codec.name());

            assert_eq!(
                last_per_step(&clean, 0),
                last_per_step(&lossy, 0),
                "{tag}: lossy trajectory diverged"
            );
            assert_params_bitwise_equal(&clean.params, &lossy.params);

            assert!(clean.wire_events.is_clean(), "{tag}: lossless run logged wire events");
            let ev = &lossy.wire_events;
            assert!(ev.drops > 0, "{tag}: drops must be counted");
            assert_eq!(ev.drops, ev.retransmits, "{tag}: one retransmit per lost copy");
            assert_eq!(ev.corruptions_detected, 0, "{tag}");
            assert_eq!(ev.staleness_adoptions, 0, "{tag}: every bucket delivered");
            assert_eq!(ev.degraded_steps, vec![0], "{tag}: no degraded steps");
            assert!(ev.wasted_bytes > 0, "{tag}: lost copies are charged");

            assert!(
                lossy.group_virt_ms[0] > clean.group_virt_ms[0],
                "{tag}: retransmit deadlines must cost virtual time: {} vs {}",
                lossy.group_virt_ms[0],
                clean.group_virt_ms[0]
            );
            assert!(
                lossy.ledger.param_bytes() > clean.ledger.param_bytes(),
                "{tag}: wasted copies must be charged to the ledger"
            );
            assert_eq!(lossy.steady_allocs, vec![0], "{tag}: retry plane must not allocate");
            assert_eq!(clean.steady_allocs, vec![0], "{tag}");
        }
    }
}

/// Corrupt, duplicate, and reorder faults (disjoint step ranges, custom
/// retry knobs): the CRC frame rejects the damaged copy, sequence numbers
/// discard the duplicate and the stale reorder — and none of it perturbs a
/// single bit of training.
#[test]
fn corrupt_duplicate_reorder_are_detected_and_value_transparent() {
    let plan = FaultPlan::none()
        .corrupt_nth(0, 0, 5, 0)
        .duplicate_nth(0, 5, 10, 0)
        .reorder_nth(0, 10, 15, 0);
    let mut conf = JobConf::new("chaos-kinds", mlp(16, 64, 32, 5));
    conf.iters = 15;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.retry = RetryConf::new(800.0, 1.5, 3);
    let clean = run_job(&conf, digits());
    conf.faults = plan;
    let chaotic = run_job(&conf, digits());
    healthy(&clean);
    healthy(&chaotic);

    assert_eq!(
        last_per_step(&clean, 0),
        last_per_step(&chaotic, 0),
        "wire chaos perturbed the trajectory"
    );
    assert_params_bitwise_equal(&clean.params, &chaotic.params);

    let ev = &chaotic.wire_events;
    assert!(ev.corruptions_detected > 0, "CRC must catch the damaged frames");
    assert!(ev.duplicates_discarded > 0, "sequence numbers must catch duplicates");
    assert!(ev.reorders_discarded > 0, "sequence numbers must catch stale reorders");
    assert!(ev.retransmits > 0, "corrupt copies must be retransmitted");
    assert_eq!(ev.staleness_adoptions, 0, "everything eventually delivered");
    assert!(ev.wasted_bytes > 0, "discarded copies are charged");
}

/// Graceful degradation: group 1's link is severed from step 5 on. The
/// group must complete every step without hanging or panicking, adopting
/// its last-known values (recorded as staleness + degraded steps), while
/// group 0 — independent servers, no sync — stays bit-for-bit identical to
/// a lossless run. The degradation deadlines land on the virtual clock.
#[test]
fn severed_link_degrades_to_bounded_staleness_without_hanging() {
    let run = |faults: FaultPlan| {
        let mut conf = JobConf::new("chaos-sever", mlp(16, 64, 32, 5));
        conf.iters = 12;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::hogwild(2, 1, 0);
        conf.faults = faults;
        run_job(&conf, digits())
    };
    let clean = run(FaultPlan::none());
    let severed = run(FaultPlan::none().sever(1, 5));
    healthy(&clean);
    healthy(&severed);

    // Both groups complete their full shard streams — no hang, no panic.
    for g in 0..2 {
        let steps: Vec<u64> = last_per_step(&severed, g).keys().copied().collect();
        assert_eq!(steps, (0..12).collect::<Vec<_>>(), "group {g} must complete");
    }

    // The healthy group is bitwise unaffected (this doubles as the
    // run_job-level armed-but-clean transparency pin: group 0 runs the
    // framed protocol, group 1's rules never touch it).
    assert_eq!(
        last_per_step(&clean, 0),
        last_per_step(&severed, 0),
        "severing group 1 perturbed group 0"
    );
    assert_params_bitwise_equal(&clean.group_params[0], &severed.group_params[0]);

    // The severed group's degradation is recorded: steps 5..12 each had at
    // least one bucket exhaust its retry budget.
    let ev = &severed.wire_events;
    assert_eq!(ev.degraded_steps.len(), 2, "one entry per worker group");
    assert_eq!(ev.degraded_steps[0], 0, "healthy group never degraded");
    assert_eq!(ev.degraded_steps[1], 7, "group 1 degraded every step from 5");
    assert!(ev.staleness_adoptions >= 7, "every degraded step adopted stale values");
    assert!(ev.drops > 0 && ev.wasted_bytes > 0, "severed copies are charged");

    // Exhausted deadlines cost virtual time on the severed group's clock.
    assert!(
        severed.group_virt_ms[1] > clean.group_virt_ms[1],
        "degradation must cost virtual time: {} vs {}",
        severed.group_virt_ms[1],
        clean.group_virt_ms[1]
    );
}

/// Probabilistic chaos replays bit-for-bit: two runs of the same seeded
/// drop-rate plan agree on every logged bit, every final param, and every
/// wire-event tally.
#[test]
fn seeded_probabilistic_chaos_is_bitwise_deterministic() {
    let plan = FaultPlan::none()
        .wire_rate(0, 0, 1_000, WireFault::Drop, 0.35)
        .with_wire_seed(0xC0FFEE);
    let a = chaos_run(Codec::Raw, true, 12, plan.clone());
    let b = chaos_run(Codec::Raw, true, 12, plan);
    healthy(&a);
    healthy(&b);
    assert_eq!(last_per_step(&a, 0), last_per_step(&b, 0), "chaos replay diverged");
    assert_params_bitwise_equal(&a.params, &b.params);
    assert_eq!(a.wire_events, b.wire_events, "wire tallies must replay exactly");
    assert!(a.wire_events.drops > 0, "a 35% drop rate over dozens of copies must fire");
}

/// A wire rule naming a worker group the job does not have is a
/// configuration error, surfaced before any thread spawns.
#[test]
#[should_panic(expected = "names worker group 7")]
fn out_of_range_wire_rule_panics_with_named_group() {
    let mut conf = JobConf::new("chaos-invalid", mlp(8, 64, 16, 5));
    conf.iters = 2;
    conf.faults = FaultPlan::none().drop_nth(7, 0, 10, 0);
    let _ = run_job(&conf, digits());
}

/// Same guard for the process plane: an out-of-range kill is rejected by
/// the same validation pass.
#[test]
#[should_panic(expected = "names worker group 3")]
fn out_of_range_kill_panics_with_named_group() {
    let mut conf = JobConf::new("chaos-invalid-kill", mlp(8, 64, 16, 5));
    conf.iters = 2;
    conf.faults = FaultPlan::none().kill(3, 1);
    let _ = run_job(&conf, digits());
}
