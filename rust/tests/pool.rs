//! Soak / reuse suite for the persistent intra-op worker pool: hundreds of
//! mixed-shape gemm + conv calls through the pool must leak no threads (the
//! worker count stays exactly flat), allocate no pack scratch and no blobs
//! after warm-up, and keep producing bit-identical results throughout.

use singa::runtime::{cores, pool};
use singa::tensor::conv::{
    col2im_acc_with_threads, conv2d_forward_into_with_threads, im2col_with_threads, Conv2dGeom,
    ConvScratch,
};
use singa::tensor::gemm::pack_alloc_count;
use singa::tensor::{gemm_with_threads, Blob, Transpose};
use singa::utils::rng::Rng;

/// Saturate the pool up front: after one dispatch wider than the machine,
/// the worker count sits at its cap and can never grow again — which makes
/// the stability assertions below robust against other tests in this binary
/// (and proves the cap itself).
fn saturate_pool() {
    pool::run(cores() + 3, |_| {});
    assert_eq!(pool::worker_count(), pool::max_workers());
}

#[test]
fn pool_never_exceeds_its_cap() {
    saturate_pool();
    for t in [2usize, 9, 33, 65] {
        pool::run(t, |_| {});
        assert_eq!(pool::worker_count(), pool::max_workers());
    }
}

/// The soak pin from the issue: 500 mixed-shape gemm + conv calls through
/// the pool — pool size stable (no thread leaks), `pack_alloc_count()` and
/// the Blob counter flat after warm-up, outputs bit-identical to serial on
/// every iteration.
#[test]
fn soak_500_mixed_gemm_conv_calls_reuse_everything() {
    saturate_pool();
    let thread_counts = [1usize, 2, 4, 7];

    // --- gemm workloads (two sizes, reused buffers) ---
    let mut rng = Rng::new(0x50a6);
    let gemm_sizes = [48usize, 150];
    let max_n = 150;
    let a = rng.uniform_vec(max_n * max_n, -1.0, 1.0);
    let b = rng.uniform_vec(max_n * max_n, -1.0, 1.0);
    let mut c = vec![0.0f32; max_n * max_n];
    let mut gemm_refs: Vec<Vec<f32>> = Vec::new();
    for &n in &gemm_sizes {
        let mut r = vec![0.0f32; n * n];
        gemm_with_threads(
            Transpose::No,
            Transpose::No,
            n,
            n,
            n,
            1.0,
            &a[..n * n],
            &b[..n * n],
            0.0,
            &mut r,
            1,
        );
        gemm_refs.push(r);
    }

    // --- conv workloads (two geometries, reused out/cols/scratch) ---
    let geoms = [
        (Conv2dGeom { in_c: 4, in_h: 12, in_w: 12, kernel: 3, stride: 1, pad: 1 }, 4usize, 8usize),
        (Conv2dGeom { in_c: 8, in_h: 8, in_w: 8, kernel: 5, stride: 1, pad: 2 }, 2, 16),
    ];
    let mut conv_state = Vec::new();
    let mut conv_refs: Vec<Vec<f32>> = Vec::new();
    for &(g, batch, out_c) in &geoms {
        let img_len = g.in_c * g.in_h * g.in_w;
        let input = Blob::from_vec(
            &[batch, g.in_c, g.in_h, g.in_w],
            rng.uniform_vec(batch * img_len, -1.0, 1.0),
        );
        let cr = g.col_rows();
        let weight = Blob::from_vec(&[out_c, cr], rng.uniform_vec(out_c * cr, -0.5, 0.5));
        let bias = Blob::from_vec(&[out_c], rng.uniform_vec(out_c, -0.1, 0.1));
        let mut out = Blob::default();
        let mut cols: Vec<Vec<f32>> = Vec::new();
        let mut scratch = ConvScratch::new();
        conv2d_forward_into_with_threads(
            &input, &weight, &bias, &g, &mut out, &mut cols, &mut scratch, 1,
        );
        conv_refs.push(out.data().to_vec());
        conv_state.push((g, input, weight, bias, out, cols, scratch));
    }

    // --- standalone im2col / col2im_acc buffers ---
    let (gi, _, _) = geoms[0];
    let img = rng.uniform_vec(gi.in_c * gi.in_h * gi.in_w, -1.0, 1.0);
    let mut col = vec![0.0f32; gi.col_rows() * gi.col_cols()];
    let mut fold = vec![0.0f32; img.len()];
    let mut im2col_ref = vec![0.0f32; col.len()];
    im2col_with_threads(&img, &gi, &mut im2col_ref, 1);

    // Warm-up: touch every (workload, thread-count) combination once so
    // the pack pool, conv scratch, and output capacities reach their
    // steady-state sizes.
    for &t in &thread_counts {
        for (si, &n) in gemm_sizes.iter().enumerate() {
            gemm_with_threads(
                Transpose::No,
                Transpose::No,
                n,
                n,
                n,
                1.0,
                &a[..n * n],
                &b[..n * n],
                0.0,
                &mut c[..n * n],
                t,
            );
            assert!(c[..n * n] == gemm_refs[si][..], "warm-up gemm n={n} t={t}");
        }
        for (ci, (g, input, weight, bias, out, cols, scratch)) in
            conv_state.iter_mut().enumerate()
        {
            conv2d_forward_into_with_threads(input, weight, bias, g, out, cols, scratch, t);
            assert!(out.data() == &conv_refs[ci][..], "warm-up conv case {ci} t={t}");
        }
        im2col_with_threads(&img, &gi, &mut col, t);
        col2im_acc_with_threads(&col, &gi, &mut fold, t);
    }

    // Steady state: 500 mixed calls; every counter must stay flat.
    let workers_before = pool::worker_count();
    let packs_before = pack_alloc_count();
    let blobs_before = Blob::alloc_count();
    for i in 0..500usize {
        let t = thread_counts[i % thread_counts.len()];
        match i % 4 {
            0 | 1 => {
                let si = (i / 4) % gemm_sizes.len();
                let n = gemm_sizes[si];
                gemm_with_threads(
                    Transpose::No,
                    Transpose::No,
                    n,
                    n,
                    n,
                    1.0,
                    &a[..n * n],
                    &b[..n * n],
                    0.0,
                    &mut c[..n * n],
                    t,
                );
                assert!(c[..n * n] == gemm_refs[si][..], "soak iter {i}: gemm n={n} t={t}");
            }
            2 => {
                let ci = (i / 4) % conv_state.len();
                let (g, input, weight, bias, out, cols, scratch) = &mut conv_state[ci];
                conv2d_forward_into_with_threads(input, weight, bias, g, out, cols, scratch, t);
                assert!(out.data() == &conv_refs[ci][..], "soak iter {i}: conv case {ci} t={t}");
            }
            _ => {
                im2col_with_threads(&img, &gi, &mut col, t);
                assert!(col == im2col_ref, "soak iter {i}: im2col t={t}");
                col2im_acc_with_threads(&col, &gi, &mut fold, t);
            }
        }
    }
    assert_eq!(
        pool::worker_count(),
        workers_before,
        "pool leaked or spawned threads during steady state"
    );
    assert_eq!(
        pack_alloc_count(),
        packs_before,
        "steady-state gemm must not allocate pack scratch"
    );
    assert_eq!(
        Blob::alloc_count(),
        blobs_before,
        "steady-state conv must not allocate blobs"
    );
}
