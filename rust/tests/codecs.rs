//! Codec test matrix for the wire-compressed parameter plane: per-codec
//! roundtrip error bounds on adversarial buckets, the error-feedback
//! accumulation contract (residuals keep the decoded running sum on the
//! uncompressed trajectory; dropping them visibly drifts), hardened decode
//! of corrupt chunks, an exhaustive bit-flip matrix over CRC-framed chunks
//! (every single-bit corruption detected), and full `run_job` pins — explicit `Codec::Raw`
//! bit-identical to the default exchange, f16/int8 overlap-vs-sequential
//! bitwise, compressed training convergence, zero steady-state Blob
//! allocations with compression armed, and honest ledger shrink.
//!
//! CI runs this suite under `PALLAS_NUM_THREADS=1` and `=4`.

use singa::comm::codec::{self, Codec, CHUNK_HEADER};
use singa::coordinator::{run_job, JobConf, JobReport};
use singa::data::{DataSource, SyntheticDigits};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::NetBuilder;
use singa::updater::UpdaterConf;
use singa::utils::quickcheck::{forall, prop_assert, Gen, PropResult};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Satellite 1: property tests — roundtrip error bounds on edge buckets
// ---------------------------------------------------------------------------

/// Generate one bucket, biased toward the quantizer's hard cases: all-zero,
/// single-element, constant-value, subnormal-magnitude, and ±huge chunks
/// alongside plain gaussian noise.
fn gen_bucket(g: &mut Gen) -> Vec<f32> {
    let n = g.usize(1, 64);
    match *g.choose(&["random", "zero", "single", "constant", "subnormal", "huge"]) {
        "random" => g.gaussian_vec(n, 1.0),
        "zero" => vec![0.0; n],
        "single" => {
            let mut v = vec![0.0; n];
            let j = g.usize(0, n - 1);
            v[j] = g.f32(-5.0, 5.0);
            v
        }
        "constant" => vec![g.f32(-3.0, 3.0); n],
        "subnormal" => g.f32_vec(n, -1e-41, 1e-41),
        "huge" => g.f32_vec(n, -1e38, 1e38),
        other => unreachable!("unknown bucket kind {other}"),
    }
}

/// Per-codec absolute error bound for one bucket: f16 errors stay under
/// `max_abs / 1000` (the binary16 relative step after normalization is
/// 2^-11), int8 under `max_abs / 100` (half a quantization step is
/// `max_abs / 254`); the additive slack covers subnormal-scale precision
/// loss and underflow-to-zero chunks.
fn roundtrip_atol(codec: Codec, src: &[f32]) -> f32 {
    let max_abs = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    match codec {
        Codec::Raw => 0.0,
        Codec::F16 => max_abs / 1000.0 + 1e-41,
        Codec::Int8 => max_abs / 100.0 + 1e-41,
    }
}

#[test]
fn quantizing_roundtrip_stays_within_per_codec_bounds() {
    forall(300, |g| {
        let src = gen_bucket(g);
        let mut enc = Vec::new();
        let mut dec = vec![0.0f32; src.len()];
        for codec in [Codec::F16, Codec::Int8] {
            codec.encode_into(&src, &mut enc);
            prop_assert(
                enc.len() == codec.encoded_len(src.len()),
                &format!("{}: encoded length", codec.name()),
            )?;
            codec
                .decode_into(&enc, &mut dec)
                .map_err(|e| format!("{}: decode failed: {e}", codec.name()))?;
            let atol = roundtrip_atol(codec, &src);
            for (i, (&x, &y)) in src.iter().zip(&dec).enumerate() {
                prop_assert(
                    (x - y).abs() <= atol,
                    &format!(
                        "{} idx {i}: {x} decoded as {y} (atol {atol}, n={})",
                        codec.name(),
                        src.len()
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn raw_roundtrip_is_bitwise() {
    forall(300, |g| -> PropResult {
        let src = gen_bucket(g);
        let mut enc = Vec::new();
        let mut dec = vec![0.0f32; src.len()];
        Codec::Raw.encode_into(&src, &mut enc);
        Codec::Raw.decode_into(&enc, &mut dec).map_err(|e| format!("raw decode: {e}"))?;
        for (i, (&x, &y)) in src.iter().zip(&dec).enumerate() {
            prop_assert(
                x.to_bits() == y.to_bits(),
                &format!("raw idx {i}: {x:?} -> {y:?} not bitwise"),
            )?;
        }
        Ok(())
    });
}

/// The all-zero chunk is the scale-0 sentinel: every codec must decode it
/// to exact zeros (not NaN from a 0/0 normalization).
#[test]
fn all_zero_bucket_decodes_to_exact_zeros() {
    let src = [0.0f32; 17];
    let mut enc = Vec::new();
    let mut dec = [1.0f32; 17];
    for codec in [Codec::Raw, Codec::F16, Codec::Int8] {
        codec.encode_into(&src, &mut enc);
        codec.decode_into(&enc, &mut dec).unwrap();
        assert!(dec.iter().all(|&v| v == 0.0), "{}: zeros in, zeros out", codec.name());
    }
}

// ---------------------------------------------------------------------------
// Satellite 2: error feedback keeps the decoded running sum on track
// ---------------------------------------------------------------------------

/// Feed the same gradient bucket through int8 for 200 steps. With error
/// feedback ([`codec::feedback_encode`] — the exact recipe the comm path
/// runs) the sum of decoded gradients telescopes to the true running sum
/// minus one bounded residual. Without feedback, the element sitting
/// between two quantization levels (0.0042 ≈ 0.53 steps) picks up the same
/// rounding bias every step and drifts linearly.
#[test]
fn int8_error_feedback_tracks_uncompressed_running_sum() {
    let grad = [1.0f32, 0.0042, -0.0042, 0.5];
    let steps = 200u32;

    let mut residual = [0.0f32; 4];
    let mut dec = [0.0f32; 4];
    let mut enc = Vec::new();
    let mut sum_fb = [0.0f64; 4];
    for _ in 0..steps {
        let mut g = grad;
        codec::feedback_encode(Codec::Int8, &mut g, &mut residual, &mut enc, &mut dec);
        for (s, &d) in sum_fb.iter_mut().zip(&dec) {
            *s += d as f64;
        }
    }

    let mut sum_nf = [0.0f64; 4];
    let mut plain = [0.0f32; 4];
    for _ in 0..steps {
        Codec::Int8.encode_into(&grad, &mut enc);
        Codec::Int8.decode_into(&enc, &mut plain).unwrap();
        for (s, &d) in sum_nf.iter_mut().zip(&plain) {
            *s += d as f64;
        }
    }

    // With feedback: |sum error| = |final residual| ≤ half a quantization
    // step of the compensated gradient (≈ max_abs / 254).
    for i in 0..4 {
        let want = grad[i] as f64 * steps as f64;
        let err = (sum_fb[i] - want).abs();
        assert!(err <= 0.016, "element {i}: feedback sum error {err} after {steps} steps");
    }

    // Without feedback: the biased element drifts by ~0.0037/step.
    let want1 = grad[1] as f64 * steps as f64;
    let err_fb = (sum_fb[1] - want1).abs();
    let err_nf = (sum_nf[1] - want1).abs();
    assert!(err_nf > 0.3, "expected visible drift without feedback, got {err_nf}");
    assert!(
        err_nf > 10.0 * err_fb.max(1e-6),
        "feedback must beat plain quantization by an order of magnitude: \
         {err_fb} (fb) vs {err_nf} (none)"
    );
}

/// Error feedback never lets the residual grow without bound: after any
/// number of steps of a random (but fixed) gradient, the residual stays
/// under one quantization step of the compensated gradient.
#[test]
fn error_feedback_residual_stays_bounded() {
    forall(50, |g| -> PropResult {
        let grad = g.gaussian_vec(g.usize(1, 32), 1.0);
        let max_abs = grad.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut residual = vec![0.0f32; grad.len()];
        let mut dec = vec![0.0f32; grad.len()];
        let mut enc = Vec::new();
        for _ in 0..50 {
            let mut step = grad.clone();
            codec::feedback_encode(Codec::Int8, &mut step, &mut residual, &mut enc, &mut dec);
        }
        // Compensated max_abs ≤ max_abs + bound; one step ≈ that / 127.
        let bound = (max_abs + 0.1) / 100.0 + 1e-41;
        for (i, &r) in residual.iter().enumerate() {
            prop_assert(
                r.abs() <= bound,
                &format!("residual {i} grew to {r} (bound {bound}, max_abs {max_abs})"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Satellite 3: hardened decode — corrupt chunks are errors, not panics
// ---------------------------------------------------------------------------

/// Every corruption mode returns an error naming the offending field, for
/// every codec — mirroring the checkpoint reader's hardening.
#[test]
fn corrupt_chunks_error_instead_of_panicking() {
    let src = [0.25f32, -1.5, 3.0, 0.0, 0.75, -0.125];
    for codec in [Codec::Raw, Codec::F16, Codec::Int8] {
        let name = codec.name();
        let mut enc = Vec::new();
        codec.encode_into(&src, &mut enc);
        let mut dst = [0.0f32; 6];

        // Truncated header.
        let err = codec.decode_into(&enc[..4], &mut dst).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{name}: {err}");

        // Short payload (one byte missing).
        let err = codec.decode_into(&enc[..enc.len() - 1], &mut dst).unwrap_err();
        assert!(err.to_string().contains("payload"), "{name}: {err}");

        // NaN scale.
        let mut bad = enc.clone();
        bad[1..5].copy_from_slice(&f32::NAN.to_le_bytes());
        let err = codec.decode_into(&bad, &mut dst).unwrap_err();
        assert!(err.to_string().contains("not finite"), "{name}: {err}");

        // Negative scale.
        let mut bad = enc.clone();
        bad[1..5].copy_from_slice(&(-1.0f32).to_le_bytes());
        let err = codec.decode_into(&bad, &mut dst).unwrap_err();
        assert!(err.to_string().contains("negative"), "{name}: {err}");

        // Corrupt element count far past the MAX_ELEMS bound.
        let mut bad = enc.clone();
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = codec.decode_into(&bad, &mut dst).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{name}: {err}");

        // Count / destination mismatch.
        let mut short = [0.0f32; 5];
        let err = codec.decode_into(&enc, &mut short).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{name}: {err}");

        // Codec tag mismatch: a chunk encoded by one codec must be rejected
        // by the others' decoders.
        for other in [Codec::Raw, Codec::F16, Codec::Int8] {
            if other == codec {
                continue;
            }
            let err = other.decode_into(&enc, &mut dst).unwrap_err();
            assert!(err.to_string().contains("tag"), "{name} vs {}: {err}", other.name());
        }

        // The pristine chunk still decodes after all that.
        codec.decode_into(&enc, &mut dst).unwrap();
    }
}

/// An empty buffer and a bare header are both truncation errors; a header
/// with zero elements and an empty destination is valid.
#[test]
fn decode_boundary_sizes() {
    let mut dst = [0.0f32; 0];
    for codec in [Codec::Raw, Codec::F16, Codec::Int8] {
        assert!(codec.decode_into(&[], &mut dst).is_err());
        assert!(codec.decode_into(&[codec as u8], &mut dst).is_err());
        let mut enc = Vec::new();
        codec.encode_into(&[], &mut enc);
        assert_eq!(enc.len(), CHUNK_HEADER);
        codec.decode_into(&enc, &mut dst).unwrap();
    }
}

// ---------------------------------------------------------------------------
// CRC frame integrity: every single-bit corruption is detected
// ---------------------------------------------------------------------------

/// Exhaustive bit-flip matrix over a CRC-framed chunk, per codec: a flip in
/// the sequence field surfaces as a sequence mismatch at the receiver, and
/// a flip anywhere else — CRC field or chunk body, Raw payloads included —
/// fails `frame_verify`. No single-bit corruption is ever silently
/// accepted, which is what lets the retry protocol trust a verified frame.
#[test]
fn every_single_bit_flip_in_a_framed_chunk_is_detected() {
    let src = [0.25f32, -1.5, 3.0, 0.0, 0.75, -0.125, 42.0, -7.5];
    let seq = 7u32;
    for codec in [Codec::Raw, Codec::F16, Codec::Int8] {
        let name = codec.name();
        let mut frame = Vec::new();
        codec::frame_chunk(codec, seq, &src, &mut frame);

        // Pristine frame: verifies, carries the seq, and wraps exactly the
        // chunk a bare encode of the same payload produces.
        let (got, chunk) = codec::frame_verify(&frame).unwrap();
        assert_eq!(got, seq, "{name}: pristine frame sequence number");
        let mut bare = Vec::new();
        codec.encode_into(&src, &mut bare);
        assert_eq!(chunk, &bare[..], "{name}: framed chunk != bare encode");

        for bit in 0..frame.len() * 8 {
            let mut bad = frame.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            let rejected = match codec::frame_verify(&bad) {
                Err(_) => true,
                Ok((s, _)) => s != seq,
            };
            assert!(rejected, "{name}: flipped bit {bit} was silently accepted");
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end run_job pins
// ---------------------------------------------------------------------------

fn mlp(batch: usize, dim: usize, hidden: usize, classes: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: hidden, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
}

fn digits() -> Arc<dyn DataSource> {
    Arc::new(SyntheticDigits::new(64, 5, 77))
}

/// Compare two single-group runs bit for bit: (step, loss, metric)
/// sequences and every server group's final replica.
fn assert_reports_bitwise_equal(a: &JobReport, b: &JobReport) {
    let (ra, rb) = (a.log.snapshot(), b.log.snapshot());
    assert_eq!(ra.len(), rb.len(), "record count");
    for (x, y) in ra.iter().zip(&rb) {
        assert_eq!((x.group, x.step), (y.group, y.step));
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "step {}: loss diverged", x.step);
        assert_eq!(x.metric.to_bits(), y.metric.to_bits(), "step {}: metric diverged", x.step);
    }
    assert_eq!(a.group_params.len(), b.group_params.len());
    for (sg, (pa, pb)) in a.group_params.iter().zip(&b.group_params).enumerate() {
        assert_eq!(pa.len(), pb.len(), "server group {sg}");
        for (name, va) in pa {
            let vb = pb.get(name).unwrap_or_else(|| panic!("missing param {name}"));
            for (x, y) in va.data().iter().zip(vb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "server group {sg} param {name}");
            }
        }
    }
}

fn codec_run(codec: Codec, overlap: bool, iters: u64) -> JobReport {
    let mut conf = JobConf::new("codec-e2e", mlp(16, 64, 32, 5));
    conf.iters = iters;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.wire_codec = codec;
    conf.overlap_exchange = overlap;
    conf.alloc_probe_from = Some(3);
    run_job(&conf, digits())
}

/// The codec knob at its `raw` default is the historical exchange: an
/// explicit `Codec::Raw` run is bit-identical to a run of the default
/// configuration, in both exchange modes.
#[test]
fn explicit_raw_codec_matches_default_bitwise() {
    for overlap in [false, true] {
        let mut default_conf = JobConf::new("codec-e2e", mlp(16, 64, 32, 5));
        default_conf.iters = 15;
        default_conf.updater = UpdaterConf::sgd(0.1);
        default_conf.overlap_exchange = overlap;
        default_conf.alloc_probe_from = Some(3);
        let default_run = run_job(&default_conf, digits());
        let explicit = codec_run(Codec::Raw, overlap, 15);
        assert_reports_bitwise_equal(&default_run, &explicit);
    }
}

/// Sequential and overlapped exchanges stay bit-identical under the
/// quantizing codecs: both route through the same per-slot error-feedback
/// encode, and residuals are per-slot state, so bucket completion order
/// cannot perturb them. The steady state stays allocation-free with
/// compression armed — encode/decode scratch and residual slots were sized
/// at workspace construction.
#[test]
fn compressed_overlap_matches_sequential_bitwise_and_alloc_free() {
    for codec in [Codec::F16, Codec::Int8] {
        let seq = codec_run(codec, false, 15);
        let ovl = codec_run(codec, true, 15);
        assert_reports_bitwise_equal(&seq, &ovl);
        assert_eq!(seq.steady_allocs, vec![0], "{}: sequential steady allocs", codec.name());
        assert_eq!(ovl.steady_allocs, vec![0], "{}: overlapped steady allocs", codec.name());
    }
}

/// Compressed training still converges: error feedback re-injects the
/// quantization error, so f16 and int8 runs reach the same quality band as
/// the task demands (the digits MLP separates cleanly within 80 iters).
#[test]
fn compressed_training_converges() {
    for codec in [Codec::F16, Codec::Int8] {
        let report = codec_run(codec, true, 80);
        for (g, f) in report.group_failures.iter().enumerate() {
            assert!(f.is_none(), "group {g} failed: {f:?}");
        }
        let recs = report.log.snapshot();
        let last = recs.iter().filter(|r| r.group == 0).last().expect("log records");
        assert!(
            last.metric > 0.7,
            "{}: final metric {} after 80 iters must clear 0.7",
            codec.name(),
            last.metric
        );
    }
}

/// The ledger charges the compressed chunk sizes, not the raw payloads:
/// parameter-plane bytes shrink by roughly the codec's element ratio
/// (headers keep it off the ideal ½ / ¼), and strictly ordered
/// int8 < f16 < raw.
#[test]
fn ledger_charges_shrink_with_compression() {
    let raw = codec_run(Codec::Raw, true, 40).ledger.param_bytes();
    let f16 = codec_run(Codec::F16, true, 40).ledger.param_bytes();
    let int8 = codec_run(Codec::Int8, true, 40).ledger.param_bytes();
    assert!(int8 < f16 && f16 < raw, "expected int8 < f16 < raw, got {int8} / {f16} / {raw}");
    assert!(
        (f16 as f64) < 0.65 * raw as f64,
        "f16 must roughly halve the wire: {f16} vs raw {raw}"
    );
    assert!(
        (int8 as f64) < 0.40 * raw as f64,
        "int8 must roughly quarter the wire: {int8} vs raw {raw}"
    );
}
