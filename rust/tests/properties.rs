//! Property tests over the partitioner and training stack: random model
//! shapes × random partition assignments must always produce a runnable,
//! gradient-complete net, and batch-dimension partitioning must preserve
//! the full-batch loss exactly — plus the determinism contract of every
//! pooled intra-op kernel (GEMM, im2col, col2im): every thread count
//! yields bit-for-bit the serial result — and the kernel-dispatch
//! contract: the simd gemm approximates the scalar oracle within FMA
//! tolerance (bit-identical across thread counts within the family), and
//! the simd conv transforms reproduce the scalar path exactly.

use singa::model::layer::{Activation, LayerConf, LayerKind, Phase};
use singa::model::partition::{logical_param_name, partition_net};
use singa::model::NetBuilder;
use singa::tensor::conv::{
    col2im_acc_with_threads, col2im_with_threads, im2col_with_threads, Conv2dGeom,
};
use singa::tensor::{gemm_with_threads, Blob, Transpose};
use singa::utils::quickcheck::{forall, prop_assert, PropResult};
use singa::utils::rng::Rng;

/// Random MLP: depth 1-3 hidden layers, random widths, SoftmaxLoss head.
fn random_mlp(g: &mut singa::utils::quickcheck::Gen, batch: usize) -> (NetBuilder, usize) {
    // Widths ≥ 4 so feature-dimension splits across ≤3 workers never
    // produce an empty sub-layer (the partitioner rejects out < workers).
    let in_dim = g.usize(4, 12);
    let depth = g.usize(1, 3);
    let classes = g.usize(4, 6);
    let mut b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, in_dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]));
    let mut prev = "data".to_string();
    for i in 0..depth {
        let name = format!("h{i}");
        let act = *g.choose(&[Activation::Relu, Activation::Sigmoid, Activation::Tanh]);
        b = b.add(LayerConf::new(
            &name,
            LayerKind::InnerProduct { out: g.usize(4, 10), act, init_std: 0.2 },
            &[&prev],
        ));
        prev = name;
    }
    b = b.add(LayerConf::new(
        "logits",
        LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.2 },
        &[&prev],
    ));
    b = b.add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
    (b, classes)
}

fn run_forward_backward(
    b: &NetBuilder,
    workers: usize,
    batch: usize,
    in_dim: usize,
    classes: usize,
    seed: u64,
) -> PropResult {
    let (bp, _plan) = partition_net(b, workers);
    let mut net = bp.build(&mut Rng::new(seed));
    let mut rng = Rng::new(seed ^ 0xf00d);
    net.set_input("data", Blob::from_vec(&[batch, in_dim], rng.uniform_vec(batch * in_dim, -1.0, 1.0)));
    net.set_input(
        "label",
        Blob::from_vec(&[batch], (0..batch).map(|i| (i % classes) as f32).collect()),
    );
    net.zero_grads();
    net.forward(Phase::Train);
    net.backward();
    // every learnable parameter must have received a gradient
    for p in net.params_mut() {
        if p.grad.norm() == 0.0 {
            // Zero gradient is legitimately possible (dead relu sub-batch),
            // but all-params-zero would mean a broken graph.
        }
    }
    let any_grad = {
        let mut net2 = net;
        net2.params_mut().iter().any(|p| p.grad.norm() > 0.0)
    };
    prop_assert(any_grad, "at least one param gradient must flow")
}

#[test]
fn random_partitions_always_build_and_train() {
    forall(40, |g| {
        let batch = g.usize(2, 8) * 2; // even batches so splits stay non-empty
        let (mut b, classes) = random_mlp(g, batch);
        let in_dim = match &b.confs()[0].kind {
            LayerKind::Input { shape } => shape[1],
            _ => unreachable!(),
        };
        let workers = g.usize(1, 3);
        // Random partition assignment per non-input layer.
        let choices = [None, Some(0), Some(1)];
        for c in b.confs_mut().iter_mut() {
            if matches!(c.kind, LayerKind::InnerProduct { .. }) {
                c.partition_dim = *g.choose(&choices);
            } else if matches!(c.kind, LayerKind::SoftmaxLoss) {
                // loss supports dim 0 or none
                c.partition_dim = *g.choose(&[None, Some(0)]);
            }
        }
        run_forward_backward(&b, workers, batch, in_dim, classes, 0xabc)
    });
}

#[test]
fn dim0_partitioning_preserves_mean_loss_for_random_models() {
    forall(25, |g| {
        let workers = g.usize(2, 4);
        let batch = workers * g.usize(1, 4); // divisible so shards are equal
        let (mut b, classes) = random_mlp(g, batch);
        let in_dim = match &b.confs()[0].kind {
            LayerKind::Input { shape } => shape[1],
            _ => unreachable!(),
        };
        // Reference (unpartitioned).
        let mut ref_net = b.clone().build(&mut Rng::new(7));
        // Partition everything learnable + loss on dim 0.
        for c in b.confs_mut().iter_mut() {
            if matches!(c.kind, LayerKind::InnerProduct { .. } | LayerKind::SoftmaxLoss) {
                c.partition_dim = Some(0);
            }
        }
        let (bp, _) = partition_net(&b, workers);
        let mut part_net = bp.build(&mut Rng::new(7));
        // Copy reference weights into every replica by logical name.
        let reference: std::collections::HashMap<String, Blob> =
            ref_net.params().iter().map(|p| (p.name.clone(), p.data.clone())).collect();
        for p in part_net.params_mut() {
            if let Some(v) = reference.get(&logical_param_name(&p.name)) {
                p.data = v.clone();
            }
        }
        let mut rng = Rng::new(3);
        let x = Blob::from_vec(&[batch, in_dim], rng.uniform_vec(batch * in_dim, -1.0, 1.0));
        let y = Blob::from_vec(&[batch], (0..batch).map(|i| (i % classes) as f32).collect());
        ref_net.set_input("data", x.clone());
        ref_net.set_input("label", y.clone());
        ref_net.forward(Phase::Train);
        part_net.set_input("data", x);
        part_net.set_input("label", y);
        part_net.forward(Phase::Train);

        let full = ref_net.total_loss();
        let losses = part_net.losses();
        let mean: f32 = losses.iter().map(|(_, l, _)| l).sum::<f32>() / losses.len() as f32;
        prop_assert(
            (full - mean).abs() < 1e-4,
            &format!("full {full} vs sharded mean {mean} (workers {workers}, batch {batch})"),
        )
    });
}

/// The tentpole determinism property: for random (m, n, k, alpha, beta,
/// ta, tb), every thread count in {2, 4, 7} produces output `==`-identical
/// (bit-for-bit, not `prop_close`) to the serial path.
#[test]
fn parallel_gemm_bit_identical_to_serial_for_random_shapes() {
    forall(30, |g| {
        let m = g.usize(1, 160); // up to 3 MC row blocks
        let n = g.usize(1, 96);
        let k = g.usize(1, 70);
        let alpha = *g.choose(&[1.0f32, -1.0, 2.5, 0.0, 0.3]);
        let beta = *g.choose(&[0.0f32, 1.0, -0.5, 2.0]);
        let ta = if g.bool() { Transpose::Yes } else { Transpose::No };
        let tb = if g.bool() { Transpose::Yes } else { Transpose::No };
        let a = g.f32_vec(m * k, -1.0, 1.0);
        let b = g.f32_vec(k * n, -1.0, 1.0);
        let c0 = g.f32_vec(m * n, -1.0, 1.0);
        let mut serial = c0.clone();
        gemm_with_threads(ta, tb, m, n, k, alpha, &a, &b, beta, &mut serial, 1);
        for &t in &[2usize, 4, 7] {
            let mut par = c0.clone();
            gemm_with_threads(ta, tb, m, n, k, alpha, &a, &b, beta, &mut par, t);
            prop_assert(
                par == serial,
                &format!(
                    "threads={t} differs from serial \
                     (m={m} n={n} k={k} alpha={alpha} beta={beta} ta={ta:?} tb={tb:?})"
                ),
            )?;
        }
        Ok(())
    });
}

/// Block-boundary-straddling and degenerate sizes, pinned explicitly:
/// stripes that end mid-MC-block, panels that straddle KC/NC, empty dims.
#[test]
fn parallel_gemm_bit_identical_on_block_straddling_sizes() {
    let cases = [
        (65usize, 257usize, 40usize), // partial MC tail + NC straddle
        (70, 130, 260),               // KC straddle with beta accumulate below
        (129, 64, 257),               // 3rd stripe is a single row
        (191, 31, 511),               // odd tail row exercises the 1-row kernel path
        (256, 40, 70),                // 4 exact MC blocks
        (1, 1, 1),
        (64, 1, 1),
        (3, 2, 0), // k = 0: pure beta scaling
        (0, 4, 4), // m = 0: empty C
        (5, 0, 9), // n = 0
    ];
    for &(m, n, k) in &cases {
        let mut rng = Rng::new((m * 131 + n * 17 + k) as u64);
        let a = rng.uniform_vec(m * k, -1.0, 1.0);
        let b = rng.uniform_vec(k * n, -1.0, 1.0);
        let c0 = rng.uniform_vec(m * n, -1.0, 1.0);
        for &(alpha, beta) in &[(1.0f32, 0.0f32), (2.5, -0.5), (0.0, 2.0), (-1.0, 1.0)] {
            let mut serial = c0.clone();
            gemm_with_threads(
                Transpose::No, Transpose::No, m, n, k, alpha, &a, &b, beta, &mut serial, 1,
            );
            for &t in &[2usize, 4, 7] {
                let mut par = c0.clone();
                gemm_with_threads(
                    Transpose::No, Transpose::No, m, n, k, alpha, &a, &b, beta, &mut par, t,
                );
                assert_eq!(
                    par, serial,
                    "m={m} n={n} k={k} t={t} alpha={alpha} beta={beta}"
                );
            }
        }
    }
}

/// The conv-transform determinism property: for random geometries
/// (channels, image size, kernel, stride, pad — including kernel == padded
/// image and stride > kernel), parallel `im2col`, `col2im` and
/// `col2im_acc` are `==`-identical (bit-for-bit) to the serial path at
/// every task count in {2, 4, 7}.
#[test]
fn parallel_conv_transforms_bit_identical_for_random_geometries() {
    forall(40, |q| {
        let c = q.usize(1, 5);
        let h = q.usize(1, 12);
        let w = q.usize(1, 12);
        let pad = q.usize(0, 2);
        // Keep the geometry valid: kernel must fit the padded image.
        let kmax = (h.min(w) + 2 * pad).min(5);
        let k = q.usize(1, kmax.max(1));
        let stride = q.usize(1, 3);
        let g = Conv2dGeom { in_c: c, in_h: h, in_w: w, kernel: k, stride, pad };
        let n = g.col_rows() * g.col_cols();

        let img = q.f32_vec(c * h * w, -1.0, 1.0);
        let mut col_serial = vec![0.0f32; n];
        im2col_with_threads(&img, &g, &mut col_serial, 1);

        let colm = q.f32_vec(n, -1.0, 1.0);
        // col2im_acc accumulates into a randomly pre-filled image (the
        // executor hands over slots already holding sibling gradients).
        let img0 = q.f32_vec(c * h * w, -1.0, 1.0);
        let mut acc_serial = img0.clone();
        col2im_acc_with_threads(&colm, &g, &mut acc_serial, 1);
        let mut fold_serial = vec![1.0f32; c * h * w];
        col2im_with_threads(&colm, &g, &mut fold_serial, 1);

        for &t in &[2usize, 4, 7] {
            let mut col_t = vec![0.0f32; n];
            im2col_with_threads(&img, &g, &mut col_t, t);
            prop_assert(
                col_t == col_serial,
                &format!("im2col t={t} differs (c={c} h={h} w={w} k={k} s={stride} p={pad})"),
            )?;
            let mut acc_t = img0.clone();
            col2im_acc_with_threads(&colm, &g, &mut acc_t, t);
            prop_assert(
                acc_t == acc_serial,
                &format!("col2im_acc t={t} differs (c={c} h={h} w={w} k={k} s={stride} p={pad})"),
            )?;
            let mut fold_t = vec![1.0f32; c * h * w];
            col2im_with_threads(&colm, &g, &mut fold_t, t);
            prop_assert(
                fold_t == fold_serial,
                &format!("col2im t={t} differs (c={c} h={h} w={w} k={k} s={stride} p={pad})"),
            )?;
        }
        Ok(())
    });
}

/// Degenerate conv shapes pinned explicitly: zero channels (empty
/// matrices), 1×1 images, kernel == padded image, stride larger than the
/// image — all must short-circuit or stripe identically at every count.
#[test]
fn parallel_conv_transforms_bit_identical_on_degenerate_shapes() {
    let cases = [
        Conv2dGeom { in_c: 0, in_h: 3, in_w: 3, kernel: 1, stride: 1, pad: 0 },
        Conv2dGeom { in_c: 1, in_h: 1, in_w: 1, kernel: 1, stride: 1, pad: 0 },
        Conv2dGeom { in_c: 3, in_h: 2, in_w: 2, kernel: 4, stride: 1, pad: 1 },
        Conv2dGeom { in_c: 2, in_h: 5, in_w: 5, kernel: 1, stride: 7, pad: 0 },
        Conv2dGeom { in_c: 7, in_h: 4, in_w: 6, kernel: 3, stride: 2, pad: 2 },
    ];
    for g in &cases {
        let mut rng = Rng::new((g.in_c * 37 + g.in_h * 5 + g.kernel) as u64);
        let img = rng.uniform_vec(g.in_c * g.in_h * g.in_w, -1.0, 1.0);
        let n = g.col_rows() * g.col_cols();
        let colm = rng.uniform_vec(n, -1.0, 1.0);
        let img0 = rng.uniform_vec(g.in_c * g.in_h * g.in_w, -1.0, 1.0);
        let mut col_serial = vec![0.0f32; n];
        im2col_with_threads(&img, g, &mut col_serial, 1);
        let mut acc_serial = img0.clone();
        col2im_acc_with_threads(&colm, g, &mut acc_serial, 1);
        for &t in &[2usize, 4, 7] {
            let mut col_t = vec![0.0f32; n];
            im2col_with_threads(&img, g, &mut col_t, t);
            assert!(col_t == col_serial, "im2col t={t} differs on {g:?}");
            let mut acc_t = img0.clone();
            col2im_acc_with_threads(&colm, g, &mut acc_t, t);
            assert!(acc_t == acc_serial, "col2im_acc t={t} differs on {g:?}");
        }
    }
}

/// The kernel-dispatch property: for random (m, n, k, alpha, beta, ta,
/// tb), the simd gemm approximates the scalar oracle within the FMA
/// reordering tolerance, and within the simd family every thread count is
/// bit-identical to simd serial. Skipped (with a notice) off AVX2+FMA.
#[test]
fn simd_gemm_matches_scalar_oracle_for_random_shapes() {
    use singa::tensor::gemm::gemm_with_kernel;
    use singa::tensor::KernelKind;
    if !singa::tensor::kernel::simd_supported() {
        eprintln!("NOTICE: AVX2+FMA not detected; skipping simd gemm property test");
        return;
    }
    forall(30, |g| {
        let m = g.usize(1, 160);
        let n = g.usize(1, 96);
        let k = g.usize(1, 70);
        let alpha = *g.choose(&[1.0f32, -1.0, 2.5, 0.0, 0.3]);
        let beta = *g.choose(&[0.0f32, 1.0, -0.5, 2.0]);
        let ta = if g.bool() { Transpose::Yes } else { Transpose::No };
        let tb = if g.bool() { Transpose::Yes } else { Transpose::No };
        let a = g.f32_vec(m * k, -1.0, 1.0);
        let b = g.f32_vec(k * n, -1.0, 1.0);
        let c0 = g.f32_vec(m * n, -1.0, 1.0);
        let mut scalar = c0.clone();
        gemm_with_kernel(ta, tb, m, n, k, alpha, &a, &b, beta, &mut scalar, 1, KernelKind::Scalar);
        let mut simd = c0.clone();
        gemm_with_kernel(ta, tb, m, n, k, alpha, &a, &b, beta, &mut simd, 1, KernelKind::Simd);
        for (i, (x, y)) in simd.iter().zip(&scalar).enumerate() {
            prop_assert(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                &format!(
                    "idx={i}: simd {x} vs scalar {y} \
                     (m={m} n={n} k={k} alpha={alpha} beta={beta} ta={ta:?} tb={tb:?})"
                ),
            )?;
        }
        for &t in &[2usize, 4, 7] {
            let mut par = c0.clone();
            gemm_with_kernel(ta, tb, m, n, k, alpha, &a, &b, beta, &mut par, t, KernelKind::Simd);
            prop_assert(
                par == simd,
                &format!("simd threads={t} differs from simd serial (m={m} n={n} k={k})"),
            )?;
        }
        Ok(())
    });
}

/// The simd conv transforms reorder no arithmetic, so — unlike the gemm
/// microkernel — they must reproduce the scalar path bit-for-bit on random
/// geometries, at every task count. Runs everywhere: off AVX2+FMA the span
/// kernels degrade to scalar lanes and the property still holds.
#[test]
fn simd_conv_transforms_bit_identical_to_scalar_for_random_geometries() {
    use singa::tensor::conv::{col2im_acc_with_kernel, im2col_with_kernel};
    use singa::tensor::KernelKind;
    forall(40, |q| {
        let c = q.usize(1, 5);
        let h = q.usize(1, 12);
        let w = q.usize(1, 12);
        let pad = q.usize(0, 2);
        let kmax = (h.min(w) + 2 * pad).min(5);
        let k = q.usize(1, kmax.max(1));
        let stride = q.usize(1, 3);
        let g = Conv2dGeom { in_c: c, in_h: h, in_w: w, kernel: k, stride, pad };
        let n = g.col_rows() * g.col_cols();

        let img = q.f32_vec(c * h * w, -1.0, 1.0);
        let mut col_scalar = vec![0.0f32; n];
        im2col_with_kernel(&img, &g, &mut col_scalar, 1, KernelKind::Scalar);
        let colm = q.f32_vec(n, -1.0, 1.0);
        let img0 = q.f32_vec(c * h * w, -1.0, 1.0);
        let mut acc_scalar = img0.clone();
        col2im_acc_with_kernel(&colm, &g, &mut acc_scalar, 1, KernelKind::Scalar);

        for &t in &[1usize, 2, 4, 7] {
            let mut col_v = vec![0.0f32; n];
            im2col_with_kernel(&img, &g, &mut col_v, t, KernelKind::Simd);
            prop_assert(
                col_v == col_scalar,
                &format!("simd im2col t={t} differs (c={c} h={h} w={w} k={k} s={stride} p={pad})"),
            )?;
            let mut acc_v = img0.clone();
            col2im_acc_with_kernel(&colm, &g, &mut acc_v, t, KernelKind::Simd);
            prop_assert(
                acc_v == acc_scalar,
                &format!(
                    "simd col2im_acc t={t} differs (c={c} h={h} w={w} k={k} s={stride} p={pad})"
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn logical_names_strip_only_batch_replicas() {
    forall(100, |g| {
        let base = format!("layer{}", g.usize(0, 9));
        let i = g.usize(0, 7);
        let b0 = format!("{base}#b{i}/weight");
        let f0 = format!("{base}#f{i}/weight");
        prop_assert(
            logical_param_name(&b0) == format!("{base}/weight")
                && logical_param_name(&f0) == f0,
            "replica naming",
        )
    });
}
