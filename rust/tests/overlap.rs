//! Bit-identity suite for the overlapped parameter exchange: bucketed
//! gradient flush during backward + prefetch must train bit-for-bit
//! identically to the strictly sequential exchange — same per-step losses
//! and metrics, same final server replicas — across frameworks, with and
//! without intra-group partitioning, for BP and CD, at every
//! `PALLAS_NUM_THREADS` (CI runs this suite under `=1` and `=4`). The
//! shared-server lockstep variants (downpour(3,1,2), hogwild with syncs
//! mid-flush) live next to the exchange internals in
//! `coordinator::exchange::tests`.

use singa::cluster::ClusterTopology;
use singa::coordinator::workspace::ParamWorkspace;
use singa::coordinator::{run_job, Algorithm, JobConf, JobReport};
use singa::data::{DataSource, SyntheticDigits};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::NetBuilder;
use singa::tensor::Blob;
use singa::updater::UpdaterConf;
use singa::utils::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

fn mlp(batch: usize, dim: usize, hidden: usize, classes: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: hidden, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
}

fn digits() -> Arc<dyn DataSource> {
    Arc::new(SyntheticDigits::new(64, 5, 77))
}

/// Compare two runs bit for bit: per-group (step, loss, metric) sequences
/// and every server group's final replica.
fn assert_reports_bitwise_equal(groups: usize, a: &JobReport, b: &JobReport) {
    let (ra, rb) = (a.log.snapshot(), b.log.snapshot());
    for g in 0..groups {
        let ga: Vec<_> = ra.iter().filter(|r| r.group == g).collect();
        let gb: Vec<_> = rb.iter().filter(|r| r.group == g).collect();
        assert_eq!(ga.len(), gb.len(), "group {g} record count");
        for (x, y) in ga.iter().zip(&gb) {
            assert_eq!(x.step, y.step, "group {g}");
            assert_eq!(
                x.loss.to_bits(),
                y.loss.to_bits(),
                "group {g} step {}: loss {} vs {}",
                x.step,
                x.loss,
                y.loss
            );
            assert_eq!(
                x.metric.to_bits(),
                y.metric.to_bits(),
                "group {g} step {}: metric diverged",
                x.step
            );
        }
    }
    assert_eq!(a.group_params.len(), b.group_params.len());
    for (sg, (pa, pb)) in a.group_params.iter().zip(&b.group_params).enumerate() {
        assert_eq!(pa.len(), pb.len(), "server group {sg}");
        for (name, va) in pa {
            let vb = pb.get(name).unwrap_or_else(|| panic!("missing param {name}"));
            assert_eq!(va.shape(), vb.shape(), "{name}");
            for (x, y) in va.data().iter().zip(vb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "server group {sg} param {name}");
            }
        }
    }
}

fn run_with(conf: &JobConf, overlap: bool, data: Arc<dyn DataSource>) -> JobReport {
    let mut conf = conf.clone();
    conf.overlap_exchange = overlap;
    run_job(&conf, data)
}

/// Sandblaster(1,1): the synchronous baseline, full-job bitwise, with the
/// distributed alloc probe armed in both modes.
#[test]
fn sandblaster_overlap_matches_sequential_bitwise() {
    let mut conf = JobConf::new("ovl-sand", mlp(16, 64, 32, 5));
    conf.iters = 15;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.alloc_probe_from = Some(3);
    let seq = run_with(&conf, false, digits());
    let ovl = run_with(&conf, true, digits());
    assert_reports_bitwise_equal(1, &seq, &ovl);
    assert_eq!(seq.steady_allocs, vec![0], "sequential steady state must not allocate");
    assert_eq!(ovl.steady_allocs, vec![0], "overlapped steady state must not allocate");
}

/// A net whose layers share logical params across partitions (dim-0
/// sub-layer replicas): bucket completion must wait for EVERY replica's
/// backward, and the replica aggregation order must match the sequential
/// recipe bit for bit.
#[test]
fn partitioned_replicas_overlap_matches_sequential_bitwise() {
    let mut b = mlp(16, 64, 32, 5);
    for c in b.confs_mut().iter_mut() {
        if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
            c.partition_dim = Some(0);
        }
    }
    let mut conf = JobConf::new("ovl-part", b);
    conf.iters = 12;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.topology = ClusterTopology::sandblaster(2, 1);
    conf.partition_within_group = true;
    conf.alloc_probe_from = Some(3);
    let seq = run_with(&conf, false, digits());
    let ovl = run_with(&conf, true, digits());
    assert_reports_bitwise_equal(1, &seq, &ovl);
    assert_eq!(ovl.steady_allocs, vec![0]);
}

/// Hogwild(2,1,10) over 10 iters: two free-running groups with their own
/// server groups (no sync fires before step 10), so each group's full
/// trajectory is deterministic and comparable bitwise.
#[test]
fn hogwild_overlap_matches_sequential_bitwise() {
    let mut conf = JobConf::new("ovl-hog", mlp(8, 64, 16, 5));
    conf.iters = 10;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.topology = ClusterTopology::hogwild(2, 1, 10);
    conf.alloc_probe_from = Some(3);
    let seq = run_with(&conf, false, digits());
    let ovl = run_with(&conf, true, digits());
    assert_reports_bitwise_equal(2, &seq, &ovl);
    assert_eq!(ovl.steady_allocs, vec![0, 0]);
}

/// Coalescing everything into ONE bucket degenerates overlap to a single
/// post-backward flush — still bit-identical, still allocation-free.
#[test]
fn single_bucket_overlap_degenerates_to_sequential() {
    let builder = mlp(16, 64, 32, 5);
    {
        let net = builder.clone().build(&mut Rng::new(1));
        assert_eq!(ParamWorkspace::new(&net, usize::MAX, singa::comm::Codec::Raw).nbuckets(), 1);
        // Threshold 0: one bucket per param-bearing layer (h1, logits).
        assert_eq!(ParamWorkspace::new(&net, 0, singa::comm::Codec::Raw).nbuckets(), 2);
    }
    let mut conf = JobConf::new("ovl-one", builder);
    conf.iters = 12;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.bucket_coalesce_bytes = usize::MAX;
    conf.alloc_probe_from = Some(3);
    let seq = run_with(&conf, false, digits());
    let ovl = run_with(&conf, true, digits());
    assert_reports_bitwise_equal(1, &seq, &ovl);
    assert_eq!(ovl.steady_allocs, vec![0]);
}

/// The CD algorithm under the overlapped exchange: completion hooks fire
/// in forward order from the CD driver; trajectories must still match the
/// sequential exchange bit for bit.
#[test]
fn cd_overlap_matches_sequential_bitwise() {
    let b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 64] }, &[]))
        .add(LayerConf::new("rbm1", LayerKind::Rbm { hidden: 24, init_std: 0.1 }, &["data"]))
        .add(LayerConf::new("rbm2", LayerKind::Rbm { hidden: 8, init_std: 0.1 }, &["rbm1"]));
    let mut conf = JobConf::new("ovl-cd", b);
    conf.iters = 10;
    conf.algorithm = Algorithm::Cd { k: 1, stage: None };
    conf.updater = UpdaterConf::sgd(0.05);
    let seq = run_with(&conf, false, digits());
    let ovl = run_with(&conf, true, digits());
    assert_reports_bitwise_equal(1, &seq, &ovl);
}

/// L2 distance between two server replicas, summed over shared params.
fn replica_distance(a: &HashMap<String, Blob>, b: &HashMap<String, Blob>) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dist = 0.0f64;
    for (name, va) in a {
        let vb = b.get(name).unwrap_or_else(|| panic!("replica missing {name}"));
        dist += va
            .data()
            .iter()
            .zip(vb.data())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>();
    }
    dist.sqrt()
}

/// `group_sync_interval` firing mid-flush: with a 3-step interval the sync
/// request arrives while the overlapped channel may still hold that
/// step's flushes. The drain-before-sync contract must keep the job
/// deadlock-free and the neighbour averaging effective (synced replicas
/// end closer than unsynced ones). The bitwise pin for this schedule
/// lives in the lockstep harness (`coordinator::exchange::tests`).
#[test]
fn group_sync_mid_flush_completes_and_averages() {
    let run = |interval: u64| {
        let mut conf = JobConf::new("ovl-sync", mlp(8, 64, 16, 5));
        conf.iters = 9;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::hogwild(2, 1, interval);
        conf.overlap_exchange = true;
        run_job(&conf, digits())
    };
    let synced = run(3); // syncs at steps 3 and 6, mid-flush
    let recs = synced.log.snapshot();
    for g in 0..2 {
        assert_eq!(
            recs.iter().filter(|r| r.group == g).count(),
            9,
            "group {g} must complete all steps"
        );
    }
    let unsynced = run(0);
    let d_synced = replica_distance(&synced.group_params[0], &synced.group_params[1]);
    let d_unsynced = replica_distance(&unsynced.group_params[0], &unsynced.group_params[1]);
    assert!(
        d_synced < d_unsynced,
        "mid-flush syncs must still pull replicas together: {d_synced} vs {d_unsynced}"
    );
}
