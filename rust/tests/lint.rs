//! Harness entry for `pallas_lint`: the repo's own sources must pass the
//! static analyzer with zero unwaived findings. Runs the compiled binary
//! (built as part of `cargo test`) against `rust/src` so CI and local test
//! runs both enforce the invariants without a separate step.

use std::path::Path;
use std::process::Command;

#[test]
fn pallas_lint_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .arg(&src)
        .output()
        .expect("run pallas_lint");
    assert!(
        out.status.success(),
        "pallas_lint reported findings:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}
