//! Lock-sanitizer integration tests.
//!
//! Two phases in one test body (the sanitizer mode override is
//! process-global, so the phases must run sequentially):
//!
//! 1. A seeded rank inversion on two public `OrderedMutex` handles is caught
//!    and the panic names both acquisition sites.
//! 2. The real concurrent machinery — opposing pairwise `sync_with` replica
//!    syncs, worker-style nested bucket→route→shard updates, mid-flush
//!    checkpoint exports, and a threaded GEMM over the shared pool — runs
//!    clean under `Stress` mode (deterministic injected yields widen race
//!    windows) with a 4-wide pool.

use std::sync::Arc;

use singa::comm::ByteLedger;
use singa::coordinator::checkpointer::Checkpointer;
use singa::coordinator::CheckpointConf;
use singa::runtime::sync::{self, Mode, OrderedMutex, RANK_SERVER_ROUTE, RANK_WORKSPACE_BUCKET};
use singa::server::ServerGroup;
use singa::tensor::{gemm_with_threads, Blob, Transpose};
use singa::updater::UpdaterConf;

/// Restores the default (env-driven) sanitizer mode even if the test panics.
struct RestoreMode;
impl Drop for RestoreMode {
    fn drop(&mut self) {
        sync::override_mode_for_tests(None);
    }
}

fn new_group(vals: &[(&str, f32)]) -> ServerGroup {
    let g = ServerGroup::new(2, UpdaterConf::sgd(0.05), Arc::new(ByteLedger::new()));
    for &(name, v) in vals {
        g.put(name, Blob::full(&[32], v), 1.0, 1.0);
    }
    g
}

#[test]
fn sanitizer_catches_inversions_and_suites_run_clean_under_stress() {
    // Pin the pool width before anything touches the shared compute pool.
    std::env::set_var("PALLAS_NUM_THREADS", "4");
    let _restore = RestoreMode;

    // ---- Phase 1: a rank inversion is caught, naming both sites. ----
    sync::override_mode_for_tests(Some(Mode::On));
    let low = OrderedMutex::new(RANK_WORKSPACE_BUCKET, "it.rank.low", ());
    let high = OrderedMutex::new(RANK_SERVER_ROUTE, "it.rank.high", ());
    let msg = std::thread::scope(|s| {
        let h = s.spawn(|| {
            let _hi = high.lock().unwrap();
            // Inversion: rank 10 acquired while rank 20 is held.
            let _lo = low.lock().unwrap();
        });
        let payload = h.join().expect_err("rank inversion must panic");
        payload.downcast::<String>().map(|b| *b).unwrap_or_default()
    });
    assert!(
        msg.contains("it.rank.high") && msg.contains("it.rank.low"),
        "sanitizer panic must name both sites, got: {msg:?}"
    );
    assert!(
        msg.contains("rank 10") && msg.contains("rank 20"),
        "sanitizer panic must name both ranks, got: {msg:?}"
    );

    // ---- Phase 2: the real suites stay clean under stress scheduling. ----
    sync::override_mode_for_tests(Some(Mode::Stress { seed: 7 }));

    let servers = Arc::new(vec![
        new_group(&[("w0", 1.0), ("w1", 2.0), ("w2", 3.0)]),
        new_group(&[("w0", 3.0), ("w1", 2.0), ("w2", 1.0)]),
    ]);
    let ck = Checkpointer::spawn(CheckpointConf::every(1), servers.clone(), "sanitize");
    let a = &servers[0];
    let b = &servers[1];

    std::thread::scope(|s| {
        // Opposing pairwise syncs: shard locks are keyed by (group, shard),
        // so both directions take them in one global order and serialize
        // instead of deadlocking.
        s.spawn(|| {
            for _ in 0..50 {
                a.sync_with(b);
            }
        });
        s.spawn(|| {
            for _ in 0..50 {
                b.sync_with(a);
            }
        });
        // Worker-style updates nested under a bucket-ranked lock — the same
        // bucket -> route -> shard chain the flush path exercises.
        s.spawn(|| {
            let bucket = OrderedMutex::new(RANK_WORKSPACE_BUCKET, "it.sanitize.bucket", ());
            let grad = Blob::full(&[32], 0.1);
            let mut out = Blob::zeros(&[32]);
            for step in 0..60u64 {
                let _held = bucket.lock().unwrap();
                a.update_into("w1", &grad, step, &mut out);
            }
        });
        // Mid-flush checkpoint exports racing the syncs and updates above.
        s.spawn(|| {
            for step in 0..30u64 {
                ck.request(step);
                ck.wait_exported();
            }
        });
        // Pool dispatch + stripe locks under stress via a threaded GEMM.
        s.spawn(|| {
            let (m, n, k) = (64usize, 48usize, 32usize);
            let av = vec![0.5f32; m * k];
            let bv = vec![0.25f32; k * n];
            for _ in 0..6 {
                let mut c = vec![1.0f32; m * n];
                gemm_with_threads(
                    Transpose::No,
                    Transpose::No,
                    m,
                    n,
                    k,
                    1.0,
                    &av,
                    &bv,
                    0.0,
                    &mut c,
                    4,
                );
                for x in &c {
                    assert!((x - 4.0).abs() < 1e-3, "gemm element off: {x}");
                }
            }
        });
    });

    let done = ck.shutdown();
    assert!(done >= 30, "checkpointer completed {done} snapshots, wanted >= 30");
    let latest = ck.latest_blocking().expect("a snapshot must have landed");
    assert!(latest.1.tensors.contains_key("w0"));
    for name in ["w0", "w1", "w2"] {
        let (value, _version) = a.get(name);
        assert!(
            value.data().iter().all(|x| x.is_finite()),
            "param {name} corrupted under stress"
        );
    }
}
