//! Fault-tolerance acceptance suite: deterministic kills, checkpoint
//! restarts, straggler/backup accounting, and panic isolation, all on the
//! simnet clock. The key pins:
//!
//! - a downpour group killed mid-run rejoins the live servers and the job
//!   still converges to the fault-free band;
//! - a sole-tenant group killed after a checkpoint boundary restores that
//!   boundary and replays to a final state bit-identical to an
//!   uninterrupted run (and cold-restarts bit-identically when nothing was
//!   ever checkpointed);
//! - backup workers hide scheduled stragglers from the virtual clock while
//!   training values stay bitwise unchanged (duplicate-flush-discard);
//! - a worker panic is a per-group failure in the report, not a job abort;
//! - checkpointing keeps the distributed steady state allocation-free.
//!
//! CI runs this suite under `PALLAS_NUM_THREADS=1` and `=4`.

use singa::cluster::ClusterTopology;
use singa::comm::{Codec, FaultPlan};
use singa::coordinator::{run_job, CheckpointConf, JobConf, JobReport};
use singa::data::{DataSource, SyntheticDigits};
use singa::model::checkpoint::Checkpoint;
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::NetBuilder;
use singa::tensor::Blob;
use singa::updater::UpdaterConf;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

fn mlp(batch: usize, dim: usize, hidden: usize, classes: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: hidden, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
}

fn digits() -> Arc<dyn DataSource> {
    Arc::new(SyntheticDigits::new(64, 5, 77))
}

/// The last logged (loss, metric) bits per step for one group. A recovered
/// run logs a killed step range twice — once before the kill, once on
/// replay — and the replay is the trajectory that must match the
/// uninterrupted run, so comparisons take the LAST record per step.
fn last_per_step(report: &JobReport, group: usize) -> BTreeMap<u64, (u32, u32)> {
    let mut m = BTreeMap::new();
    for r in report.log.snapshot() {
        if r.group == group {
            m.insert(r.step, (r.loss.to_bits(), r.metric.to_bits()));
        }
    }
    m
}

fn assert_params_bitwise_equal(a: &HashMap<String, Blob>, b: &HashMap<String, Blob>) {
    assert_eq!(a.len(), b.len(), "param count");
    for (name, va) in a {
        let vb = b.get(name).unwrap_or_else(|| panic!("missing param {name}"));
        assert_eq!(va.shape(), vb.shape(), "{name}");
        for (x, y) in va.data().iter().zip(vb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged");
        }
    }
}

fn healthy(report: &JobReport) {
    for (g, f) in report.group_failures.iter().enumerate() {
        assert!(f.is_none(), "group {g} failed: {f:?}");
    }
}

/// Downpour(3,1,2): group 1 dies mid-run. Its server group is shared, so
/// the healthy groups' progress survives and the restarted group rejoins
/// the live state at its kill step — and the job still converges to the
/// fault-free loss band.
#[test]
fn downpour_midrun_kill_converges_to_fault_free_band() {
    let run = |faults: FaultPlan| {
        let mut conf = JobConf::new("fault-downpour", mlp(16, 64, 32, 5));
        conf.iters = 80;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(3, 1, 2);
        conf.faults = faults;
        run_job(&conf, digits())
    };
    let free = run(FaultPlan::none());
    let faulted = run(FaultPlan::none().kill(1, 25).with_restart_latency_us(500_000.0));
    healthy(&free);
    healthy(&faulted);

    assert!(free.fault_events.is_empty());
    assert_eq!(faulted.fault_events.len(), 1, "exactly one recovered kill");
    let ev = &faulted.fault_events[0];
    assert_eq!(ev.group, 1);
    assert_eq!(ev.killed_at_step, 25);
    assert_eq!(ev.resumed_at_step, 25, "shared servers → live rejoin at the kill step");
    assert_eq!(ev.restored_from, None, "live rejoin restores no checkpoint");
    assert!(ev.recovery_virt_ms >= 500.0, "restart latency on the clock: {}", ev.recovery_virt_ms);

    // The killed group completes every step exactly once (rejoin replays
    // nothing), and recovery shows up on its virtual clock.
    let steps: Vec<u64> = last_per_step(&faulted, 1).keys().copied().collect();
    assert_eq!(steps, (0..80).collect::<Vec<_>>(), "group 1 completes its shard stream");
    assert!(
        faulted.group_virt_ms[1] > free.group_virt_ms[1],
        "recovery must cost virtual time: {} vs {}",
        faulted.group_virt_ms[1],
        free.group_virt_ms[1]
    );

    // Fault-free band: async interleaving is nondeterministic, so compare
    // converged quality, not trajectories.
    let final_metric = |r: &JobReport| {
        (0..3)
            .map(|g| f32::from_bits(last_per_step(r, g).values().last().unwrap().1))
            .fold(0.0f32, f32::max)
    };
    let (mf, mk) = (final_metric(&free), final_metric(&faulted));
    assert!(mf > 0.7, "fault-free run must converge: {mf}");
    assert!(mk > 0.7, "killed run must converge: {mk}");
    assert!((mf - mk).abs() < 0.25, "kill left the loss band: {mf} vs {mk}");
}

/// Sandblaster(1,1) with checkpointing every 8 steps, killed at step 20:
/// recovery restores the step-16 boundary and replays 16..28. The replayed
/// trajectory and the final params must be bit-identical to an
/// uninterrupted run, the durable `.ckpt` files must land and load, and
/// the fault record must name the restored boundary.
#[test]
fn restart_from_checkpoint_is_bitwise_identical() {
    let dir = std::env::temp_dir().join(format!("singa_faults_restart_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut conf = JobConf::new("fault-restart", mlp(16, 64, 32, 5));
    conf.iters = 28;
    conf.updater = UpdaterConf::sgd(0.2);

    let baseline = run_job(&conf, digits());

    conf.checkpoint = Some(CheckpointConf::every(8).with_dir(&dir));
    conf.faults = FaultPlan::none().kill(0, 20).with_restart_latency_us(500_000.0);
    let recovered = run_job(&conf, digits());
    healthy(&baseline);
    healthy(&recovered);

    assert_eq!(recovered.fault_events.len(), 1);
    let ev = &recovered.fault_events[0];
    assert_eq!(ev.killed_at_step, 20);
    assert_eq!(ev.resumed_at_step, 16, "latest boundary before the kill");
    assert_eq!(ev.restored_from, Some(16));
    // Boundaries 8 and 16 before the kill, 24 on replay.
    assert_eq!(recovered.checkpoints, 3);

    // Steps 16..20 ran twice — pre-kill and replayed — and the replay must
    // retrace the uninterrupted trajectory bit for bit.
    let recs = recovered.log.snapshot();
    for step in 16..20u64 {
        assert_eq!(
            recs.iter().filter(|r| r.step == step).count(),
            2,
            "step {step} must be replayed after the restore"
        );
    }
    let (a, b) = (last_per_step(&baseline, 0), last_per_step(&recovered, 0));
    assert_eq!(a.keys().collect::<Vec<_>>(), b.keys().collect::<Vec<_>>());
    for (step, bits) in &a {
        assert_eq!(bits, &b[step], "step {step} diverged after restart");
    }
    assert_params_bitwise_equal(&baseline.params, &recovered.params);

    // Durable snapshots: one loadable file per boundary, no temp litter.
    for step in [8u64, 16, 24] {
        let path = dir.join(format!("fault-restart.step{step}.ckpt"));
        let loaded = Checkpoint::load(&path)
            .unwrap_or_else(|e| panic!("{} must load: {e}", path.display()));
        assert_eq!(loaded.tensors.len(), baseline.params.len());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A kill before the first checkpoint boundary — or with checkpointing
/// disabled entirely — cold-restarts from the seed params and replays the
/// whole shard stream, which must also be bit-identical to an
/// uninterrupted run (same seed, same stream).
#[test]
fn cold_restart_without_checkpoint_replays_bitwise() {
    let mut conf = JobConf::new("fault-cold", mlp(16, 64, 32, 5));
    conf.iters = 12;
    conf.updater = UpdaterConf::sgd(0.2);

    let baseline = run_job(&conf, digits());

    conf.faults = FaultPlan::none().kill(0, 5).with_restart_latency_us(100_000.0);
    let recovered = run_job(&conf, digits());
    healthy(&recovered);

    assert_eq!(recovered.fault_events.len(), 1);
    let ev = &recovered.fault_events[0];
    assert_eq!(ev.killed_at_step, 5);
    assert_eq!(ev.resumed_at_step, 0, "no checkpoint → replay from the seed");
    assert_eq!(ev.restored_from, None);
    assert_eq!(recovered.checkpoints, 0);

    let (a, b) = (last_per_step(&baseline, 0), last_per_step(&recovered, 0));
    assert_eq!(a, b, "cold-restarted trajectory diverged");
    assert_params_bitwise_equal(&baseline.params, &recovered.params);
}

/// The schedule edge: a kill at step 0 (before any work at all) must still
/// recover — the fired-kill ledger keeps the replayed step 0 alive.
#[test]
fn kill_at_step_zero_recovers() {
    let mut conf = JobConf::new("fault-zero", mlp(8, 64, 16, 5));
    conf.iters = 6;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.faults = FaultPlan::none().kill(0, 0).with_restart_latency_us(100_000.0);
    let report = run_job(&conf, digits());
    healthy(&report);
    assert_eq!(report.fault_events.len(), 1);
    assert_eq!(report.fault_events[0].killed_at_step, 0);
    assert_eq!(report.fault_events[0].resumed_at_step, 0);
    let steps: Vec<u64> = last_per_step(&report, 0).keys().copied().collect();
    assert_eq!(steps, (0..6).collect::<Vec<_>>());
}

/// Codec × fault interaction: a downpour group killed mid-run under
/// `Codec::Int8` (quantized flushes with error feedback) still converges
/// to the int8 fault-free loss band. The restarted group's residuals reset
/// to zero — exactly what a real rejoining worker would do — so the pin is
/// the convergence band, not bitwise equality.
#[test]
fn int8_midrun_kill_converges_to_fault_free_band() {
    let run = |faults: FaultPlan| {
        let mut conf = JobConf::new("fault-int8", mlp(16, 64, 32, 5));
        conf.iters = 80;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(3, 1, 2);
        conf.wire_codec = Codec::Int8;
        conf.faults = faults;
        run_job(&conf, digits())
    };
    let free = run(FaultPlan::none());
    let faulted = run(FaultPlan::none().kill(1, 25).with_restart_latency_us(500_000.0));
    healthy(&free);
    healthy(&faulted);
    assert_eq!(faulted.fault_events.len(), 1, "exactly one recovered kill");

    let final_metric = |r: &JobReport| {
        (0..3)
            .map(|g| f32::from_bits(last_per_step(r, g).values().last().unwrap().1))
            .fold(0.0f32, f32::max)
    };
    let (mf, mk) = (final_metric(&free), final_metric(&faulted));
    assert!(mf > 0.7, "int8 fault-free run must converge: {mf}");
    assert!(mk > 0.7, "int8 killed run must converge: {mk}");
    assert!((mf - mk).abs() < 0.25, "kill left the int8 loss band: {mf} vs {mk}");
}

/// Codec × checkpoint restart: under an *explicit* `Codec::Raw` the
/// kill-restore-replay path stays bit-identical to the uninterrupted run —
/// the codec knob at its default must not perturb the PR 7 recovery
/// contract.
#[test]
fn raw_codec_restart_from_checkpoint_stays_bitwise() {
    let dir = std::env::temp_dir().join(format!("singa_faults_raw_codec_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut conf = JobConf::new("fault-raw-codec", mlp(16, 64, 32, 5));
    conf.iters = 28;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.wire_codec = Codec::Raw;

    let baseline = run_job(&conf, digits());

    conf.checkpoint = Some(CheckpointConf::every(8).with_dir(&dir));
    conf.faults = FaultPlan::none().kill(0, 20).with_restart_latency_us(500_000.0);
    let recovered = run_job(&conf, digits());
    healthy(&baseline);
    healthy(&recovered);

    let (a, b) = (last_per_step(&baseline, 0), last_per_step(&recovered, 0));
    assert_eq!(a.keys().collect::<Vec<_>>(), b.keys().collect::<Vec<_>>());
    for (step, bits) in &a {
        assert_eq!(bits, &b[step], "step {step} diverged after restart under raw codec");
    }
    assert_params_bitwise_equal(&baseline.params, &recovered.params);
    std::fs::remove_dir_all(&dir).ok();
}

/// Sandblaster straggler mitigation: a scheduled 50× straggler stretches
/// the virtual clock — unless backup workers absorb it, in which case the
/// clock stays at the healthy pace, the duplicate flush is charged to the
/// ledger and discarded, and the rescues are counted. Training values are
/// bitwise identical in all three runs (delays and backups only move the
/// clock and the ledger, never the math).
#[test]
fn backup_workers_hide_stragglers_without_perturbing_values() {
    let run = |faults: FaultPlan, backups: usize| {
        let mut conf = JobConf::new("fault-straggle", mlp(16, 64, 32, 5));
        conf.iters = 12;
        conf.updater = UpdaterConf::sgd(0.2);
        conf.faults = faults;
        conf.backup_workers = backups;
        run_job(&conf, digits())
    };
    let slow = FaultPlan::none().delay_range(0, 2, 10, 50.0);
    let base = run(FaultPlan::none(), 0);
    let straggler = run(slow.clone(), 0);
    let rescued = run(slow, 1);
    for r in [&base, &straggler, &rescued] {
        healthy(r);
        assert!(r.fault_events.is_empty(), "delays are not kills");
    }

    // Values: bitwise identical across all three runs.
    let a = last_per_step(&base, 0);
    assert_eq!(a, last_per_step(&straggler, 0), "straggler perturbed values");
    assert_eq!(a, last_per_step(&rescued, 0), "backup perturbed values");
    assert_params_bitwise_equal(&base.params, &straggler.params);
    assert_params_bitwise_equal(&base.params, &rescued.params);

    // Clock: the unmitigated straggler drags 8 steps by 50×; backups hide
    // it (the backup's copy of the slow shard wins at the healthy pace).
    assert!(
        straggler.group_virt_ms[0] > rescued.group_virt_ms[0],
        "backups must hide the straggler on the clock: {} vs {}",
        straggler.group_virt_ms[0],
        rescued.group_virt_ms[0]
    );
    assert_eq!(straggler.backup_rescues, 0);
    assert_eq!(rescued.backup_rescues, 8, "one rescue per delayed step");

    // Ledger: the discarded duplicate flushes are still paid for on the
    // wire.
    assert!(
        rescued.ledger.param_bytes() > base.ledger.param_bytes(),
        "duplicate flushes must be charged: {} vs {}",
        rescued.ledger.param_bytes(),
        base.ledger.param_bytes()
    );
}

/// A data source that fails for one group's shard partway through — an
/// *unscheduled* death, unlike the fault plan's recoverable kills.
struct OutageSource {
    inner: SyntheticDigits,
    groups: u64,
    dead_group: u64,
    from_step: u64,
}

impl DataSource for OutageSource {
    fn input_names(&self) -> Vec<String> {
        self.inner.input_names()
    }

    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob> {
        if index % self.groups == self.dead_group && index / self.groups >= self.from_step {
            panic!("synthetic data outage");
        }
        self.inner.batch(index, batch)
    }
}

/// An unscheduled worker panic surfaces as that group's entry in
/// `group_failures` — the healthy groups complete every step and the job
/// still delivers params, instead of aborting the process.
#[test]
fn worker_panic_is_a_group_failure_not_a_job_abort() {
    let mut conf = JobConf::new("fault-panic", mlp(16, 64, 32, 5));
    conf.iters = 10;
    conf.updater = UpdaterConf::sgd(0.1);
    conf.topology = ClusterTopology::downpour(3, 1, 1);
    let data = Arc::new(OutageSource {
        inner: SyntheticDigits::new(64, 5, 77),
        groups: 3,
        dead_group: 1,
        from_step: 5,
    });
    let report = run_job(&conf, data);

    assert_eq!(report.group_failures.len(), 3);
    assert!(report.group_failures[0].is_none());
    assert!(report.group_failures[2].is_none());
    let msg = report.group_failures[1].as_ref().expect("group 1 must be reported dead");
    assert!(msg.contains("synthetic data outage"), "panic message surfaced: {msg}");
    assert!(report.fault_events.is_empty(), "an unscheduled panic is not a recovered kill");

    for g in [0usize, 2] {
        let steps: Vec<u64> = last_per_step(&report, g).keys().copied().collect();
        assert_eq!(steps, (0..10).collect::<Vec<_>>(), "healthy group {g} completes");
    }
    assert!(last_per_step(&report, 1).len() < 10, "dead group stopped early");
    assert!(!report.params.is_empty(), "the job still delivers params");
    assert_eq!(report.group_virt_ms[1], 0.0, "failed group reports no clock");
}

/// The zero-alloc pin with the checkpoint plane armed: cadence requests are
/// one channel send and the export clones on the checkpointer thread, so
/// worker steady-state Blob allocations stay exactly zero.
#[test]
fn checkpointing_keeps_steady_state_allocation_free() {
    let mut conf = JobConf::new("fault-alloc", mlp(16, 64, 32, 5));
    conf.iters = 12;
    conf.updater = UpdaterConf::sgd(0.2);
    conf.checkpoint = Some(CheckpointConf::every(4));
    conf.alloc_probe_from = Some(3);
    let report = run_job(&conf, digits());
    healthy(&report);
    assert_eq!(report.steady_allocs, vec![0], "checkpointing must stay off the hot path");
    assert_eq!(report.checkpoints, 3);
}
