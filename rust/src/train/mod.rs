//! `TrainOneBatch` algorithms (paper §4.1.3): one per model category.
//!
//! * [`bp`] — Back-Propagation for feed-forward nets (Algorithm 1), which
//!   also drives recurrent nets whose layers unroll internally (BPTT,
//!   paper Fig 5b / §4.2.3).
//! * [`cd`] — Contrastive Divergence for undirected models (RBM).
//!
//! Each algorithm determines the order in which `ComputeFeature` and
//! `ComputeGradient` are invoked across the `NeuralNet`. Users with bespoke
//! workflows implement [`TrainOneBatch`] themselves (the paper's template).

pub mod bp;
pub mod cd;

use crate::model::{NeuralNet, Phase};
use crate::tensor::Blob;
use std::collections::HashMap;

pub use crate::model::net::{GradObserver, NoopObserver};

/// Result of one training iteration.
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// `(loss layer name, loss, metric)` per loss layer.
    pub losses: Vec<(String, f32, f32)>,
}

impl StepStats {
    pub fn total_loss(&self) -> f32 {
        self.losses.iter().map(|(_, l, _)| l).sum()
    }

    /// Mean metric (accuracy) over loss layers that report one.
    pub fn metric(&self) -> f32 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.losses.iter().map(|(_, _, m)| m).sum::<f32>() / self.losses.len() as f32
    }
}

/// The algorithm template from the paper: given the net and this iteration's
/// named input blobs, run one gradient-computation pass. Gradients are left
/// in `Param::grad`; the caller (worker) ships them to the servers.
pub trait TrainOneBatch: Send {
    fn train_one_batch(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
    ) -> StepStats;

    /// [`TrainOneBatch::train_one_batch`] with gradient-completion hooks:
    /// `obs.grads_ready(net, i)` fires once per node the moment that node's
    /// parameter gradients are final — for BP, in reverse-topological order
    /// as each `ComputeGradient` returns (paper §5: a layer's gradients are
    /// transferred as soon as they are computed, overlapping the exchange
    /// with the remaining backward pass). The default runs the plain
    /// algorithm and fires every node afterwards in reverse order: always
    /// correct for custom algorithms, but it gives the observer no overlap
    /// window — drivers on the hot path override it.
    fn train_one_batch_observed(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
        obs: &mut dyn GradObserver,
    ) -> StepStats {
        let stats = self.train_one_batch(net, inputs);
        for i in (0..net.len()).rev() {
            obs.grads_ready(net, i);
        }
        stats
    }

    /// Algorithm name for logs/configs.
    fn name(&self) -> &'static str;
}

/// Evaluation pass (no gradients).
pub fn evaluate(net: &mut NeuralNet, inputs: &HashMap<String, Blob>) -> StepStats {
    for (name, blob) in inputs {
        net.try_set_input_ref(name, blob);
    }
    net.forward(Phase::Test);
    StepStats { losses: net.losses() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_stats_aggregation() {
        let s = StepStats {
            losses: vec![("a".into(), 1.0, 0.5), ("b".into(), 2.0, 0.7)],
        };
        assert_eq!(s.total_loss(), 3.0);
        assert!((s.metric() - 0.6).abs() < 1e-6);
        assert_eq!(StepStats::default().metric(), 0.0);
    }
}
