//! Contrastive-Divergence `TrainOneBatch` for undirected models (paper
//! §4.1.3). Drives every [`RbmLayer`] in the net through a CD-k step on the
//! feature produced by its source layers — the layer-by-layer greedy
//! pre-training scheme of Hinton & Salakhutdinov used by the deep
//! auto-encoder application (paper §4.2.2, Fig 8).

use super::{GradObserver, NoopObserver, StepStats, TrainOneBatch};
use crate::model::rbm::RbmLayer;
use crate::model::{NeuralNet, Phase};
use crate::tensor::Blob;
use std::collections::HashMap;

/// CD-k driver. `train_upto` limits which RBM (by name) is being trained in
/// the current greedy stage; earlier RBMs only propagate features.
pub struct Cd {
    pub k: usize,
    /// Name of the RBM currently being trained; `None` trains every RBM.
    pub train_only: Option<String>,
}

impl Cd {
    pub fn new(k: usize) -> Cd {
        Cd { k, train_only: None }
    }

    pub fn stage(k: usize, layer: &str) -> Cd {
        Cd { k, train_only: Some(layer.to_string()) }
    }
}

impl TrainOneBatch for Cd {
    fn train_one_batch(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
    ) -> StepStats {
        self.train_one_batch_observed(net, inputs, &mut NoopObserver)
    }

    /// CD's completion order is the forward node order: each RBM's param
    /// gradients are final right after its `cd_step`, so its hook fires
    /// there (stage-filtered RBMs and non-RBM nodes fire with their grads
    /// still zero — final for this step by definition), letting the
    /// overlapped exchange flush each RBM while later RBMs keep sampling.
    fn train_one_batch_observed(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
        obs: &mut dyn GradObserver,
    ) -> StepStats {
        for (name, blob) in inputs {
            net.try_set_input_ref(name, blob);
        }
        // Positive-phase forward to materialize features up to each RBM.
        net.forward(Phase::Train);
        let mut losses = Vec::new();
        // For each RBM layer, run CD-k with its source feature as v0 —
        // read straight from the workspace, no clone.
        for i in 0..net.len() {
            {
                let (nodes, ws) = net.split_mut();
                let node = &mut nodes[i];
                if node.layer.type_name() == "Rbm" && !node.srcs.is_empty() {
                    let name = node.layer.name().to_string();
                    let in_stage =
                        self.train_only.as_ref().map_or(true, |only| only == &name);
                    if in_stage {
                        let v0 = ws.feature(node.srcs[0]);
                        let rbm = node
                            .layer
                            .as_any()
                            .downcast_mut::<RbmLayer>()
                            .expect("type_name Rbm but downcast failed");
                        let err = rbm.cd_step(v0, self.k);
                        losses.push((name, err, 0.0));
                    }
                }
            }
            obs.grads_ready(net, i);
        }
        StepStats { losses }
    }

    fn name(&self) -> &'static str {
        "CD"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerConf, LayerKind};
    use crate::model::NetBuilder;
    use crate::utils::rng::Rng;

    fn rbm_net(batch: usize, visible: usize, h1: usize, h2: usize) -> NeuralNet {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, visible] }, &[]))
            .add(LayerConf::new("rbm1", LayerKind::Rbm { hidden: h1, init_std: 0.1 }, &["data"]))
            .add(LayerConf::new("rbm2", LayerKind::Rbm { hidden: h2, init_std: 0.1 }, &["rbm1"]))
            .build(&mut Rng::new(17))
    }

    fn batch_patterns(rng: &mut Rng, batch: usize) -> Blob {
        // Stripe patterns over 8 visible units.
        let protos = [[1., 1., 1., 1., 0., 0., 0., 0.], [0., 0., 0., 0., 1., 1., 1., 1.]];
        let mut data = Vec::new();
        for _ in 0..batch {
            let p = &protos[rng.below(2)];
            for &v in p {
                data.push(if rng.uniform() < 0.05 { 1.0 - v } else { v });
            }
        }
        Blob::from_vec(&[batch, 8], data)
    }

    #[test]
    fn cd_trains_stacked_rbms_greedily() {
        let mut net = rbm_net(16, 8, 12, 6);
        let mut rng = Rng::new(3);

        // Stage 1: train rbm1 only.
        let mut alg = Cd::stage(1, "rbm1");
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..200 {
            let mut inputs = HashMap::new();
            inputs.insert("data".to_string(), batch_patterns(&mut rng, 16));
            net.zero_grads();
            let stats = alg.train_one_batch(&mut net, &inputs);
            assert_eq!(stats.losses.len(), 1);
            assert_eq!(stats.losses[0].0, "rbm1");
            for p in net.params_mut() {
                p.sgd_step(0.1);
            }
            if it == 0 {
                first = stats.total_loss();
            }
            last = stats.total_loss();
        }
        assert!(last < first * 0.6, "stage-1 reconstruction: first {first} last {last}");

        // Stage 2: train rbm2 on rbm1 features.
        let mut alg2 = Cd::stage(1, "rbm2");
        let mut first2 = 0.0;
        let mut last2 = 0.0;
        for it in 0..200 {
            let mut inputs = HashMap::new();
            inputs.insert("data".to_string(), batch_patterns(&mut rng, 16));
            net.zero_grads();
            let stats = alg2.train_one_batch(&mut net, &inputs);
            assert_eq!(stats.losses[0].0, "rbm2");
            for p in net.params_mut() {
                p.sgd_step(0.1);
            }
            if it == 0 {
                first2 = stats.total_loss();
            }
            last2 = stats.total_loss();
        }
        assert!(last2 < first2, "stage-2 reconstruction should improve");
    }

    /// End-to-end CD training step (input ref + forward + CD-k + SGD) is
    /// blob-allocation-free at steady state, matching the BP path's
    /// planned-executor contract (ROADMAP "zero-alloc CD path").
    #[test]
    fn cd_train_one_batch_is_allocation_free_after_warmup() {
        let mut net = rbm_net(16, 8, 12, 6);
        let mut rng = Rng::new(8);
        let mut inputs = HashMap::new();
        inputs.insert("data".to_string(), batch_patterns(&mut rng, 16));
        let mut alg = Cd::new(1);
        let mut step = |net: &mut NeuralNet, alg: &mut Cd| {
            net.zero_grads();
            alg.train_one_batch(net, &inputs);
            for p in net.params_mut() {
                p.sgd_step(0.05);
            }
        };
        for _ in 0..2 {
            step(&mut net, &mut alg);
        }
        let before = Blob::alloc_count();
        for _ in 0..4 {
            step(&mut net, &mut alg);
        }
        assert_eq!(
            Blob::alloc_count(),
            before,
            "steady-state CD training must not allocate blobs"
        );
    }

    #[test]
    fn cd_all_mode_reports_every_rbm() {
        let mut net = rbm_net(4, 8, 6, 4);
        let mut rng = Rng::new(5);
        let mut alg = Cd::new(1);
        let mut inputs = HashMap::new();
        inputs.insert("data".to_string(), batch_patterns(&mut rng, 4));
        let stats = alg.train_one_batch(&mut net, &inputs);
        assert_eq!(stats.losses.len(), 2);
    }
}
