//! Back-Propagation `TrainOneBatch` (paper Algorithm 1).
//!
//! The first loop visits each layer in topological order and computes
//! features; the second visits layers in reverse and computes gradients.
//! Recurrent layers (e.g. [`crate::model::gru::GruLayer`]) unroll internally,
//! so the same driver realizes BPTT (paper §4.1.3: "for feed-forward and
//! recurrent models, the BP algorithm is provided").

use super::{GradObserver, NoopObserver, StepStats, TrainOneBatch};
use crate::model::{NeuralNet, Phase};
use crate::tensor::Blob;
use std::collections::HashMap;

/// Stateless BP driver.
#[derive(Default, Clone)]
pub struct Bp;

impl Bp {
    pub fn new() -> Bp {
        Bp
    }
}

impl TrainOneBatch for Bp {
    fn train_one_batch(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
    ) -> StepStats {
        self.train_one_batch_observed(net, inputs, &mut NoopObserver)
    }

    /// BP plumbs the observer straight into the backward pass: each layer's
    /// hook fires right after its `ComputeGradient`, in reverse-topological
    /// order, while the layers below are still computing — the overlap
    /// window the bucketed exchange drains.
    fn train_one_batch_observed(
        &mut self,
        net: &mut NeuralNet,
        inputs: &HashMap<String, Blob>,
        obs: &mut dyn GradObserver,
    ) -> StepStats {
        for (name, blob) in inputs {
            // Copied straight into the input layer's workspace slot — no
            // per-step clone.
            net.try_set_input_ref(name, blob);
        }
        net.forward(Phase::Train); // Collect + ComputeFeature loop
        net.backward_observed(obs); // ComputeGradient + Update loop
        StepStats { losses: net.losses() }
    }

    fn name(&self) -> &'static str {
        "BP"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::NetBuilder;
    use crate::utils::rng::Rng;

    fn xor_net(batch: usize) -> NeuralNet {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 2] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(LayerConf::new(
                "h",
                LayerKind::InnerProduct { out: 8, act: Activation::Tanh, init_std: 0.8 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 2, act: Activation::Identity, init_std: 0.8 },
                &["h"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
            .build(&mut Rng::new(21))
    }

    /// BP must solve XOR — the classic non-linear sanity check.
    #[test]
    fn bp_learns_xor() {
        let mut net = xor_net(4);
        let mut alg = Bp::new();
        let x = Blob::from_vec(&[4, 2], vec![0., 0., 0., 1., 1., 0., 1., 1.]);
        let y = Blob::from_vec(&[4], vec![0., 1., 1., 0.]);
        let mut inputs = HashMap::new();
        inputs.insert("data".to_string(), x);
        inputs.insert("label".to_string(), y);
        let mut last = StepStats::default();
        for _ in 0..400 {
            net.zero_grads();
            last = alg.train_one_batch(&mut net, &inputs);
            for p in net.params_mut() {
                p.sgd_step(0.5);
            }
        }
        assert_eq!(last.metric(), 1.0, "XOR accuracy must reach 1.0");
        assert!(last.total_loss() < 0.1);
    }

    /// BPTT through the GRU layer: a sequence task (predict previous char)
    /// must be learnable.
    #[test]
    fn bp_drives_bptt_on_gru() {
        let batch = 8;
        let steps = 4;
        let vocab = 5;
        let mut net = NetBuilder::new()
            .add(LayerConf::new("chars", LayerKind::Input { shape: vec![batch, steps] }, &[]))
            .add(LayerConf::new("labels", LayerKind::Input { shape: vec![batch, steps] }, &[]))
            .add(LayerConf::new("onehot", LayerKind::OneHot { vocab }, &["chars"]))
            .add(LayerConf::new("gru", LayerKind::Gru { hidden: 16, steps, init_std: 0.3 }, &["onehot"]))
            .add(LayerConf::new(
                "proj",
                LayerKind::InnerProduct { out: steps * vocab, act: Activation::Identity, init_std: 0.3 },
                &["gru"],
            ))
            .add(LayerConf::new("loss", LayerKind::SeqSoftmaxLoss { steps }, &["proj", "labels"]))
            .build(&mut Rng::new(33));
        let mut alg = Bp::new();
        let mut rng = Rng::new(11);
        let mut last = StepStats::default();
        let mut first_loss = None;
        for _ in 0..150 {
            // Task: label[t] = char[t] (copy); learnable via the projection.
            let mut chars = Vec::new();
            for _ in 0..batch * steps {
                chars.push(rng.below(vocab) as f32);
            }
            let c = Blob::from_vec(&[batch, steps], chars.clone());
            let l = Blob::from_vec(&[batch, steps], chars);
            let mut inputs = HashMap::new();
            inputs.insert("chars".to_string(), c);
            inputs.insert("labels".to_string(), l);
            net.zero_grads();
            last = alg.train_one_batch(&mut net, &inputs);
            if first_loss.is_none() {
                first_loss = Some(last.total_loss());
            }
            for p in net.params_mut() {
                // GRU params all have lr_mult 1.0; the projection bias
                // trains at its usual 2x.
                p.sgd_step(0.5 / p.lr_mult.max(1.0));
            }
        }
        assert!(
            last.total_loss() < 0.5 * first_loss.unwrap(),
            "BPTT loss should halve: first {:?} last {}",
            first_loss,
            last.total_loss()
        );
        assert!(last.metric() > 0.8, "copy-task accuracy {}", last.metric());
    }
}
