//! `singa` CLI — the L3 leader entrypoint.
//!
//! ```text
//! singa train <job.json>       run a training job from a config file
//! singa repro <figure|all>     regenerate a paper table/figure series
//! singa summary <model>        print a model preset's layer summary
//! singa version
//! ```

use singa::utils::log::{set_level, Level};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "-v" || a == "--verbose") {
        set_level(Level::Debug);
    }
    let cmd = args.get(1).map(String::as_str).unwrap_or("help");
    match cmd {
        "version" => println!("singa-rs {}", singa::VERSION),
        "train" => {
            let path = args.get(2).expect("usage: singa train <job.json>");
            let text = std::fs::read_to_string(path).expect("reading config");
            let conf = singa::config::parse_job(&text).expect("parsing config");
            let data: std::sync::Arc<dyn singa::data::DataSource> =
                std::sync::Arc::new(singa::data::SyntheticDigits::mnist_like(conf.seed));
            let report = singa::coordinator::run_job(&conf, data);
            print!("{}", report.log.to_tsv());
            eprintln!(
                "done: wall {:.1} ms, {} param bytes moved",
                report.wall_ms,
                report.ledger.param_bytes()
            );
        }
        "repro" => {
            let fig = args.get(2).map(String::as_str).unwrap_or("all");
            let out = match fig {
                "all" => singa::bench::run_all(false),
                "quick" => singa::bench::run_all(true),
                "table1" => singa::bench::table1(),
                "fig16" => singa::bench::fig16(300),
                "fig17" => singa::bench::fig17(300),
                "fig18a" => singa::bench::fig18a(None),
                "fig18b" => singa::bench::fig18b(None),
                "fig19ab" => singa::bench::fig19ab(16, 150),
                "fig19c" => singa::bench::fig19c(4, 150),
                "fig20a" => singa::bench::fig20a(),
                "fig20b" => singa::bench::fig20b(),
                "fig21a" => singa::bench::fig21a(),
                "fig21b" => singa::bench::fig21b(),
                "ablation_priority" => singa::bench::ablation_priority(),
                "ablation_partition_rule" => singa::bench::ablation_partition_rule(),
                other => {
                    eprintln!("unknown figure '{other}'");
                    std::process::exit(2);
                }
            };
            print!("{out}");
        }
        "summary" => {
            let model = args.get(2).map(String::as_str).unwrap_or("cifar_convnet");
            let net = singa::config::model_preset(model, 32)
                .expect("unknown model")
                .build(&mut singa::utils::rng::Rng::new(1));
            print!("{}", net.summary());
            println!("total params: {}", net.param_count());
        }
        _ => {
            println!("singa-rs {} — SINGA reproduction (rust + JAX + Pallas)", singa::VERSION);
            println!("usage: singa <train|repro|summary|version> [args]");
        }
    }
}
