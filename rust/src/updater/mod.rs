//! Parameter updaters (paper §4.1.4): the protocol servers apply when a
//! gradient arrives. SGD (+momentum), AdaGrad (the paper's example),
//! Nesterov and RMSProp, each combined with a learning-rate schedule.
//!
//! Updaters are stateful per parameter (momentum / accumulated squares), so
//! each server shard owns one updater state entry per parameter it manages.

use crate::tensor::blob::Param;
use crate::tensor::Blob;
use std::collections::HashMap;

/// Learning-rate schedules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Fixed,
    /// `lr * gamma^(step / stride)` (staircase).
    Step { gamma: f32, stride: u64 },
    /// `lr * gamma^step` (smooth exponential).
    Exp { gamma: f32 },
    /// `lr / (1 + gamma * step)^power`.
    Inverse { gamma: f32, power: f32 },
}

impl LrSchedule {
    pub fn at(&self, base: f32, step: u64) -> f32 {
        match *self {
            LrSchedule::Fixed => base,
            LrSchedule::Step { gamma, stride } => base * gamma.powi((step / stride) as i32),
            LrSchedule::Exp { gamma } => base * gamma.powi(step as i32),
            LrSchedule::Inverse { gamma, power } => {
                base / (1.0 + gamma * step as f32).powf(power)
            }
        }
    }
}

/// Updater algorithm + hyper-parameters.
#[derive(Debug, Clone)]
pub struct UpdaterConf {
    pub algo: Algo,
    pub lr: f32,
    pub schedule: LrSchedule,
    /// L2 weight decay coefficient.
    pub weight_decay: f32,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algo {
    Sgd { momentum: f32 },
    AdaGrad { eps: f32 },
    Nesterov { momentum: f32 },
    RmsProp { decay: f32, eps: f32 },
}

impl UpdaterConf {
    pub fn sgd(lr: f32) -> UpdaterConf {
        UpdaterConf { algo: Algo::Sgd { momentum: 0.0 }, lr, schedule: LrSchedule::Fixed, weight_decay: 0.0 }
    }

    pub fn sgd_momentum(lr: f32, momentum: f32) -> UpdaterConf {
        UpdaterConf { algo: Algo::Sgd { momentum }, lr, schedule: LrSchedule::Fixed, weight_decay: 0.0 }
    }

    pub fn adagrad(lr: f32) -> UpdaterConf {
        UpdaterConf { algo: Algo::AdaGrad { eps: 1e-8 }, lr, schedule: LrSchedule::Fixed, weight_decay: 0.0 }
    }

    pub fn nesterov(lr: f32, momentum: f32) -> UpdaterConf {
        UpdaterConf { algo: Algo::Nesterov { momentum }, lr, schedule: LrSchedule::Fixed, weight_decay: 0.0 }
    }

    pub fn rmsprop(lr: f32) -> UpdaterConf {
        UpdaterConf {
            algo: Algo::RmsProp { decay: 0.9, eps: 1e-8 },
            lr,
            schedule: LrSchedule::Fixed,
            weight_decay: 0.0,
        }
    }

    pub fn with_schedule(mut self, s: LrSchedule) -> UpdaterConf {
        self.schedule = s;
        self
    }

    pub fn with_weight_decay(mut self, wd: f32) -> UpdaterConf {
        self.weight_decay = wd;
        self
    }
}

/// Stateful updater over a set of named parameters.
pub struct Updater {
    conf: UpdaterConf,
    /// Per-param auxiliary state (momentum buffer / squared-grad history).
    state: HashMap<String, Blob>,
}

impl Updater {
    pub fn new(conf: UpdaterConf) -> Updater {
        Updater { conf, state: HashMap::new() }
    }

    pub fn conf(&self) -> &UpdaterConf {
        &self.conf
    }

    /// Apply one update: `value -= f(grad)` where `f` depends on the
    /// algorithm. `lr_mult`/`wd_mult` come from the `Param` metadata; `step`
    /// is the global iteration for the LR schedule.
    ///
    /// L2 weight decay is folded into every fused loop below
    /// (`g = grad + wd * value`, each element reading its own pre-update
    /// value), so servers never materialize a decayed-gradient blob. Because
    /// elements are independent, this is bit-identical to the historical
    /// two-pass `grad.clone()` + `axpy` formulation (pinned by
    /// `fused_weight_decay_matches_two_pass_reference_bitwise`).
    pub fn update(
        &mut self,
        name: &str,
        value: &mut Blob,
        grad: &Blob,
        lr_mult: f32,
        wd_mult: f32,
        step: u64,
    ) {
        assert_eq!(value.shape(), grad.shape(), "updater shape mismatch for {name}");
        let lr = self.conf.schedule.at(self.conf.lr, step) * lr_mult;
        let wd = self.conf.weight_decay * wd_mult;
        // The wd == 0 guard below (in every loop) is not just an optimization:
        // it keeps the decay-off path using `gi` untouched, exactly like the
        // historical code — `gi + 0.0 * w` would turn a non-finite weight
        // into a NaN gradient and poison the state buffers.
        match self.conf.algo {
            Algo::Sgd { momentum } => {
                if momentum == 0.0 {
                    for (w, &gi) in value.data_mut().iter_mut().zip(grad.data()) {
                        let g = if wd != 0.0 { gi + wd * *w } else { gi };
                        *w += -lr * g;
                    }
                } else {
                    let buf = self
                        .state
                        .entry(name.to_string())
                        .or_insert_with(|| Blob::zeros(value.shape()));
                    // v = mu*v + g ; w -= lr*v
                    for ((v, w), &gi) in buf.data_mut().iter_mut().zip(value.data_mut()).zip(grad.data())
                    {
                        let g = if wd != 0.0 { gi + wd * *w } else { gi };
                        *v = momentum * *v + g;
                        *w += -lr * *v;
                    }
                }
            }
            Algo::AdaGrad { eps } => {
                let hist = self
                    .state
                    .entry(name.to_string())
                    .or_insert_with(|| Blob::zeros(value.shape()));
                for ((h, w), &gi) in hist.data_mut().iter_mut().zip(value.data_mut()).zip(grad.data())
                {
                    let g = if wd != 0.0 { gi + wd * *w } else { gi };
                    *h += g * g;
                    *w -= lr * g / (h.sqrt() + eps);
                }
            }
            Algo::Nesterov { momentum } => {
                let buf = self
                    .state
                    .entry(name.to_string())
                    .or_insert_with(|| Blob::zeros(value.shape()));
                // v' = mu*v - lr*g ; w += -mu*v + (1+mu)*v', fused
                // elementwise so no copy of the previous velocity is kept.
                for ((w, v), &gi) in value.data_mut().iter_mut().zip(buf.data_mut()).zip(grad.data())
                {
                    let g = if wd != 0.0 { gi + wd * *w } else { gi };
                    let vnew = momentum * *v - lr * g;
                    *w += -momentum * *v + (1.0 + momentum) * vnew;
                    *v = vnew;
                }
            }
            Algo::RmsProp { decay, eps } => {
                let hist = self
                    .state
                    .entry(name.to_string())
                    .or_insert_with(|| Blob::zeros(value.shape()));
                for ((h, w), &gi) in hist.data_mut().iter_mut().zip(value.data_mut()).zip(grad.data())
                {
                    let g = if wd != 0.0 { gi + wd * *w } else { gi };
                    *h = decay * *h + (1.0 - decay) * g * g;
                    *w -= lr * g / (h.sqrt() + eps);
                }
            }
        }
    }

    /// Apply one update directly to a [`Param`], splitting its `data`/`grad`
    /// fields internally — callers no longer clone the gradient to work
    /// around the aliasing.
    pub fn update_param(&mut self, p: &mut Param, step: u64) {
        let Param { name, data, grad, lr_mult, wd_mult, .. } = p;
        self.update(name, data, grad, *lr_mult, *wd_mult, step);
    }

    /// Bytes of auxiliary state held (server memory accounting).
    pub fn state_bytes(&self) -> usize {
        self.state.values().map(|b| b.byte_size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_descent(conf: UpdaterConf, iters: usize) -> f32 {
        // Minimize f(w) = 0.5*||w||^2 starting from w = 3.
        let mut u = Updater::new(conf);
        let mut w = Blob::full(&[4], 3.0);
        for step in 0..iters {
            let g = w.clone(); // grad of 0.5 w^2 is w
            u.update("w", &mut w, &g, 1.0, 1.0, step as u64);
        }
        w.norm()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(quadratic_descent(UpdaterConf::sgd(0.1), 100) < 1e-3);
    }

    #[test]
    fn momentum_beats_plain_sgd_same_lr() {
        let plain = quadratic_descent(UpdaterConf::sgd(0.01), 100);
        let mom = quadratic_descent(UpdaterConf::sgd_momentum(0.01, 0.9), 100);
        assert!(mom < plain, "momentum {mom} vs plain {plain}");
    }

    #[test]
    fn adagrad_converges() {
        assert!(quadratic_descent(UpdaterConf::adagrad(0.5), 300) < 0.1);
    }

    #[test]
    fn nesterov_converges() {
        assert!(quadratic_descent(UpdaterConf::nesterov(0.05, 0.9), 200) < 1e-2);
    }

    #[test]
    fn rmsprop_converges() {
        assert!(quadratic_descent(UpdaterConf::rmsprop(0.05), 300) < 0.1);
    }

    /// The fused decay loops must reproduce the historical two-pass
    /// formulation (clone the gradient, `axpy` the decay term, update with
    /// decay off) bit-for-bit, for every algorithm and across steps that
    /// exercise the stateful buffers.
    #[test]
    fn fused_weight_decay_matches_two_pass_reference_bitwise() {
        use crate::utils::rng::Rng;
        let confs = [
            UpdaterConf::sgd(0.07),
            UpdaterConf::sgd_momentum(0.05, 0.9),
            UpdaterConf::adagrad(0.1),
            UpdaterConf::nesterov(0.04, 0.8),
            UpdaterConf::rmsprop(0.03),
        ];
        for base in confs {
            let wd = 0.3f32;
            let wd_mult = 0.7f32;
            let mut fused = Updater::new(base.clone().with_weight_decay(wd));
            let mut twopass = Updater::new(base.clone()); // decay handled manually
            let mut rng = Rng::new(11);
            let mut wf = Blob::from_vec(&[6], rng.uniform_vec(6, -1.0, 1.0));
            let mut wt = wf.clone();
            for step in 0..5u64 {
                let g = Blob::from_vec(&[6], rng.uniform_vec(6, -0.5, 0.5));
                fused.update("p", &mut wf, &g, 1.3, wd_mult, step);
                let mut d = g.clone();
                d.axpy(wd * wd_mult, &wt);
                twopass.update("p", &mut wt, &d, 1.3, 1.0, step);
                assert_eq!(wf.data(), wt.data(), "{:?} step {step}", base.algo);
            }
        }
    }

    /// With decay off, the gradient must be used untouched: `gi + 0.0 * w`
    /// would turn a non-finite weight into a NaN update and poison the
    /// momentum/history state (a diverged weight should stay inf, which is
    /// diagnosable).
    #[test]
    fn decay_off_never_touches_nonfinite_weights() {
        for conf in [
            UpdaterConf::sgd(0.1),
            UpdaterConf::sgd_momentum(0.1, 0.9),
            UpdaterConf::adagrad(0.1),
            UpdaterConf::nesterov(0.1, 0.9),
            UpdaterConf::rmsprop(0.1),
        ] {
            let mut u = Updater::new(conf);
            let mut w = Blob::from_vec(&[2], vec![f32::INFINITY, 1.0]);
            let g = Blob::zeros(&[2]);
            u.update("w", &mut w, &g, 1.0, 1.0, 0);
            assert!(w.data()[0].is_infinite(), "diverged weight must stay inf, not NaN");
            assert!(w.data()[1].is_finite());
        }
    }

    /// Decay no longer allocates: an update with weight decay enabled makes
    /// exactly as many blob allocations as one without.
    #[test]
    fn decayed_update_allocates_no_extra_blobs() {
        let measure = |conf: UpdaterConf| {
            let mut u = Updater::new(conf);
            let mut w = Blob::full(&[32], 1.0);
            let g = Blob::full(&[32], 0.1);
            u.update("w", &mut w, &g, 1.0, 1.0, 0); // warm (sizes any state)
            let before = Blob::alloc_count();
            u.update("w", &mut w, &g, 1.0, 1.0, 1);
            Blob::alloc_count() - before
        };
        let plain = measure(UpdaterConf::sgd_momentum(0.1, 0.9));
        let decayed = measure(UpdaterConf::sgd_momentum(0.1, 0.9).with_weight_decay(0.01));
        assert_eq!(plain, 0, "steady-state update must not allocate");
        assert_eq!(decayed, 0, "decayed update must not allocate either");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut u = Updater::new(UpdaterConf::sgd(0.1).with_weight_decay(0.5));
        let mut w = Blob::full(&[2], 1.0);
        let zero_grad = Blob::zeros(&[2]);
        u.update("w", &mut w, &zero_grad, 1.0, 1.0, 0);
        // w -= lr * wd * w → 1 - 0.05
        assert!((w.data()[0] - 0.95).abs() < 1e-6);
        // wd_mult = 0 disables decay (bias convention)
        let mut b = Blob::full(&[2], 1.0);
        u.update("b", &mut b, &zero_grad, 1.0, 0.0, 0);
        assert_eq!(b.data()[0], 1.0);
    }

    #[test]
    fn schedules() {
        let s = LrSchedule::Step { gamma: 0.1, stride: 10 };
        assert_eq!(s.at(1.0, 0), 1.0);
        assert!((s.at(1.0, 10) - 0.1).abs() < 1e-6);
        assert!((s.at(1.0, 25) - 0.01).abs() < 1e-7);
        let e = LrSchedule::Exp { gamma: 0.99 };
        assert!(e.at(1.0, 100) < 0.4);
        let inv = LrSchedule::Inverse { gamma: 1e-2, power: 0.75 };
        assert!(inv.at(1.0, 1000) < 0.2);
        assert_eq!(LrSchedule::Fixed.at(0.3, 999), 0.3);
    }

    #[test]
    fn lr_mult_scales_update() {
        let mut u = Updater::new(UpdaterConf::sgd(0.1));
        let mut a = Blob::full(&[1], 1.0);
        let mut b = Blob::full(&[1], 1.0);
        let g = Blob::full(&[1], 1.0);
        u.update("a", &mut a, &g, 1.0, 1.0, 0);
        u.update("b", &mut b, &g, 2.0, 1.0, 0);
        assert!((a.data()[0] - 0.9).abs() < 1e-6);
        assert!((b.data()[0] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn state_bytes_accounting() {
        let mut u = Updater::new(UpdaterConf::sgd_momentum(0.1, 0.9));
        assert_eq!(u.state_bytes(), 0);
        let mut w = Blob::zeros(&[10]);
        let g = Blob::zeros(&[10]);
        u.update("w", &mut w, &g, 1.0, 1.0, 0);
        assert_eq!(u.state_bytes(), 40);
    }
}
