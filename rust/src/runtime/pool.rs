//! Persistent intra-op worker pool: a lazily-spawned set of parked worker
//! threads shared by every parallel compute kernel in the process (today:
//! the tiled GEMM in [`crate::tensor::gemm`] and the im2col/col2im stripes
//! in [`crate::tensor::conv`]).
//!
//! # Why a pool
//!
//! The first parallel GEMM spawned a `std::thread::scope` per `(kk, jj)`
//! panel — simple and provably deterministic, but thread creation costs
//! tens of microseconds, paid hundreds of times per large GEMM. The pool
//! keeps workers parked on a condvar between dispatches, so fanning a panel
//! out costs two lock/notify round-trips instead of `t` thread spawns.
//!
//! # Execution model
//!
//! [`run`]`(tasks, f)` executes `f(0)`, `f(1)`, …, `f(tasks - 1)` and
//! returns when all of them finished. The *caller* always executes task 0
//! on its own thread; tasks `1..` are pushed onto a process-global queue
//! drained by the parked workers. While waiting for its own tasks, the
//! caller also helps drain the queue (it may execute other callers' tasks),
//! so the pool is work-conserving and concurrent callers — e.g. several
//! coordinator worker groups — share the same workers without deadlock:
//! every queued task is eventually executed by a worker, its enqueuer, or
//! another helping caller, and no thread ever blocks while holding work.
//!
//! # Determinism
//!
//! The pool assigns *task indices*, never thread identities: which OS
//! thread executes task `i` is scheduling-dependent, but the work performed
//! by task `i` is a pure function of `i` chosen by the caller. Kernels
//! built on the pool therefore keep the bit-for-bit determinism contract —
//! partition by task index, write disjoint output regions — regardless of
//! how many workers actually exist.
//!
//! # Sizing
//!
//! Workers are spawned lazily up to [`max_workers`] (`cores - 1`, because
//! the caller is the extra compute thread) and then parked forever — the
//! pool never shrinks and never exceeds the machine, no matter how many
//! tasks callers request. Requesting more tasks than workers is fine: the
//! surplus queues and the available threads (including the caller) drain
//! it. Combined with the worker-group-aware budget in
//! [`crate::runtime::threads`], nested parallelism degrades into queueing,
//! not OS oversubscription.

use crate::runtime::sync::{
    OrderedCondvar, OrderedMutex, RANK_POOL_LATCH, RANK_POOL_STATE,
};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// One queued task: the erased closure, the task index to call it with,
/// and the completion latch of the `run` call that enqueued it.
///
/// The `'static` lifetimes are a lie told by [`run`], which transmutes
/// stack borrows before enqueueing; soundness rests on `run` never
/// returning (or unwinding) until the latch reports every enqueued task
/// finished, so the borrows outlive all uses.
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    task: usize,
    latch: &'static Latch,
}

/// Countdown latch synchronizing a `run` call with its enqueued tasks.
/// The mutex also provides the happens-before edge that makes task writes
/// (e.g. GEMM output stripes) visible to the caller after the wait.
struct Latch {
    remaining: OrderedMutex<usize>,
    cv: OrderedCondvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: OrderedMutex::new(RANK_POOL_LATCH, "pool.latch", n),
            cv: OrderedCondvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    /// Mark one task finished. The final `done` must not touch the latch
    /// after releasing the lock: the caller may return and free it.
    fn done(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        while *remaining > 0 {
            remaining = self.cv.wait(remaining).unwrap();
        }
    }
}

struct PoolState {
    queue: VecDeque<Job>,
    /// Workers spawned so far (they never exit, so this is also the live
    /// count — asserted stable by the soak suite in `tests/pool.rs`).
    workers: usize,
}

struct Pool {
    state: OrderedMutex<PoolState>,
    /// Parked workers wait here for the queue to become non-empty.
    work_cv: OrderedCondvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: OrderedMutex::new(
            RANK_POOL_STATE,
            "pool.state",
            PoolState { queue: VecDeque::new(), workers: 0 },
        ),
        work_cv: OrderedCondvar::new(),
    })
}

/// Upper bound on spawned workers: [`crate::runtime::cores`]` - 1`, because
/// the calling thread always executes task 0 (and helps drain the queue), so
/// `cores` compute threads exist at full fan-out without oversubscribing.
pub fn max_workers() -> usize {
    crate::runtime::cores().saturating_sub(1)
}

/// Workers spawned so far. Monotone, bounded by [`max_workers`]; the soak
/// suite asserts it stays flat across thousands of steady-state dispatches.
pub fn worker_count() -> usize {
    pool().state.lock().unwrap().workers
}

/// Try to spawn one worker. Failure (e.g. the process is at its thread
/// limit) is tolerated, never propagated: `run` must not unwind while Jobs
/// holding lifetime-erased borrows sit in the queue, and a smaller pool is
/// always safe — the caller's help loop drains whatever workers don't.
fn spawn_worker(id: usize) -> bool {
    std::thread::Builder::new()
        .name(format!("pallas-pool-{id}"))
        .spawn(worker_loop)
        .is_ok()
}

fn worker_loop() {
    let p = pool();
    loop {
        let job = {
            let mut st = p.state.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    break job;
                }
                st = p.work_cv.wait(st).unwrap();
            }
        };
        execute(job);
    }
}

/// Run one task, converting a panic into a latch flag so the worker thread
/// survives and the originating caller re-raises. `done` is the last touch
/// of the latch (see [`Latch::done`]).
fn execute(job: Job) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.f)(job.task)));
    if result.is_err() {
        job.latch.panicked.store(true, Ordering::Relaxed);
    }
    job.latch.done();
}

/// Drain queued tasks (any caller's) until `latch` completes. Never blocks
/// while work is available, so a caller whose tasks sit behind another
/// caller's burst makes progress by executing the head of the queue.
fn help_until_done(latch: &Latch) {
    loop {
        if latch.is_done() {
            return;
        }
        let job = pool().state.lock().unwrap().queue.pop_front();
        match job {
            Some(job) => execute(job),
            // Queue empty: every task of ours is held by some running
            // thread, which will call `done` when it finishes.
            None => {
                latch.wait();
                return;
            }
        }
    }
}

/// Guard ensuring `run` waits for its enqueued tasks even when the caller's
/// own `f(0)` panics — the borrows smuggled into the queue must not dangle.
struct HelpOnDrop<'a>(&'a Latch);

impl Drop for HelpOnDrop<'_> {
    fn drop(&mut self) {
        help_until_done(self.0);
    }
}

/// Execute `f(0..tasks)` across the persistent pool and block until every
/// task finished. `f` may run concurrently on several threads (it must be
/// `Sync`); per-task mutable state is typically handed out through a
/// `Vec<OrderedMutex<_>>` indexed by task — each slot is locked by exactly one
/// task, so the locks are uncontended.
///
/// `tasks <= 1` runs entirely on the caller thread, touching no pool
/// machinery (the serial path of every kernel stays spawn- and lock-free).
///
/// Panics in any task are re-raised on the calling thread after all tasks
/// settle.
pub fn run<F: Fn(usize) + Sync>(tasks: usize, f: F) {
    if tasks == 0 {
        return;
    }
    if tasks == 1 {
        f(0);
        return;
    }
    let latch = Latch::new(tasks - 1);
    // SAFETY: the `'static` borrows below never escape this call. Every
    // enqueued Job holds `&f` and `&latch`; `run` returns (or resumes
    // unwinding) only after `latch` counts every Job finished — enforced on
    // the normal path AND the panic path by `HelpOnDrop` — and the final
    // `Latch::done` releases its lock before the caller can observe
    // completion, so no task touches either borrow afterwards.
    let f_dyn: &(dyn Fn(usize) + Sync) = &f;
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_dyn) };
    let latch_static: &'static Latch = unsafe { std::mem::transmute(&latch) };
    // Armed BEFORE any Job escapes into the queue: from here on, every exit
    // from this frame — normal return or unwind from any statement below —
    // first drains/awaits the latch, so the erased borrows cannot dangle.
    let complete = HelpOnDrop(&latch);
    {
        let p = pool();
        let mut st = p.state.lock().unwrap();
        for task in 1..tasks {
            st.queue.push_back(Job { f: f_static, task, latch: latch_static });
        }
        let want = (tasks - 1).min(max_workers());
        while st.workers < want && spawn_worker(st.workers) {
            st.workers += 1;
        }
        drop(st);
        p.work_cv.notify_all();
    }
    f(0);
    drop(complete);
    if latch.panicked.load(Ordering::Relaxed) {
        panic!("intra-op pool task panicked (see worker output above)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn zero_and_one_task_run_inline() {
        let count = AtomicUsize::new(0);
        run(0, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 0);
        run(1, |i| {
            assert_eq!(i, 0);
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn every_task_index_runs_exactly_once() {
        for &tasks in &[2usize, 3, 8, 17] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            run(tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn worker_count_is_capped_at_max_workers() {
        // Request far more tasks than cores: the surplus queues instead of
        // spawning threads.
        run(max_workers() + 7, |_| {});
        assert!(worker_count() <= max_workers());
        for _ in 0..20 {
            run(4, |_| {});
        }
        assert!(worker_count() <= max_workers());
    }

    #[test]
    fn tasks_mutate_disjoint_slices_via_per_task_mutexes() {
        let mut data = vec![0u32; 64];
        let t = 4;
        {
            let chunk = data.len() / t;
            use crate::runtime::sync::RANK_COMPUTE_STRIPE;
            let slots: Vec<OrderedMutex<&mut [u32]>> = data
                .chunks_mut(chunk)
                .map(|s| OrderedMutex::new(RANK_COMPUTE_STRIPE, "pool.test.slot", s))
                .collect();
            run(t, |tid| {
                let mut s = slots[tid].try_lock().expect("task owns its slot");
                for v in s.iter_mut() {
                    *v = tid as u32 + 1;
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 16) as u32 + 1, "index {i}");
        }
    }

    #[test]
    fn concurrent_callers_share_the_pool_without_deadlock() {
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                s.spawn(move || {
                    for round in 0..20 {
                        let tasks = 2 + ((seed as usize + round) % 5);
                        let sum = AtomicUsize::new(0);
                        run(tasks, |i| {
                            sum.fetch_add(i + 1, Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::Relaxed), tasks * (tasks + 1) / 2);
                    }
                });
            }
        });
    }

    #[test]
    #[should_panic(expected = "intra-op pool task panicked")]
    fn panicking_task_propagates_to_the_caller() {
        run(2, |i| {
            if i == 1 {
                panic!("boom");
            }
        });
    }
}
