//! Simulated accelerator devices (DESIGN.md §Hardware-Adaptation).
//!
//! The paper's GPU experiments (3× GTX 970 over PCIe) are modeled as
//! devices with a compute-rate multiplier relative to the measured CPU
//! execution and a host↔device transfer link. The copy-queue experiments
//! (Fig 14 / Fig 20a) charge transfers against the device's link while
//! compute proceeds — see [`crate::coordinator::copyqueue`].

use crate::comm::LinkModel;

/// Kind of execution resource backing a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    /// Simulated GPU: compute time = measured CPU time / speedup.
    SimGpu,
}

/// One device slot assignable to a worker (paper §5.1: "SINGA automatically
/// assigns g GPU devices to the first g workers on each node").
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub kind: DeviceKind,
    pub id: usize,
    /// Speedup over the host CPU for dense compute (GTX-970-class cards ran
    /// the paper's convnets ~15-30x faster than one CPU core).
    pub speedup: f64,
    /// Host ↔ device link.
    pub link: LinkModel,
}

impl Device {
    pub fn cpu(id: usize) -> Device {
        Device { kind: DeviceKind::Cpu, id, speedup: 1.0, link: LinkModel::shared_memory() }
    }

    pub fn sim_gpu(id: usize) -> Device {
        Device { kind: DeviceKind::SimGpu, id, speedup: 20.0, link: LinkModel::pcie3() }
    }

    /// Device-clock compute time for work measured at `cpu_us` on the host.
    pub fn compute_us(&self, cpu_us: f64) -> f64 {
        cpu_us / self.speedup
    }

    /// Host↔device transfer time for `bytes`.
    pub fn transfer_us(&self, bytes: usize) -> f64 {
        self.link.transfer_us(bytes)
    }
}

/// Assign `g` simulated GPUs to the first `g` of `n` workers, CPUs to the
/// rest (the paper's §5.1 assignment rule).
pub fn assign_devices(n: usize, g: usize) -> Vec<Device> {
    (0..n)
        .map(|i| if i < g { Device::sim_gpu(i) } else { Device::cpu(i) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_rule() {
        let d = assign_devices(4, 2);
        assert_eq!(d[0].kind, DeviceKind::SimGpu);
        assert_eq!(d[1].kind, DeviceKind::SimGpu);
        assert_eq!(d[2].kind, DeviceKind::Cpu);
        assert_eq!(d[3].kind, DeviceKind::Cpu);
    }

    #[test]
    fn compute_scaling() {
        let gpu = Device::sim_gpu(0);
        assert!((gpu.compute_us(2000.0) - 100.0).abs() < 1e-9);
        let cpu = Device::cpu(0);
        assert_eq!(cpu.compute_us(2000.0), 2000.0);
    }

    #[test]
    fn gpu_transfers_cost_more_than_cpu() {
        let gpu = Device::sim_gpu(0);
        let cpu = Device::cpu(0);
        assert!(gpu.transfer_us(1_000_000) > cpu.transfer_us(1_000_000));
    }
}
