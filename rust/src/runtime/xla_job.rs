//! XLA-backed distributed training: the production path where worker
//! groups execute an AOT-compiled step artifact (L2 model + L1 Pallas
//! kernels) through PJRT while the L3 coordinator moves parameters between
//! them and the server groups. Python never runs here.

use super::XlaRuntime;
use crate::cluster::ClusterTopology;
use crate::comm::{ByteLedger, CostModel, VirtualClock};
use crate::metrics::{Record, TrainingLog};
use crate::server::ServerGroup;
use crate::tensor::Blob;
use crate::updater::UpdaterConf;
use crate::utils::rng::Rng;
use crate::utils::timer::Stopwatch;
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Produces the data inputs (non-param inputs) of a step artifact for a
/// given batch index.
pub type Batcher = Arc<dyn Fn(u64) -> HashMap<String, Blob> + Send + Sync>;

/// Job configuration for XLA-backed training.
#[derive(Clone)]
pub struct XlaJobConf {
    pub artifact: String,
    pub artifact_dir: PathBuf,
    pub updater: UpdaterConf,
    pub topology: ClusterTopology,
    pub iters: u64,
    pub seed: u64,
    pub cost: CostModel,
    pub log_every: u64,
}

impl XlaJobConf {
    pub fn new(artifact: &str) -> XlaJobConf {
        XlaJobConf {
            artifact: artifact.to_string(),
            artifact_dir: XlaRuntime::default_dir(),
            updater: UpdaterConf::sgd(0.1),
            topology: ClusterTopology::sandblaster(1, 1),
            iters: 50,
            seed: 0xa07,
            cost: CostModel::numa_server(),
            log_every: 1,
        }
    }
}

/// Report mirror of [`crate::coordinator::JobReport`] for the XLA path.
pub struct XlaJobReport {
    pub log: Arc<TrainingLog>,
    pub ledger: Arc<ByteLedger>,
    pub wall_ms: f64,
    pub params: HashMap<String, Blob>,
}

/// Run the XLA-backed training job.
pub fn run_xla_job(conf: &XlaJobConf, batcher: Batcher) -> Result<XlaJobReport> {
    let ledger = Arc::new(ByteLedger::new());
    // One probe runtime on the main thread to read the manifest and
    // initialize parameters at the servers.
    let probe = XlaRuntime::open(&conf.artifact_dir)?;
    let spec = probe
        .manifest
        .artifacts
        .get(&conf.artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{}' missing", conf.artifact))?
        .clone();
    drop(probe);

    let topo = &conf.topology;
    let servers: Arc<Vec<ServerGroup>> = Arc::new(
        (0..topo.nserver_groups)
            .map(|_| ServerGroup::new(topo.nservers_per_group, conf.updater.clone(), ledger.clone()))
            .collect(),
    );
    // Gaussian init scaled per fan-in (weights) / zero (1-d biases).
    let mut rng = Rng::new(conf.seed);
    for io in spec.params() {
        let init = if io.shape.len() >= 2 {
            let fan_in: usize = io.shape[..io.shape.len() - 1].iter().product();
            Blob::gaussian(&io.shape, (1.0 / (fan_in as f32).sqrt()).min(0.1), &mut rng)
        } else {
            Blob::zeros(&io.shape)
        };
        for sg in servers.iter() {
            sg.put(io.logical(), init.clone(), 1.0, 1.0);
        }
    }

    let log = Arc::new(TrainingLog::new());
    let sw = Stopwatch::new();
    let mut handles = Vec::new();
    for g in 0..topo.nworker_groups {
        let conf = conf.clone();
        let spec = spec.clone();
        let servers = servers.clone();
        let log = log.clone();
        let batcher = batcher.clone();
        let topo = topo.clone();
        let sw = sw.clone();
        handles.push(std::thread::Builder::new().name(format!("xwg{g}")).spawn(
            move || -> Result<()> {
                let mut rt = XlaRuntime::open(&conf.artifact_dir)?;
                let sg = &servers[topo.server_group_of(g)];
                let mut clock = VirtualClock::new();
                // local param cache, ordered per spec
                let mut values: HashMap<String, Blob> = HashMap::new();
                for io in spec.params() {
                    let (v, _) = sg.get(io.logical());
                    values.insert(io.logical().to_string(), v);
                }
                for step in 0..conf.iters {
                    let idx = crate::data::shard_index(step, g, topo.nworker_groups);
                    let data = batcher(idx);
                    // Assemble inputs in manifest order.
                    let inputs: Vec<Blob> = spec
                        .inputs
                        .iter()
                        .map(|io| {
                            if io.is_param() {
                                values[io.logical()].clone()
                            } else {
                                data.get(&io.name)
                                    .unwrap_or_else(|| {
                                        panic!("batcher missing input '{}'", io.name)
                                    })
                                    .clone()
                            }
                        })
                        .collect();
                    let refs: Vec<&Blob> = inputs.iter().collect();
                    let t = Stopwatch::new();
                    let outs = rt.execute(&conf.artifact, &refs)?;
                    clock.advance(t.elapsed_us());
                    let loss = outs[0].data()[0];
                    // Ship each grad:* output to the server; refresh values.
                    let mut bytes = 0usize;
                    for (o, io) in outs.iter().zip(&spec.outputs) {
                        if io.is_grad() {
                            bytes += 2 * o.byte_size() + 128;
                            let (fresh, _) = sg.update(io.logical(), o, step);
                            values.insert(io.logical().to_string(), fresh);
                        }
                    }
                    clock.transfer(&conf.cost.intra_node, bytes);
                    if step % conf.log_every == 0 || step + 1 == conf.iters {
                        log.push(Record {
                            group: g,
                            step,
                            wall_ms: sw.elapsed_ms(),
                            virt_ms: clock.ms(),
                            loss,
                            metric: 0.0,
                        });
                    }
                }
                Ok(())
            },
        )?);
    }
    for h in handles {
        h.join().expect("xla worker panicked")?;
    }

    let mut params = HashMap::new();
    for name in servers[0].param_names() {
        params.insert(name.clone(), servers[0].get(&name).0);
    }
    Ok(XlaJobReport { log, ledger, wall_ms: sw.elapsed_ms(), params })
}

/// Batcher adapter: integer labels → one-hot, pass-through otherwise.
pub fn onehot_batcher(
    src: Arc<dyn crate::data::DataSource>,
    batch: usize,
    classes: usize,
    data_key: &str,
    label_key: &str,
) -> Batcher {
    let data_key = data_key.to_string();
    let label_key = label_key.to_string();
    Arc::new(move |idx| {
        let mut m = src.batch(idx, batch);
        let labels = m.remove("label").expect("source must provide 'label'");
        let rows = labels.len();
        let mut oh = Blob::zeros(&[rows, classes]);
        for (r, &l) in labels.data().iter().enumerate() {
            oh.data_mut()[r * classes + l as usize] = 1.0;
        }
        let mut out = HashMap::new();
        let data = m.remove("data").expect("source must provide 'data'");
        // flatten NCHW to [b, dim] if the artifact expects 2-d data
        out.insert(data_key.clone(), data);
        out.insert(label_key.clone(), oh);
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDigits;

    fn ready() -> bool {
        XlaRuntime::default_dir().join("manifest.json").exists()
    }

    /// End-to-end three-layer smoke: L3 coordinator + PJRT runtime + the
    /// AOT-compiled JAX/Pallas MLP — loss must drop under SGD.
    #[test]
    fn xla_mlp_training_reduces_loss() {
        if !ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut conf = XlaJobConf::new("mlp_step");
        conf.iters = 12;
        conf.updater = UpdaterConf::sgd(0.3);
        let src = Arc::new(SyntheticDigits::new(784, 10, 5));
        let batcher = onehot_batcher(src, 32, 10, "data", "label_onehot");
        let report = run_xla_job(&conf, batcher).unwrap();
        let recs = report.log.snapshot();
        assert_eq!(recs.len(), 12);
        let first = recs.first().unwrap().loss;
        let last = recs.last().unwrap().loss;
        assert!(
            last < 0.6 * first,
            "XLA training should reduce loss: {first} -> {last}"
        );
        assert!(report.ledger.param_bytes() > 0);
    }
}
