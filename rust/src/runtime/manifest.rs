//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime — which HLO file implements which step function, and the
//! names/shapes/dtypes of its inputs and outputs.
//!
//! Also the home of the *kernel provenance* line: which microkernel family
//! (`PALLAS_KERNEL` request, detected CPU features, chosen path) produced
//! a process's numbers. [`log_kernel_once`] emits it once at kernel
//! resolution, and the bench writers embed [`kernel_json`] in every
//! `BENCH_*.json` artifact so recorded figures stay attributable.

use crate::tensor::kernel::KernelChoice;
use crate::utils::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Once;

/// Human-readable one-liner describing a kernel resolution.
pub fn kernel_line(c: &KernelChoice) -> String {
    let mut s = format!(
        "kernel dispatch: requested={} avx2_fma={} chosen={}",
        c.requested,
        c.avx2_fma,
        c.chosen.name()
    );
    if let Some(note) = &c.note {
        s.push_str(" (");
        s.push_str(note);
        s.push(')');
    }
    s
}

/// JSON object fragment recording a kernel resolution in bench artifacts.
/// All fields are closed-vocabulary strings/bools (sanitized in
/// [`crate::tensor::kernel::resolve`]), so no escaping is needed.
pub fn kernel_json(c: &KernelChoice) -> String {
    format!(
        "{{\"requested\": \"{}\", \"avx2_fma\": {}, \"chosen\": \"{}\"}}",
        c.requested,
        c.avx2_fma,
        c.chosen.name()
    )
}

/// Log the resolved kernel once per process (stderr, like the pool's
/// diagnostics) — called by [`crate::runtime::kernel_choice`] at first
/// resolution so every bench/CI log records which kernel ran.
pub fn log_kernel_once(c: &KernelChoice) {
    static LOGGED: Once = Once::new();
    LOGGED.call_once(|| eprintln!("[runtime] {}", kernel_line(c)));
}

/// One input or output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// `param:<name>` inputs come from the parameter server; `grad:<name>`
    /// outputs go back to it; everything else is batch data.
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn is_param(&self) -> bool {
        self.name.starts_with("param:")
    }

    pub fn is_grad(&self) -> bool {
        self.name.starts_with("grad:")
    }

    /// Logical parameter name without the role prefix.
    pub fn logical(&self) -> &str {
        self.name
            .strip_prefix("param:")
            .or_else(|| self.name.strip_prefix("grad:"))
            .unwrap_or(&self.name)
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled step function.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Parameter inputs in order.
    pub fn params(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|i| i.is_param()).collect()
    }

    /// Data (non-param) inputs in order.
    pub fn data_inputs(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|i| !i.is_param()).collect()
    }

    /// Index of the first output named `name`.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts' object"))?;
        let mut out = BTreeMap::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}': missing file"))?
                .to_string();
            let ios = |key: &str| -> Result<Vec<IoSpec>> {
                spec.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact '{name}': missing {key}"))?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("io missing name"))?
                                .to_string(),
                            shape: io
                                .get("shape")
                                .map(Json::usize_vec)
                                .ok_or_else(|| anyhow!("io missing shape"))?,
                            dtype: io
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string(),
                        })
                    })
                    .collect()
            };
            out.insert(
                name.clone(),
                ArtifactSpec { file, inputs: ios("inputs")?, outputs: ios("outputs")? },
            );
        }
        Ok(Manifest { artifacts: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "mlp_step": {
          "file": "mlp_step.hlo.txt",
          "inputs": [
            {"name": "param:mlp/w0", "shape": [784, 256], "dtype": "float32"},
            {"name": "data", "shape": [32, 784], "dtype": "float32"},
            {"name": "chars", "shape": [16, 20], "dtype": "int32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "float32"},
            {"name": "grad:mlp/w0", "shape": [784, 256], "dtype": "float32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["mlp_step"];
        assert_eq!(a.file, "mlp_step.hlo.txt");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.params().len(), 1);
        assert_eq!(a.data_inputs().len(), 2);
        assert_eq!(a.params()[0].logical(), "mlp/w0");
        assert_eq!(a.inputs[2].dtype, "int32");
        assert_eq!(a.output_index("grad:mlp/w0"), Some(1));
        assert!(a.outputs[1].is_grad());
        assert_eq!(a.outputs[1].logical(), "mlp/w0");
        assert_eq!(a.inputs[0].elements(), 784 * 256);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("{\"artifacts\": {\"x\": {}}}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn kernel_line_and_json_record_the_resolution() {
        let c = crate::tensor::kernel::resolve(Some("simd"), true);
        let line = kernel_line(&c);
        assert!(line.contains("requested=simd"), "{line}");
        assert!(line.contains("avx2_fma=true"), "{line}");
        assert!(line.contains("chosen=simd"), "{line}");
        let j = kernel_json(&c);
        let doc = Json::parse(&j).expect("kernel json parses");
        assert_eq!(doc.get("requested").and_then(Json::as_str), Some("simd"));
        assert_eq!(doc.get("chosen").and_then(Json::as_str), Some("simd"));

        let fallback = crate::tensor::kernel::resolve(Some("simd"), false);
        let line = kernel_line(&fallback);
        assert!(line.contains("chosen=scalar"), "{line}");
        assert!(line.contains("falling back"), "{line}");
        let doc = Json::parse(&kernel_json(&fallback)).unwrap();
        assert_eq!(doc.get("chosen").and_then(Json::as_str), Some("scalar"));
    }
}
