//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime — which HLO file implements which step function, and the
//! names/shapes/dtypes of its inputs and outputs.

use crate::utils::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One input or output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// `param:<name>` inputs come from the parameter server; `grad:<name>`
    /// outputs go back to it; everything else is batch data.
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn is_param(&self) -> bool {
        self.name.starts_with("param:")
    }

    pub fn is_grad(&self) -> bool {
        self.name.starts_with("grad:")
    }

    /// Logical parameter name without the role prefix.
    pub fn logical(&self) -> &str {
        self.name
            .strip_prefix("param:")
            .or_else(|| self.name.strip_prefix("grad:"))
            .unwrap_or(&self.name)
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled step function.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Parameter inputs in order.
    pub fn params(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|i| i.is_param()).collect()
    }

    /// Data (non-param) inputs in order.
    pub fn data_inputs(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|i| !i.is_param()).collect()
    }

    /// Index of the first output named `name`.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts' object"))?;
        let mut out = BTreeMap::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}': missing file"))?
                .to_string();
            let ios = |key: &str| -> Result<Vec<IoSpec>> {
                spec.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact '{name}': missing {key}"))?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("io missing name"))?
                                .to_string(),
                            shape: io
                                .get("shape")
                                .map(Json::usize_vec)
                                .ok_or_else(|| anyhow!("io missing shape"))?,
                            dtype: io
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string(),
                        })
                    })
                    .collect()
            };
            out.insert(
                name.clone(),
                ArtifactSpec { file, inputs: ios("inputs")?, outputs: ios("outputs")? },
            );
        }
        Ok(Manifest { artifacts: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "mlp_step": {
          "file": "mlp_step.hlo.txt",
          "inputs": [
            {"name": "param:mlp/w0", "shape": [784, 256], "dtype": "float32"},
            {"name": "data", "shape": [32, 784], "dtype": "float32"},
            {"name": "chars", "shape": [16, 20], "dtype": "int32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "float32"},
            {"name": "grad:mlp/w0", "shape": [784, 256], "dtype": "float32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["mlp_step"];
        assert_eq!(a.file, "mlp_step.hlo.txt");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.params().len(), 1);
        assert_eq!(a.data_inputs().len(), 2);
        assert_eq!(a.params()[0].logical(), "mlp/w0");
        assert_eq!(a.inputs[2].dtype, "int32");
        assert_eq!(a.output_index("grad:mlp/w0"), Some(1));
        assert!(a.outputs[1].is_grad());
        assert_eq!(a.outputs[1].logical(), "mlp/w0");
        assert_eq!(a.inputs[0].elements(), 784 * 256);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("{\"artifacts\": {\"x\": {}}}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
