//! Runtime: the native backend's execution substrate — the persistent
//! intra-op worker [`pool`], the thread-budget policy behind [`threads`] —
//! plus the loader that executes AOT-compiled XLA artifacts (L2 models with
//! L1 Pallas kernels lowered in) from the rust hot path via the PJRT C API.
//!
//! # Intra-op pool lifecycle
//!
//! [`pool`] owns a process-global set of parked worker threads, spawned
//! lazily on the first parallel kernel dispatch and capped at
//! `cores - 1` (the calling thread is always the extra compute thread).
//! Workers park on a condvar between dispatches and are never torn down:
//! a steady-state training loop dispatches thousands of panels without
//! creating a single thread (the soak suite in `tests/pool.rs` pins both
//! the stable worker count and the zero-allocation counters across mixed
//! gemm + conv traffic).
//!
//! # `PALLAS_NUM_THREADS` semantics
//!
//! [`threads`] resolves the per-kernel *task* count:
//!
//! * **Explicit value wins.** `PALLAS_NUM_THREADS=N` (N ≥ 1) always yields
//!   `N`, regardless of worker groups; `1` selects the exact serial code
//!   path (no pool machinery touched); `0`/garbage fall back to `1`.
//! * **Unset → divided core budget.** `available_parallelism` divided by
//!   the number of *active coordinator worker groups* (registered via
//!   [`register_worker_group`] for the duration of a job), min 1 — so `W`
//!   groups × intra-op parallelism never oversubscribes the machine.
//!
//! The pool additionally clamps real thread usage at the OS level: task
//! counts beyond the worker cap queue instead of spawning, so even a
//! deliberately oversubscribed `PALLAS_NUM_THREADS` degrades gracefully.
//!
//! # `PALLAS_KERNEL` semantics
//!
//! [`kernel`] resolves which microkernel family the tensor hot loops
//! dispatch on ([`crate::tensor::kernel`]):
//!
//! * **`scalar` / unset** — the portable autovectorized oracle (default;
//!   preserves today's bit patterns exactly).
//! * **`simd`** — explicit AVX2/FMA microkernels when the CPU has them,
//!   otherwise a logged fallback to scalar.
//! * **`auto`** — simd iff detected, silently.
//!
//! The choice is resolved once per process ([`kernel_choice`]) and logged
//! through [`manifest::log_kernel_once`] so bench artifacts and CI logs
//! record which kernel produced each number. [`with_kernel`] scopes a
//! per-thread override for in-process probes (the env knob resolves only
//! once). GEMM under simd trades the scalar bit pattern for FMA register
//! tiles (approximately equal, pinned by property tests); the conv
//! transforms stay bitwise identical under either kind, and the
//! per-thread-count determinism contract holds within each kind.
//!
//! # Determinism contract
//!
//! The knob (and the group division) only affect *speed*: every parallel
//! kernel partitions work by task index into regions whose per-element
//! float-operation sequence is identical to the serial path, so results
//! are **bit-for-bit identical at every thread count**. Changing budgets —
//! statically via the environment or dynamically via group registration —
//! can never change a training trajectory.
//!
//! # XLA artifacts
//!
//! `python/compile/aot.py` writes `artifacts/*.hlo.txt` plus
//! `manifest.json`; [`XlaRuntime`] compiles each HLO module once on the
//! PJRT CPU client and serves typed executions. Interchange is HLO *text*
//! (see /opt/xla-example/README.md: jax≥0.5 protos have 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! [`device`] models accelerator devices (compute-rate multiplier + PCIe
//! transfer link) for the GPU-era experiments on this CPU-only testbed.

pub mod device;
pub mod manifest;
pub mod pool;
pub mod sync;
pub mod xla_job;

use crate::tensor::kernel::{simd_supported, KernelChoice, KernelKind};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Active coordinator worker groups (see [`register_worker_group`]).
static ACTIVE_WORKER_GROUPS: AtomicUsize = AtomicUsize::new(0);

/// Number of intra-op tasks for the native backend's parallel kernels (the
/// tiled GEMM in [`crate::tensor::gemm`] and the im2col/col2im stripes in
/// [`crate::tensor::conv`]).
///
/// See the module docs for the full policy: an explicit
/// `PALLAS_NUM_THREADS` value wins; unset divides the core budget by the
/// active worker-group count. The value only affects speed — the kernels
/// are bit-for-bit identical to serial at every count — so it is safe for
/// this to change dynamically as groups come and go.
pub fn threads() -> usize {
    threads_policy(explicit_env(), cores(), active_worker_groups())
}

/// Pure resolution of the thread-budget policy (split out so tests can
/// exercise the arithmetic without mutating process environment):
/// * explicit positive integer (whitespace tolerated) → that count;
/// * explicit `0` or anything unparsable → 1 (predictable serial fallback);
/// * unset → `cores / groups` (each divisor at least 1), min 1.
pub fn threads_policy(env: Option<&str>, cores: usize, groups: usize) -> usize {
    match env {
        Some(s) => s.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or(1),
        None => (cores.max(1) / groups.max(1)).max(1),
    }
}

/// [`threads_policy`] against this machine's cores with no worker groups —
/// the historical single-job resolution of `PALLAS_NUM_THREADS`.
pub fn threads_from(env: Option<&str>) -> usize {
    threads_policy(env, cores(), 1)
}

/// Cached `available_parallelism` (min 1).
pub fn cores() -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    *CORES.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Cached one-shot read of `PALLAS_NUM_THREADS` (the raw string; parsing
/// stays in [`threads_policy`] so garbage handling is uniform).
fn explicit_env() -> Option<&'static str> {
    static EXPLICIT: OnceLock<Option<String>> = OnceLock::new();
    EXPLICIT.get_or_init(|| std::env::var("PALLAS_NUM_THREADS").ok()).as_deref()
}

/// Worker groups currently registered by the coordinator.
pub fn active_worker_groups() -> usize {
    ACTIVE_WORKER_GROUPS.load(Ordering::Relaxed)
}

/// The process-wide kernel resolution: `PALLAS_KERNEL` (read once) against
/// runtime CPU detection, logged on first use through
/// [`manifest::log_kernel_once`].
pub fn kernel_choice() -> &'static KernelChoice {
    static CHOICE: OnceLock<KernelChoice> = OnceLock::new();
    CHOICE.get_or_init(|| {
        let choice = crate::tensor::kernel::resolve(kernel_env(), simd_supported());
        manifest::log_kernel_once(&choice);
        choice
    })
}

/// Cached one-shot read of `PALLAS_KERNEL` (raw string; parsing stays in
/// [`crate::tensor::kernel::resolve`] so garbage handling is uniform).
fn kernel_env() -> Option<&'static str> {
    static EXPLICIT: OnceLock<Option<String>> = OnceLock::new();
    EXPLICIT.get_or_init(|| std::env::var("PALLAS_KERNEL").ok()).as_deref()
}

thread_local! {
    /// Scoped per-thread override installed by [`with_kernel`].
    static KERNEL_OVERRIDE: Cell<Option<KernelKind>> = const { Cell::new(None) };
}

/// The microkernel kind for tensor hot loops on the *calling* thread:
/// a [`with_kernel`] override if one is active, else the process-wide
/// [`kernel_choice`]. Kernels resolve this once per call on the caller
/// thread and hand the kind to their workers, so one call never mixes
/// families.
pub fn kernel() -> KernelKind {
    KERNEL_OVERRIDE.with(|o| o.get()).unwrap_or_else(|| kernel_choice().chosen)
}

/// Run `f` with this thread's kernel dispatch forced to `kind` (restored
/// on exit, panic-safe). `Simd` is sanitized back to `Scalar` when the
/// host lacks AVX2+FMA, mirroring the env-knob fallback, so probes can
/// request simd unconditionally. Used by the alloc/scaling probes to
/// exercise both families in one process — the env knob resolves only
/// once.
pub fn with_kernel<R>(kind: KernelKind, f: impl FnOnce() -> R) -> R {
    let kind = if kind == KernelKind::Simd && !simd_supported() {
        KernelKind::Scalar
    } else {
        kind
    };
    struct Restore(Option<KernelKind>);
    impl Drop for Restore {
        fn drop(&mut self) {
            KERNEL_OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let _restore = Restore(KERNEL_OVERRIDE.with(|o| o.replace(Some(kind))));
    f()
}

/// RAII registration of one coordinator worker group for thread budgeting:
/// while the guard lives, the default (env-unset) intra-op budget is
/// divided by the active group count, so `W` concurrent groups share the
/// machine instead of each claiming every core. The coordinator registers
/// one guard per group for the duration of a job; dropping restores the
/// budget. An explicit `PALLAS_NUM_THREADS` is never divided.
pub struct WorkerGroupGuard {
    _priv: (),
}

/// Register one worker group; see [`WorkerGroupGuard`].
pub fn register_worker_group() -> WorkerGroupGuard {
    ACTIVE_WORKER_GROUPS.fetch_add(1, Ordering::Relaxed);
    WorkerGroupGuard { _priv: () }
}

impl Drop for WorkerGroupGuard {
    fn drop(&mut self) {
        ACTIVE_WORKER_GROUPS.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod thread_knob_tests {
    use super::*;

    #[test]
    fn explicit_counts_parse() {
        assert_eq!(threads_from(Some("4")), 4);
        assert_eq!(threads_from(Some(" 7 ")), 7);
        assert_eq!(threads_from(Some("1")), 1);
    }

    #[test]
    fn zero_and_garbage_fall_back_to_serial() {
        assert_eq!(threads_from(Some("0")), 1);
        assert_eq!(threads_from(Some("")), 1);
        assert_eq!(threads_from(Some("lots")), 1);
        assert_eq!(threads_from(Some("-3")), 1);
    }

    #[test]
    fn unset_uses_available_parallelism() {
        assert!(threads_from(None) >= 1);
    }

    #[test]
    fn policy_divides_cores_by_active_groups_when_unset() {
        assert_eq!(threads_policy(None, 8, 2), 4);
        assert_eq!(threads_policy(None, 8, 3), 2);
        assert_eq!(threads_policy(None, 9, 2), 4);
        assert_eq!(threads_policy(None, 4, 8), 1, "budget floors at 1");
        assert_eq!(threads_policy(None, 8, 0), 8, "no groups = whole machine");
        assert_eq!(threads_policy(None, 0, 0), 1);
    }

    #[test]
    fn policy_explicit_value_wins_over_group_division() {
        assert_eq!(threads_policy(Some("6"), 8, 4), 6);
        assert_eq!(threads_policy(Some("1"), 64, 2), 1);
        assert_eq!(threads_policy(Some("0"), 8, 4), 1);
        assert_eq!(threads_policy(Some("64"), 4, 2), 64, "oversubscription is allowed explicitly");
    }

    #[test]
    fn getter_is_positive() {
        // Other tests register/drop groups concurrently, so only monotone
        // facts hold here; the pure policy tests pin the arithmetic.
        assert!(threads() >= 1);
        assert!(cores() >= 1);
    }

    /// Saturating the registry must drive the env-unset budget to 1 while
    /// an explicit env value stays untouched — robust against the handful
    /// of groups concurrent coordinator tests may add or remove.
    #[test]
    fn many_registered_groups_shrink_the_default_budget() {
        let guards: Vec<WorkerGroupGuard> = (0..1000).map(|_| register_worker_group()).collect();
        assert!(active_worker_groups() >= 990);
        match std::env::var("PALLAS_NUM_THREADS") {
            Ok(v) => assert_eq!(threads(), threads_from(Some(&v)), "explicit value wins"),
            Err(_) => assert_eq!(threads(), 1, "cores / ~1000 groups floors at 1"),
        }
        drop(guards);
    }
}

#[cfg(test)]
mod kernel_knob_tests {
    use super::*;

    #[test]
    fn with_kernel_overrides_and_restores() {
        let ambient = kernel();
        assert_eq!(with_kernel(KernelKind::Scalar, kernel), KernelKind::Scalar);
        let forced = with_kernel(KernelKind::Simd, kernel);
        if simd_supported() {
            assert_eq!(forced, KernelKind::Simd);
        } else {
            assert_eq!(forced, KernelKind::Scalar, "sanitized on non-AVX2 hosts");
        }
        assert_eq!(kernel(), ambient, "override restored on exit");
    }

    #[test]
    fn with_kernel_restores_on_panic() {
        let ambient = kernel();
        let r = std::panic::catch_unwind(|| {
            with_kernel(KernelKind::Scalar, || {
                panic!("probe failed");
            })
        });
        assert!(r.is_err());
        assert_eq!(kernel(), ambient, "override restored by the drop guard");
    }

    #[test]
    fn choice_matches_env_and_detection() {
        let c = kernel_choice();
        let expect = match std::env::var("PALLAS_KERNEL") {
            Ok(v) => crate::tensor::kernel::resolve(Some(&v), simd_supported()),
            Err(_) => crate::tensor::kernel::resolve(None, simd_supported()),
        };
        assert_eq!(*c, expect);
        assert!(c.chosen == KernelKind::Scalar || simd_supported(), "simd only when detected");
    }
}

#[cfg(feature = "xla-backend")]
use crate::tensor::Blob;
#[cfg(feature = "xla-backend")]
use anyhow::{anyhow, Context, Result};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
#[cfg(feature = "xla-backend")]
use std::collections::HashMap;
#[cfg(feature = "xla-backend")]
use std::path::{Path, PathBuf};

/// Stub runtime used when the crate is built without the `xla-backend`
/// feature (the offline default: the external `xla` bindings and libxla are
/// not available). `open` always fails with a clear message; every caller
/// already guards on the artifact directory existing, so the native path is
/// unaffected.
#[cfg(not(feature = "xla-backend"))]
mod stub {
    use super::Manifest;
    use crate::tensor::Blob;
    use anyhow::Result;
    use std::path::{Path, PathBuf};

    /// PJRT client + compiled executable cache (stub).
    pub struct XlaRuntime {
        pub manifest: Manifest,
    }

    impl XlaRuntime {
        pub fn open(_dir: &Path) -> Result<XlaRuntime> {
            Err(anyhow::anyhow!(
                "XLA backend not compiled in: rebuild with `--features xla-backend` \
                 (requires the vendored `xla` crate and libxla; see Cargo.toml)"
            ))
        }

        /// Default artifact directory (repo-root `artifacts/`).
        pub fn default_dir() -> PathBuf {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn execute(&mut self, _name: &str, _inputs: &[&Blob]) -> Result<Vec<Blob>> {
            Err(anyhow::anyhow!("XLA backend not compiled in"))
        }
    }
}

#[cfg(not(feature = "xla-backend"))]
pub use stub::XlaRuntime;

/// A compiled artifact ready to execute.
#[cfg(feature = "xla-backend")]
pub struct LoadedStep {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client + compiled executable cache.
#[cfg(feature = "xla-backend")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    loaded: HashMap<String, LoadedStep>,
}

#[cfg(feature = "xla-backend")]
impl XlaRuntime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(XlaRuntime { client, dir: dir.to_path_buf(), manifest, loaded: HashMap::new() })
    }

    /// Default artifact directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&LoadedStep> {
        if !self.loaded.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
            self.loaded.insert(name.to_string(), LoadedStep { spec, exe });
        }
        Ok(&self.loaded[name])
    }

    /// Execute an artifact on f32 blobs ordered per the manifest. Integer
    /// inputs (dtype `int32` in the manifest) are converted from the blob's
    /// f32 values. Returns output blobs ordered per the manifest.
    pub fn execute(&mut self, name: &str, inputs: &[&Blob]) -> Result<Vec<Blob>> {
        self.load(name)?;
        let step = &self.loaded[name];
        let spec = &step.spec;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' wants {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (b, io) in inputs.iter().zip(&spec.inputs) {
            let expect: usize = io.shape.iter().product();
            if b.len() != expect {
                return Err(anyhow!(
                    "input '{}' of '{name}': expected {:?} ({expect}), got {} elements",
                    io.name,
                    io.shape,
                    b.len()
                ));
            }
            let dims: Vec<i64> = io.shape.iter().map(|&d| d as i64).collect();
            let lit = if io.dtype == "int32" {
                let ints: Vec<i32> = b.data().iter().map(|&v| v as i32).collect();
                xla::Literal::vec1(&ints)
            } else {
                xla::Literal::vec1(b.data())
            };
            let lit = if dims.is_empty() {
                lit
            } else {
                lit.reshape(&dims).map_err(|e| anyhow!("reshape input: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = step
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, io) in parts.into_iter().zip(&spec.outputs) {
            let data: Vec<f32> = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output '{}' to_vec: {e:?}", io.name))?;
            let shape = if io.shape.is_empty() { vec![1] } else { io.shape.clone() };
            out.push(Blob::from_vec(&shape, data));
        }
        Ok(out)
    }
}

#[cfg(all(test, feature = "xla-backend"))]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        XlaRuntime::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_and_execution_roundtrip() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = XlaRuntime::open(&XlaRuntime::default_dir()).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        let spec = rt.manifest.artifacts.get("mlp_step").unwrap().clone();
        // Build zero-ish inputs per spec; params get small values.
        let inputs: Vec<Blob> = spec
            .inputs
            .iter()
            .map(|io| {
                let n: usize = io.shape.iter().product();
                if io.name.starts_with("param:") {
                    Blob::from_vec(
                        &io.shape,
                        (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect(),
                    )
                } else if io.name == "label_onehot" {
                    // one-hot rows
                    let classes = io.shape[1];
                    let rows = io.shape[0];
                    let mut v = vec![0.0; n];
                    for r in 0..rows {
                        v[r * classes + r % classes] = 1.0;
                    }
                    Blob::from_vec(&io.shape, v)
                } else {
                    Blob::from_vec(&io.shape, vec![0.1; n])
                }
            })
            .collect();
        let refs: Vec<&Blob> = inputs.iter().collect();
        let outs = rt.execute("mlp_step", &refs).unwrap();
        assert_eq!(outs.len(), spec.outputs.len());
        let loss = outs[0].data()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // grads shaped like params
        for (o, io) in outs.iter().zip(&spec.outputs) {
            if io.name.starts_with("grad:") {
                assert_eq!(o.len(), io.shape.iter().product::<usize>());
            }
        }
    }

    #[test]
    fn wrong_input_count_rejected() {
        if !artifacts_ready() {
            return;
        }
        let mut rt = XlaRuntime::open(&XlaRuntime::default_dir()).unwrap();
        let err = rt.execute("mlp_step", &[]).unwrap_err();
        assert!(err.to_string().contains("inputs"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        if !artifacts_ready() {
            return;
        }
        let mut rt = XlaRuntime::open(&XlaRuntime::default_dir()).unwrap();
        assert!(rt.execute("ghost", &[]).is_err());
    }
}
