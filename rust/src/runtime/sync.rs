//! Rank-ordered lock wrappers with an optional runtime lock-order sanitizer.
//!
//! Every blocking lock in the crate is an [`OrderedMutex`] (paired with
//! [`OrderedCondvar`] where waiting is needed) carrying a **static rank** and
//! a **per-lock ordering key**. The crate-wide invariant, previously asserted
//! only in comments, is:
//!
//! > A thread may acquire a lock only if its rank is **strictly greater**
//! > than every rank it already holds, or — for same-rank families that are
//! > legitimately held together (server shards during `sync_with`) — its
//! > ordering key is strictly greater than every held key of that rank.
//!
//! # Rank table
//!
//! Ascending rank = acquired later while other locks are held. The order is
//! derived from the real nesting in the code (a workspace bucket is held
//! across `ServerGroup::update_into`, which takes route then shard; the
//! checkpointer holds its channel lock while publishing state), not from
//! module layering:
//!
//! | rank | const                   | lock                                      |
//! |------|-------------------------|-------------------------------------------|
//! | 10   | `RANK_WORKSPACE_BUCKET` | `coordinator::workspace` bucket buffers   |
//! | 15   | `RANK_LINK_TIMELINE`    | shared wire timeline of the retry plane   |
//! | 20   | `RANK_SERVER_ROUTE`     | `server` shard routing table              |
//! | 30   | `RANK_SERVER_SHARD`     | `server` parameter shards (keyed)         |
//! | 40   | `RANK_CKPT_CHANNEL`     | checkpointer request channel slot         |
//! | 50   | `RANK_CKPT_STATE`       | checkpointer published state              |
//! | 55   | `RANK_CKPT_WRITER`      | checkpointer writer join-handle slot      |
//! | 60   | `RANK_WARMUP_GATE`      | coordinator warm-up gate                  |
//! | 70   | `RANK_METRICS_LOG`      | `metrics::TrainingLog` records            |
//! | 80   | `RANK_POOL_STATE`       | `runtime::pool` queue state               |
//! | 84   | `RANK_POOL_LATCH`       | `runtime::pool` per-dispatch latch        |
//! | 90   | `RANK_COMPUTE_STRIPE`   | per-task output stripes (gemm/conv/tests) |
//!
//! # Arming
//!
//! The sanitizer is controlled by `PALLAS_SANITIZE`, resolved once:
//!
//! * unset — **on** in debug builds, **off** in release builds;
//! * `0` / `off` — forced off (raw `std::sync` fast path: the only per-op
//!   cost is two relaxed atomic loads and a predictable branch);
//! * `1` / `on` — track held locks, panic on rank/key inversion or on a
//!   cycle in the global site-pair acquisition graph, naming both sites;
//! * `stress[:seed]` — everything `on` does, plus deterministic seeded
//!   yields injected at acquire points to perturb thread schedules.
//!
//! Violations panic with both sites named, e.g.
//! `acquiring `server.route` (rank 20) while holding `pool.latch` (rank 84)`.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Condvar, LockResult, Mutex, MutexGuard, OnceLock, PoisonError, TryLockError};

pub const RANK_WORKSPACE_BUCKET: u16 = 10;
pub const RANK_LINK_TIMELINE: u16 = 15;
pub const RANK_SERVER_ROUTE: u16 = 20;
pub const RANK_SERVER_SHARD: u16 = 30;
pub const RANK_CKPT_CHANNEL: u16 = 40;
pub const RANK_CKPT_STATE: u16 = 50;
pub const RANK_CKPT_WRITER: u16 = 55;
pub const RANK_WARMUP_GATE: u16 = 60;
pub const RANK_METRICS_LOG: u16 = 70;
pub const RANK_POOL_STATE: u16 = 80;
pub const RANK_POOL_LATCH: u16 = 84;
pub const RANK_COMPUTE_STRIPE: u16 = 90;

/// Sanitizer mode, resolved once from `PALLAS_SANITIZE` (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Off,
    On,
    /// `On` plus deterministic seeded yields at acquire points.
    Stress { seed: u64 },
}

/// Decide the mode from the raw env value and the build profile. Pure policy
/// (unit-tested); [`mode`] caches the result of applying it to the process
/// environment.
pub fn mode_policy(env: Option<&str>, debug_build: bool) -> Mode {
    match env.map(str::trim) {
        None => {
            if debug_build {
                Mode::On
            } else {
                Mode::Off
            }
        }
        Some("0") | Some("off") | Some("") => Mode::Off,
        Some(s) if s == "stress" || s.starts_with("stress:") => {
            let seed = s
                .strip_prefix("stress:")
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(0x9E37_79B9_7F4A_7C15);
            Mode::Stress { seed }
        }
        // "1", "on", and anything unrecognized arm the plain sanitizer —
        // a typo in the knob should never silently disarm it.
        Some(_) => Mode::On,
    }
}

/// The process-wide sanitizer mode (env resolved once).
pub fn mode() -> Mode {
    match OVERRIDE.load(Ordering::Relaxed) {
        OVR_NONE => {}
        OVR_OFF => return Mode::Off,
        OVR_ON => return Mode::On,
        _ => return Mode::Stress { seed: override_seed() },
    }
    static MODE: OnceLock<Mode> = OnceLock::new();
    *MODE.get_or_init(|| {
        mode_policy(std::env::var("PALLAS_SANITIZE").ok().as_deref(), cfg!(debug_assertions))
    })
}

const OVR_NONE: u8 = 0;
const OVR_OFF: u8 = 1;
const OVR_ON: u8 = 2;
const OVR_STRESS: u8 = 3;
static OVERRIDE: AtomicU8 = AtomicU8::new(OVR_NONE);
static OVERRIDE_SEED: AtomicU64 = AtomicU64::new(0);

fn override_seed() -> u64 {
    OVERRIDE_SEED.load(Ordering::Relaxed)
}

/// Force a mode for the current process, bypassing the cached env decision.
/// Test-only escape hatch (the sanitizer's own tests must run armed even in
/// `--release` test runs, and integration tests force `stress`
/// deterministically instead of relying on the harness environment).
/// `None` restores the env-derived mode.
pub fn override_mode_for_tests(m: Option<Mode>) {
    match m {
        None => OVERRIDE.store(OVR_NONE, Ordering::Relaxed),
        Some(Mode::Off) => OVERRIDE.store(OVR_OFF, Ordering::Relaxed),
        Some(Mode::On) => OVERRIDE.store(OVR_ON, Ordering::Relaxed),
        Some(Mode::Stress { seed }) => {
            OVERRIDE_SEED.store(seed, Ordering::Relaxed);
            OVERRIDE.store(OVR_STRESS, Ordering::Relaxed);
        }
    }
}

/// Static identity of one lock: rank, ordering key, and a site label used in
/// violation reports and as the node id of the acquisition-order graph.
#[derive(Debug)]
struct LockMeta {
    rank: u16,
    key: u64,
    site: &'static str,
}

/// Auto-assigned ordering keys start far above any explicit key a caller
/// would construct (`server` uses `group_id << 16 | shard`), so the two
/// schemes never interleave within a rank class by accident.
const AUTO_KEY_BASE: u64 = 1 << 40;
static NEXT_AUTO_KEY: AtomicU64 = AtomicU64::new(AUTO_KEY_BASE);

fn auto_key() -> u64 {
    NEXT_AUTO_KEY.fetch_add(1, Ordering::Relaxed)
}

/// A mutex carrying a static rank + ordering key, checked by the sanitizer
/// when armed. API mirrors `std::sync::Mutex` (`lock` returns a
/// `LockResult`, poisoning included) so call sites migrate unchanged.
pub struct OrderedMutex<T> {
    meta: LockMeta,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// A lock with an auto-assigned ordering key (creation order). Use when
    /// no two locks of this rank are ever held together.
    pub fn new(rank: u16, site: &'static str, value: T) -> OrderedMutex<T> {
        OrderedMutex {
            meta: LockMeta { rank, key: auto_key(), site },
            inner: Mutex::new(value),
        }
    }

    /// A lock with an explicit ordering key, for same-rank families that are
    /// held together and must therefore be acquired in ascending-key order
    /// (e.g. server shards keyed `(group_id << 16) | shard_index`).
    pub fn with_key(rank: u16, site: &'static str, key: u64, value: T) -> OrderedMutex<T> {
        debug_assert!(key < AUTO_KEY_BASE, "explicit keys live below AUTO_KEY_BASE");
        OrderedMutex { meta: LockMeta { rank, key, site }, inner: Mutex::new(value) }
    }

    pub fn lock(&self) -> LockResult<OrderedMutexGuard<'_, T>> {
        let tracked = sanitizer::before_acquire(&self.meta);
        let (inner, poisoned) = match self.inner.lock() {
            Ok(g) => (g, false),
            Err(p) => (p.into_inner(), true),
        };
        if tracked {
            sanitizer::on_acquired(&self.meta);
        }
        let guard = OrderedMutexGuard { inner: Some(inner), meta: &self.meta, tracked };
        if poisoned {
            Err(PoisonError::new(guard))
        } else {
            Ok(guard)
        }
    }

    /// Non-blocking acquire: tracked in the held set but exempt from the
    /// ordering check (a `try_lock` that would invert merely fails, it
    /// cannot deadlock).
    pub fn try_lock(&self) -> Result<OrderedMutexGuard<'_, T>, TryLockError<()>> {
        let tracked = mode() != Mode::Off;
        match self.inner.try_lock() {
            Ok(g) => {
                if tracked {
                    sanitizer::on_acquired(&self.meta);
                }
                Ok(OrderedMutexGuard { inner: Some(g), meta: &self.meta, tracked })
            }
            Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            Err(TryLockError::Poisoned(_)) => {
                Err(TryLockError::Poisoned(PoisonError::new(())))
            }
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut d = f.debug_struct("OrderedMutex");
        d.field("rank", &self.meta.rank).field("site", &self.meta.site);
        match self.inner.try_lock() {
            Ok(g) => d.field("data", &&*g),
            Err(_) => d.field("data", &"<locked>"),
        };
        d.finish()
    }
}

/// RAII guard for [`OrderedMutex`]; releases the lock and pops the held-set
/// token on drop. The `Option` exists so [`OrderedCondvar::wait`] can take
/// the inner guard without double-releasing.
pub struct OrderedMutexGuard<'a, T> {
    inner: Option<MutexGuard<'a, T>>,
    meta: &'a LockMeta,
    tracked: bool,
}

impl<T> std::ops::Deref for OrderedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the lock until dropped or waited")
    }
}

impl<T> std::ops::DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the lock until dropped or waited")
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() && self.tracked {
            sanitizer::on_release(self.meta);
        }
    }
}

/// Condvar paired with [`OrderedMutex`]: `wait` pops the lock's held-set
/// token for the duration of the sleep and re-checks + re-pushes on wake
/// (re-acquisition while holding other locks is still an ordering event).
pub struct OrderedCondvar {
    inner: Condvar,
}

impl Default for OrderedCondvar {
    fn default() -> OrderedCondvar {
        OrderedCondvar::new()
    }
}

impl OrderedCondvar {
    pub const fn new() -> OrderedCondvar {
        OrderedCondvar { inner: Condvar::new() }
    }

    pub fn wait<'a, T>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
    ) -> LockResult<OrderedMutexGuard<'a, T>> {
        let meta = guard.meta;
        let tracked = guard.tracked;
        let inner = guard.inner.take().expect("guard holds the lock until dropped or waited");
        drop(guard); // inner is None: releases nothing, pops nothing
        if tracked {
            sanitizer::on_release(meta);
        }
        let (inner, poisoned) = match self.inner.wait(inner) {
            Ok(g) => (g, false),
            Err(p) => (p.into_inner(), true),
        };
        if tracked {
            // Re-acquisition after the sleep is an ordering event too: the
            // waiter may hold other locks across the wait.
            sanitizer::before_acquire(meta);
            sanitizer::on_acquired(meta);
        }
        let guard = OrderedMutexGuard { inner: Some(inner), meta, tracked };
        if poisoned {
            Err(PoisonError::new(guard))
        } else {
            Ok(guard)
        }
    }

    /// [`OrderedCondvar::wait`] with a real-time upper bound: the second
    /// tuple field reports whether the sleep timed out. Used by the armed
    /// exchange to bound a worker's per-bucket wait, so a wedged comm
    /// driver can never hang the forward pass silently.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> LockResult<(OrderedMutexGuard<'a, T>, bool)> {
        let meta = guard.meta;
        let tracked = guard.tracked;
        let inner = guard.inner.take().expect("guard holds the lock until dropped or waited");
        drop(guard); // inner is None: releases nothing, pops nothing
        if tracked {
            sanitizer::on_release(meta);
        }
        let (inner, timed_out, poisoned) = match self.inner.wait_timeout(inner, dur) {
            Ok((g, t)) => (g, t.timed_out(), false),
            Err(p) => {
                let (g, t) = p.into_inner();
                (g, t.timed_out(), true)
            }
        };
        if tracked {
            sanitizer::before_acquire(meta);
            sanitizer::on_acquired(meta);
        }
        let guard = OrderedMutexGuard { inner: Some(inner), meta, tracked };
        if poisoned {
            Err(PoisonError::new((guard, timed_out)))
        } else {
            Ok((guard, timed_out))
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

/// The sanitizer proper: per-thread held-lock sets, the global site-pair
/// acquisition graph, and the stress-mode yield injector. Everything here is
/// reached only when [`mode`] is not `Off`.
mod sanitizer {
    use super::*;

    #[derive(Clone, Copy)]
    struct Held {
        rank: u16,
        key: u64,
        site: &'static str,
    }

    thread_local! {
        static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
        /// Site pairs this thread has already reported to the global graph;
        /// keeps the global mutex off the steady-state armed path.
        static KNOWN_EDGES: RefCell<HashSet<(usize, usize)>> = RefCell::new(HashSet::new());
    }

    /// Global acquisition-order graph over site labels: an edge `a -> b`
    /// means some thread acquired `b` while holding `a`. A cycle means two
    /// code paths disagree about lock order even if each individually
    /// respects some ranking.
    struct Graph {
        adj: HashMap<&'static str, HashSet<&'static str>>,
    }

    fn graph() -> &'static Mutex<Graph> {
        static GRAPH: OnceLock<Mutex<Graph>> = OnceLock::new();
        GRAPH.get_or_init(|| Mutex::new(Graph { adj: HashMap::new() }))
    }

    /// Deterministic per-acquire yield decision for stress mode: a splitmix64
    /// hash of (seed, global acquire counter) — reproducible for a given
    /// interleaving-free workload, schedule-perturbing for a concurrent one.
    fn stress_yield(seed: u64) {
        static ACQUIRES: AtomicU64 = AtomicU64::new(0);
        let n = ACQUIRES.fetch_add(1, Ordering::Relaxed);
        let mut z = seed.wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        match z % 4 {
            0 => std::thread::yield_now(),
            1 => {
                // A slightly longer perturbation than yield_now: enough to
                // let a racing thread win the lock, short enough to keep the
                // stress suites fast.
                std::thread::sleep(std::time::Duration::from_micros(z % 50));
            }
            _ => {}
        }
    }

    /// Run the ordering checks for `meta` against this thread's held set.
    /// Returns whether the sanitizer is armed (the caller threads that bool
    /// through the guard so push/pop stay balanced even if the mode override
    /// flips mid-hold). Panics on violation.
    pub(super) fn before_acquire(meta: &LockMeta) -> bool {
        let m = mode();
        if m == Mode::Off {
            return false;
        }
        if let Mode::Stress { seed } = m {
            stress_yield(seed);
        }
        HELD.with(|held| {
            let held = held.borrow();
            if held.is_empty() {
                return;
            }
            record_edges(&held, meta);
            for h in held.iter() {
                let inverted = h.rank > meta.rank || (h.rank == meta.rank && h.key >= meta.key);
                if inverted {
                    panic!(
                        "PALLAS_SANITIZE: lock-order violation: acquiring `{}` (rank {}, key {:#x}) \
                         while holding `{}` (rank {}, key {:#x}) — locks must be taken in ascending \
                         (rank, key) order; see the rank table in runtime::sync",
                        meta.site, meta.rank, meta.key, h.site, h.rank, h.key
                    );
                }
            }
        });
        true
    }

    /// Record `held -> meta` site pairs in the global graph, panicking if a
    /// new edge closes a cycle (a path `meta.site -> ... -> held.site`
    /// already exists from some other code path).
    fn record_edges(held: &[Held], meta: &LockMeta) {
        for h in held {
            if h.site == meta.site {
                // Same-site families (shards, stripes, buckets) are ordered
                // by key, not by the graph; a self-edge would be a false
                // cycle.
                continue;
            }
            let pair = (h.site.as_ptr() as usize, meta.site.as_ptr() as usize);
            let fresh = KNOWN_EDGES.with(|known| known.borrow_mut().insert(pair));
            if !fresh {
                continue;
            }
            let mut g = graph().lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(path) = path_between(&g.adj, meta.site, h.site) {
                panic!(
                    "PALLAS_SANITIZE: acquisition-order cycle: acquiring `{}` (rank {}) while \
                     holding `{}` (rank {}) closes the cycle {} -> `{}`",
                    meta.site,
                    meta.rank,
                    h.site,
                    h.rank,
                    path.iter().map(|s| format!("`{s}`")).collect::<Vec<_>>().join(" -> "),
                    meta.site,
                );
            }
            g.adj.entry(h.site).or_default().insert(meta.site);
        }
    }

    /// DFS: a path `from -> ... -> to` in the acquisition graph, if any.
    fn path_between(
        adj: &HashMap<&'static str, HashSet<&'static str>>,
        from: &'static str,
        to: &'static str,
    ) -> Option<Vec<&'static str>> {
        let mut stack = vec![vec![from]];
        let mut seen: HashSet<&str> = HashSet::new();
        while let Some(path) = stack.pop() {
            let last = *path.last().expect("paths are non-empty by construction");
            if last == to {
                return Some(path);
            }
            if !seen.insert(last) {
                continue;
            }
            if let Some(next) = adj.get(last) {
                for &n in next {
                    let mut p = path.clone();
                    p.push(n);
                    stack.push(p);
                }
            }
        }
        None
    }

    pub(super) fn on_acquired(meta: &LockMeta) {
        HELD.with(|held| {
            held.borrow_mut().push(Held { rank: meta.rank, key: meta.key, site: meta.site });
        });
    }

    pub(super) fn on_release(meta: &LockMeta) {
        HELD.with(|held| {
            let mut held = held.borrow_mut();
            // Guards may drop in any order; pop the newest matching token.
            if let Some(i) = held.iter().rposition(|h| h.key == meta.key && h.rank == meta.rank)
            {
                held.remove(i);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize tests that flip the process-wide mode override (the lib
    /// test binary runs tests in parallel; two tests forcing different modes
    /// concurrently would see each other's setting).
    fn override_guard(m: Mode) -> impl Drop {
        static SERIAL: Mutex<()> = Mutex::new(());
        struct Restore(Option<MutexGuard<'static, ()>>);
        impl Drop for Restore {
            fn drop(&mut self) {
                override_mode_for_tests(None);
                self.0.take();
            }
        }
        let serial = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
        override_mode_for_tests(Some(m));
        Restore(Some(serial))
    }

    #[test]
    fn mode_policy_resolves_env_and_profile() {
        assert_eq!(mode_policy(None, true), Mode::On);
        assert_eq!(mode_policy(None, false), Mode::Off);
        assert_eq!(mode_policy(Some("0"), true), Mode::Off);
        assert_eq!(mode_policy(Some("off"), true), Mode::Off);
        assert_eq!(mode_policy(Some("1"), false), Mode::On);
        assert_eq!(mode_policy(Some("on"), false), Mode::On);
        assert!(matches!(mode_policy(Some("stress"), false), Mode::Stress { .. }));
        assert_eq!(mode_policy(Some("stress:42"), false), Mode::Stress { seed: 42 });
        // Unknown values arm the sanitizer rather than silently disarming it.
        assert_eq!(mode_policy(Some("banana"), false), Mode::On);
    }

    #[test]
    fn ascending_rank_acquisition_is_clean() {
        let _g = override_guard(Mode::On);
        let low = OrderedMutex::new(10, "test.ascending.low", 1u32);
        let high = OrderedMutex::new(20, "test.ascending.high", 2u32);
        let a = low.lock().unwrap();
        let b = high.lock().unwrap();
        assert_eq!(*a + *b, 3);
    }

    #[test]
    fn inverted_rank_acquisition_panics_naming_both_sites() {
        let _g = override_guard(Mode::On);
        let low = OrderedMutex::new(10, "test.invert.low", ());
        let high = OrderedMutex::new(20, "test.invert.high", ());
        let err = std::thread::scope(|s| {
            s.spawn(|| {
                let _b = high.lock().unwrap();
                let _a = low.lock().unwrap(); // rank 10 after rank 20: inversion
            })
            .join()
            .expect_err("inversion must panic")
        });
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap());
        assert!(msg.contains("lock-order violation"), "{msg}");
        assert!(msg.contains("`test.invert.low` (rank 10"), "{msg}");
        assert!(msg.contains("`test.invert.high` (rank 20"), "{msg}");
    }

    #[test]
    fn same_rank_descending_key_panics() {
        let _g = override_guard(Mode::On);
        // Ascending keys on one pair: fine.
        {
            let first = OrderedMutex::with_key(30, "test.key.asc.first", 1, ());
            let second = OrderedMutex::with_key(30, "test.key.asc.second", 2, ());
            let _a = first.lock().unwrap();
            let _b = second.lock().unwrap();
        }
        // Descending keys on a fresh pair (no prior graph edges, so the
        // rank/key check — not the cycle check — is what fires).
        let first = OrderedMutex::with_key(30, "test.key.desc.first", 1, ());
        let second = OrderedMutex::with_key(30, "test.key.desc.second", 2, ());
        let err = std::thread::scope(|s| {
            s.spawn(|| {
                let _b = second.lock().unwrap();
                let _a = first.lock().unwrap(); // key 1 after key 2 at equal rank
            })
            .join()
            .expect_err("descending same-rank keys must panic")
        });
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-order violation"), "{msg}");
    }

    #[test]
    fn acquisition_graph_reports_cycles_between_sites() {
        let _g = override_guard(Mode::On);
        // Same rank, auto keys in creation order: locking a then b is legal
        // by rank/key and records the edge a -> b. A second code path that
        // locks b then a is caught by the graph (the key check would also
        // fire; the graph check runs first and names the cycle).
        let a = OrderedMutex::new(50, "test.cycle.a", ());
        let b = OrderedMutex::new(50, "test.cycle.b", ());
        {
            let _a = a.lock().unwrap();
            let _b = b.lock().unwrap();
        }
        let err = std::thread::scope(|s| {
            s.spawn(|| {
                let _b = b.lock().unwrap();
                let _a = a.lock().unwrap();
            })
            .join()
            .expect_err("reversed order must be reported")
        });
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("acquisition-order cycle"), "{msg}");
        assert!(msg.contains("`test.cycle.a`"), "{msg}");
        assert!(msg.contains("`test.cycle.b`"), "{msg}");
    }

    #[test]
    fn disarmed_mode_skips_all_checks() {
        let _g = override_guard(Mode::Off);
        let low = OrderedMutex::new(10, "test.off.low", ());
        let high = OrderedMutex::new(20, "test.off.high", ());
        // Inverted order, but the sanitizer is off: raw fast path, no panic.
        let _b = high.lock().unwrap();
        let _a = low.lock().unwrap();
    }

    #[test]
    fn condvar_wait_releases_and_reacquires_tracking() {
        let _g = override_guard(Mode::On);
        let gate = OrderedMutex::new(40, "test.cv.gate", false);
        let cv = OrderedCondvar::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut ready = gate.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
                // Woken holding only `gate`; acquiring a higher rank is legal.
                let after = OrderedMutex::new(60, "test.cv.after", 7u32);
                assert_eq!(*after.lock().unwrap(), 7);
            });
            loop {
                let mut ready = gate.lock().unwrap();
                *ready = true;
                cv.notify_all();
                break;
            }
        });
    }

    #[test]
    fn condvar_wait_timeout_reports_expiry_and_wakeups() {
        let _g = override_guard(Mode::On);
        let gate = OrderedMutex::new(40, "test.cv.timeout", false);
        let cv = OrderedCondvar::new();
        // Nobody notifies: the bounded wait must come back with the lock
        // reacquired and the timeout flagged.
        let g = gate.lock().unwrap();
        let (g, timed_out) = cv.wait_timeout(g, std::time::Duration::from_millis(5)).unwrap();
        assert!(timed_out);
        assert!(!*g);
        drop(g);
        // A notified wait returns well before a generous deadline.
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut ready = gate.lock().unwrap();
                while !*ready {
                    let (g, timed_out) = cv
                        .wait_timeout(ready, std::time::Duration::from_secs(30))
                        .unwrap();
                    ready = g;
                    assert!(!timed_out, "the notifier should beat a 30 s deadline");
                }
            });
            let mut ready = gate.lock().unwrap();
            *ready = true;
            cv.notify_all();
        });
    }

    #[test]
    fn stress_mode_perturbs_but_stays_correct() {
        let _g = override_guard(Mode::Stress { seed: 7 });
        let shared = OrderedMutex::new(50, "test.stress.ctr", 0u64);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..200 {
                        *shared.lock().unwrap() += 1;
                    }
                });
            }
        });
        assert_eq!(*shared.lock().unwrap(), 800);
    }

    #[test]
    fn try_lock_is_tracked_but_exempt_from_order_checks() {
        let _g = override_guard(Mode::On);
        let low = OrderedMutex::new(10, "test.try.low", ());
        let high = OrderedMutex::new(20, "test.try.high", ());
        let _b = high.lock().unwrap();
        // A blocking lock here would invert; try_lock cannot deadlock and is
        // allowed through (it still lands in the held set).
        let a = low.try_lock().expect("uncontended");
        drop(a);
        assert!(matches!(low.try_lock(), Ok(_)));
    }

    #[test]
    fn poisoned_ordered_mutex_still_hands_back_data() {
        let _g = override_guard(Mode::On);
        let m = OrderedMutex::new(70, "test.poison", 5u32);
        let _ = std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = m.lock().unwrap();
                panic!("poison it");
            })
            .join()
        });
        let v = match m.lock() {
            Ok(g) => *g,
            Err(poisoned) => *poisoned.into_inner(),
        };
        assert_eq!(v, 5);
    }
}
