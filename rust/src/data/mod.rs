//! Synthetic dataset generators standing in for the paper's corpora
//! (DESIGN.md §Substitutions): CIFAR-10 / MNIST / NUS-WIDE / the Linux
//! kernel source are not available offline, so each generator produces
//! deterministic data with the same shapes and a learnable class structure
//! (class prototypes + noise), which is what the training-dynamics
//! experiments actually exercise.
//!
//! Batches are addressed by index, so every worker group shards the stream
//! deterministically (data parallelism: "each worker group trains against a
//! partition of the training dataset", §5.1).

use crate::tensor::Blob;
use crate::utils::rng::Rng;
use std::collections::HashMap;

/// A deterministic, indexable mini-batch source.
pub trait DataSource: Send + Sync {
    /// Names of the input layers this source feeds.
    fn input_names(&self) -> Vec<String>;

    /// The `index`-th mini-batch of `batch` examples. Deterministic:
    /// `(index, batch)` fully determines the content.
    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob>;

    /// Fill `out` with the `index`-th mini-batch, reusing its existing blob
    /// buffers. Must produce exactly the values [`DataSource::batch`] would
    /// (the coordinator's trajectories may not depend on which entry point
    /// the caller used). The default materializes a fresh batch; sources on
    /// the coordinator's hot path override it allocation-free so the
    /// steady-state training step allocates no Blobs.
    fn batch_into(&self, index: u64, batch: usize, out: &mut HashMap<String, Blob>) {
        *out = self.batch(index, batch);
    }
}

/// Move the named slots out of `out` for in-place refilling (inserting
/// empty defaults on first use), returning owned blobs whose buffers are
/// reused across calls. Pair with [`restore_slots`].
fn take_slots<const N: usize>(out: &mut HashMap<String, Blob>, names: [&str; N]) -> [Blob; N] {
    if out.is_empty() {
        for name in names {
            out.insert(name.to_string(), Blob::default());
        }
    }
    names.map(|name| {
        std::mem::take(
            out.get_mut(name).unwrap_or_else(|| panic!("batch_into: missing '{name}' slot")),
        )
    })
}

/// Move refilled blobs back into their slots (no rehash, no Blob clones).
fn restore_slots<const N: usize>(
    out: &mut HashMap<String, Blob>,
    names: [&str; N],
    values: [Blob; N],
) {
    for (name, value) in names.into_iter().zip(values) {
        *out.get_mut(name).unwrap() = value;
    }
}

/// CIFAR-like image classification: `[b, 3, h, w]` images in 10 classes.
/// Each class has a per-channel spatial prototype; samples add Gaussian
/// noise, so accuracy saturates with training like the paper's CIFAR runs.
pub struct SyntheticImages {
    pub classes: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    pub noise: f32,
    prototypes: Vec<Vec<f32>>,
    seed: u64,
}

impl SyntheticImages {
    pub fn cifar_like(seed: u64) -> SyntheticImages {
        SyntheticImages::new(10, 3, 32, 32, 0.35, seed)
    }

    pub fn new(
        classes: usize,
        channels: usize,
        h: usize,
        w: usize,
        noise: f32,
        seed: u64,
    ) -> SyntheticImages {
        let mut rng = Rng::with_stream(seed, 0x1337);
        let dim = channels * h * w;
        let prototypes = (0..classes)
            .map(|_| {
                // Smooth prototypes: random low-frequency pattern.
                let fx = rng.uniform_range(0.5, 3.0);
                let fy = rng.uniform_range(0.5, 3.0);
                let phase = rng.uniform_range(0.0, 6.28);
                let mut p = Vec::with_capacity(dim);
                for c in 0..channels {
                    for y in 0..h {
                        for x in 0..w {
                            let v = ((x as f32 / w as f32) * fx * 6.28
                                + (y as f32 / h as f32) * fy * 6.28
                                + phase
                                + c as f32)
                                .sin();
                            p.push(0.5 * v);
                        }
                    }
                }
                p
            })
            .collect();
        SyntheticImages { classes, channels, h, w, noise, prototypes, seed }
    }

    pub fn image_dim(&self) -> usize {
        self.channels * self.h * self.w
    }

    /// The single batch recipe behind both entry points: resize the slots
    /// and write the deterministic sample stream in place.
    fn fill(&self, index: u64, batch: usize, data: &mut Blob, label: &mut Blob) {
        let mut rng = Rng::with_stream(self.seed ^ index.wrapping_mul(0x9e3779b9), 7);
        let dim = self.image_dim();
        data.resize(&[batch, self.channels, self.h, self.w]);
        label.resize(&[batch]);
        let xs = data.data_mut();
        let ys = label.data_mut();
        for i in 0..batch {
            let c = rng.below(self.classes);
            ys[i] = c as f32;
            for (j, &p) in self.prototypes[c].iter().enumerate() {
                xs[i * dim + j] = p + self.noise * rng.gaussian();
            }
        }
    }
}

impl DataSource for SyntheticImages {
    fn input_names(&self) -> Vec<String> {
        vec!["data".to_string(), "label".to_string()]
    }

    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob> {
        let mut m = HashMap::new();
        self.batch_into(index, batch, &mut m);
        m
    }

    fn batch_into(&self, index: u64, batch: usize, out: &mut HashMap<String, Blob>) {
        let [mut data, mut label] = take_slots(out, ["data", "label"]);
        self.fill(index, batch, &mut data, &mut label);
        restore_slots(out, ["data", "label"], [data, label]);
    }
}

/// MNIST-like flat binary-ish vectors in `[0,1]`, 10 classes — used by the
/// RBM / deep auto-encoder application (§4.2.2).
pub struct SyntheticDigits {
    pub dim: usize,
    pub classes: usize,
    prototypes: Vec<Vec<f32>>,
    seed: u64,
}

impl SyntheticDigits {
    pub fn mnist_like(seed: u64) -> SyntheticDigits {
        SyntheticDigits::new(784, 10, seed)
    }

    pub fn new(dim: usize, classes: usize, seed: u64) -> SyntheticDigits {
        let mut rng = Rng::with_stream(seed, 0xd161);
        let prototypes = (0..classes)
            .map(|_| (0..dim).map(|_| if rng.uniform() < 0.25 { 1.0 } else { 0.0 }).collect())
            .collect();
        SyntheticDigits { dim, classes, prototypes, seed }
    }

    /// The single batch recipe behind both entry points: resize the slots
    /// and write the deterministic sample stream in place.
    fn fill(&self, index: u64, batch: usize, data: &mut Blob, label: &mut Blob) {
        let mut rng = Rng::with_stream(self.seed ^ index.wrapping_mul(0x51ed), 11);
        data.resize(&[batch, self.dim]);
        label.resize(&[batch]);
        let xs = data.data_mut();
        let ys = label.data_mut();
        for i in 0..batch {
            let c = rng.below(self.classes);
            ys[i] = c as f32;
            for (j, &p) in self.prototypes[c].iter().enumerate() {
                // flip 3% of pixels
                xs[i * self.dim + j] = if rng.uniform() < 0.03 { 1.0 - p } else { p };
            }
        }
    }
}

impl DataSource for SyntheticDigits {
    fn input_names(&self) -> Vec<String> {
        vec!["data".to_string(), "label".to_string()]
    }

    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob> {
        let mut m = HashMap::new();
        self.batch_into(index, batch, &mut m);
        m
    }

    fn batch_into(&self, index: u64, batch: usize, out: &mut HashMap<String, Blob>) {
        let [mut data, mut label] = take_slots(out, ["data", "label"]);
        self.fill(index, batch, &mut data, &mut label);
        restore_slots(out, ["data", "label"], [data, label]);
    }
}

/// Pseudo-C source corpus for Char-RNN (§4.2.3): the Linux kernel source is
/// replaced by a generated corpus with C-like token statistics (keywords,
/// braces, identifiers), giving the model real sequential structure.
pub struct CharCorpus {
    pub text: Vec<u8>,
    pub vocab: Vec<u8>,
    index_of: [usize; 256],
    pub steps: usize,
}

impl CharCorpus {
    /// Generate ~`size` bytes of pseudo-C.
    pub fn pseudo_c(size: usize, steps: usize, seed: u64) -> CharCorpus {
        let mut rng = Rng::with_stream(seed, 0xc0de);
        let keywords = [
            "int ", "if (", "for (", "while (", "return ", "void ", "static ", "struct ",
            "char ", "unsigned ", "const ", "case ", "break;\n", "else {\n", "#define ",
        ];
        let idents = ["i", "j", "n", "ptr", "buf", "len", "ret", "dev", "flags", "size"];
        let mut text = Vec::with_capacity(size + 64);
        let mut depth: usize = 0;
        while text.len() < size {
            match rng.below(10) {
                0..=3 => text.extend_from_slice(keywords[rng.below(keywords.len())].as_bytes()),
                4..=6 => {
                    let id = idents[rng.below(idents.len())];
                    text.extend_from_slice(id.as_bytes());
                    match rng.below(4) {
                        0 => text.extend_from_slice(b" = "),
                        1 => text.extend_from_slice(b"++;\n"),
                        2 => text.extend_from_slice(b" < "),
                        _ => text.extend_from_slice(b"; "),
                    }
                }
                7 => {
                    text.extend_from_slice(b"{\n");
                    depth += 1;
                }
                8 if depth > 0 => {
                    text.extend_from_slice(b"}\n");
                    depth -= 1;
                }
                _ => {
                    let num = rng.below(100);
                    text.extend_from_slice(format!("{num}").as_bytes());
                }
            }
        }
        text.truncate(size);
        // Vocabulary = distinct bytes, in sorted order.
        let mut seen = [false; 256];
        for &b in &text {
            seen[b as usize] = true;
        }
        let vocab: Vec<u8> = (0..=255u8).filter(|&b| seen[b as usize]).collect();
        let mut index_of = [0usize; 256];
        for (i, &b) in vocab.iter().enumerate() {
            index_of[b as usize] = i;
        }
        CharCorpus { text, vocab, index_of, steps }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    pub fn decode(&self, id: usize) -> char {
        self.vocab[id] as char
    }

    /// The single batch recipe behind both entry points: resize the slots
    /// and write the deterministic sample stream in place. Reads
    /// `steps + 1` successive characters per example (paper §4.2.3): the
    /// first `steps` are inputs, the last `steps` are next-char labels.
    fn fill(&self, index: u64, batch: usize, chars: &mut Blob, labels: &mut Blob) {
        let mut rng = Rng::with_stream(0xc4a2 ^ index.wrapping_mul(31), 3);
        let span = self.steps + 1;
        chars.resize(&[batch, self.steps]);
        labels.resize(&[batch, self.steps]);
        let cs = chars.data_mut();
        let ls = labels.data_mut();
        for i in 0..batch {
            let start = rng.below(self.text.len() - span);
            for t in 0..self.steps {
                cs[i * self.steps + t] = self.index_of[self.text[start + t] as usize] as f32;
                ls[i * self.steps + t] = self.index_of[self.text[start + t + 1] as usize] as f32;
            }
        }
    }
}

impl DataSource for CharCorpus {
    fn input_names(&self) -> Vec<String> {
        vec!["chars".to_string(), "labels".to_string()]
    }

    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob> {
        let mut m = HashMap::new();
        self.batch_into(index, batch, &mut m);
        m
    }

    fn batch_into(&self, index: u64, batch: usize, out: &mut HashMap<String, Blob>) {
        let [mut chars, mut labels] = take_slots(out, ["chars", "labels"]);
        self.fill(index, batch, &mut chars, &mut labels);
        restore_slots(out, ["chars", "labels"], [chars, labels]);
    }
}

/// NUS-WIDE-like multimodal pairs (§4.2.1): an image and a bag-of-tags text
/// vector that share a latent class, plus the class label. Feeds the MDNN.
pub struct MultiModalPairs {
    pub classes: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    pub text_dim: usize,
    images: SyntheticImages,
    text_protos: Vec<Vec<f32>>,
    seed: u64,
}

impl MultiModalPairs {
    pub fn nuswide_like(seed: u64) -> MultiModalPairs {
        MultiModalPairs::new(8, 3, 16, 16, 64, seed)
    }

    pub fn new(
        classes: usize,
        channels: usize,
        h: usize,
        w: usize,
        text_dim: usize,
        seed: u64,
    ) -> MultiModalPairs {
        let images = SyntheticImages::new(classes, channels, h, w, 0.3, seed);
        let mut rng = Rng::with_stream(seed, 0x7e57);
        let text_protos = (0..classes)
            .map(|_| {
                (0..text_dim)
                    .map(|_| if rng.uniform() < 0.15 { rng.uniform_range(0.5, 1.0) } else { 0.0 })
                    .collect()
            })
            .collect();
        MultiModalPairs { classes, channels, h, w, text_dim, images, text_protos, seed }
    }

    /// The single batch recipe behind both entry points: resize the slots
    /// and write the deterministic sample stream in place.
    fn fill(&self, index: u64, batch: usize, image: &mut Blob, text: &mut Blob, label: &mut Blob) {
        let mut rng = Rng::with_stream(self.seed ^ index.wrapping_mul(0xabcd), 13);
        let img_dim = self.channels * self.h * self.w;
        image.resize(&[batch, self.channels, self.h, self.w]);
        text.resize(&[batch, self.text_dim]);
        label.resize(&[batch]);
        let imgs = image.data_mut();
        let texts = text.data_mut();
        let ys = label.data_mut();
        for i in 0..batch {
            let c = rng.below(self.classes);
            ys[i] = c as f32;
            for (j, &p) in self.images.prototypes[c].iter().enumerate() {
                imgs[i * img_dim + j] = p + 0.3 * rng.gaussian();
            }
            for (j, &p) in self.text_protos[c].iter().enumerate() {
                texts[i * self.text_dim + j] = (p + 0.1 * rng.gaussian()).max(0.0);
            }
        }
    }
}

impl DataSource for MultiModalPairs {
    fn input_names(&self) -> Vec<String> {
        vec!["image".to_string(), "text".to_string(), "label".to_string()]
    }

    fn batch(&self, index: u64, batch: usize) -> HashMap<String, Blob> {
        let mut m = HashMap::new();
        self.batch_into(index, batch, &mut m);
        m
    }

    fn batch_into(&self, index: u64, batch: usize, out: &mut HashMap<String, Blob>) {
        let [mut image, mut text, mut label] = take_slots(out, ["image", "text", "label"]);
        self.fill(index, batch, &mut image, &mut text, &mut label);
        restore_slots(out, ["image", "text", "label"], [image, text, label]);
    }
}

/// Shard a global batch stream across `k` worker groups: group `g` reads
/// batch indices `g, g+k, g+2k, ...` (disjoint partitions of the dataset).
pub fn shard_index(global_step: u64, group: usize, groups: usize) -> u64 {
    global_step * groups as u64 + group as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_shapes_and_determinism() {
        let d = SyntheticImages::new(4, 3, 8, 8, 0.2, 42);
        let b1 = d.batch(5, 6);
        let b2 = d.batch(5, 6);
        assert_eq!(b1["data"].shape(), &[6, 3, 8, 8]);
        assert_eq!(b1["label"].shape(), &[6]);
        assert_eq!(b1["data"], b2["data"]);
        // different indices differ
        let b3 = d.batch(6, 6);
        assert_ne!(b1["data"], b3["data"]);
        // labels in range
        assert!(b1["label"].data().iter().all(|&l| (l as usize) < 4));
    }

    #[test]
    fn images_are_classifiable_by_nearest_prototype() {
        let d = SyntheticImages::new(4, 1, 8, 8, 0.2, 7);
        let b = d.batch(0, 32);
        let dim = d.image_dim();
        let mut correct = 0;
        for i in 0..32 {
            let x = &b["data"].data()[i * dim..(i + 1) * dim];
            let mut best = (f32::INFINITY, 0);
            for (c, p) in d.prototypes.iter().enumerate() {
                let dist: f32 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == b["label"].data()[i] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 30, "nearest-prototype should classify: {correct}/32");
    }

    #[test]
    fn digits_are_binaryish() {
        let d = SyntheticDigits::new(100, 5, 3);
        let b = d.batch(1, 10);
        assert!(b["data"].data().iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn char_corpus_structure() {
        let c = CharCorpus::pseudo_c(4096, 10, 1);
        assert_eq!(c.text.len(), 4096);
        assert!(c.vocab_size() > 10 && c.vocab_size() < 100, "vocab {}", c.vocab_size());
        let b = c.batch(0, 4);
        assert_eq!(b["chars"].shape(), &[4, 10]);
        assert_eq!(b["labels"].shape(), &[4, 10]);
        // labels are inputs shifted by one: label[t] matches char[t+1]
        for bi in 0..4 {
            for t in 0..9 {
                assert_eq!(
                    b["labels"].data()[bi * 10 + t],
                    b["chars"].data()[bi * 10 + t + 1]
                );
            }
        }
        // all ids within vocab
        assert!(b["chars"].data().iter().all(|&v| (v as usize) < c.vocab_size()));
    }

    #[test]
    fn multimodal_pairs_share_class() {
        let d = MultiModalPairs::new(4, 1, 4, 4, 16, 9);
        let b = d.batch(2, 8);
        assert_eq!(b["image"].shape(), &[8, 1, 4, 4]);
        assert_eq!(b["text"].shape(), &[8, 16]);
        assert_eq!(b["label"].shape(), &[8]);
        assert!(b["text"].data().iter().all(|&v| v >= 0.0));
    }

    /// `batch_into` must produce exactly the blobs `batch` would (the
    /// coordinator's trajectories may not depend on the entry point), and
    /// refills after the first must allocate nothing — for ALL four
    /// sources, including the Char-RNN corpus (2 slots) and the
    /// multi-modal pairs (3 slots).
    #[test]
    fn batch_into_matches_batch_and_reuses_buffers() {
        let digits = SyntheticDigits::new(64, 5, 77);
        let images = SyntheticImages::new(4, 3, 8, 8, 0.2, 42);
        let corpus = CharCorpus::pseudo_c(4096, 8, 1);
        let pairs = MultiModalPairs::new(4, 1, 4, 4, 16, 9);
        let sources: [&dyn DataSource; 4] = [&digits, &images, &corpus, &pairs];
        for src in sources {
            let mut reused = HashMap::new();
            for index in [0u64, 3, 9] {
                src.batch_into(index, 6, &mut reused);
                let fresh = src.batch(index, 6);
                assert_eq!(fresh.len(), reused.len());
                for (name, want) in &fresh {
                    let got = &reused[name];
                    assert_eq!(got.shape(), want.shape(), "{name}");
                    for (x, y) in got.data().iter().zip(want.data()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{name} @ index {index}");
                    }
                }
            }
            // Steady state: same-size refills perform zero Blob allocations.
            let before = Blob::alloc_count();
            for index in 10..15u64 {
                src.batch_into(index, 6, &mut reused);
            }
            assert_eq!(Blob::alloc_count(), before, "refills must not allocate");
        }
    }

    #[test]
    fn shard_indices_disjoint() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for g in 0..4 {
            for s in 0..10 {
                assert!(seen.insert(shard_index(s, g, 4)));
            }
        }
    }
}
