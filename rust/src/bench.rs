//! Benchmark harness: one entry point per table/figure of the paper's
//! evaluation (§6). Each returns the TSV it prints so tests can assert the
//! series' *shape* (who wins, where curves bend) — absolute numbers depend
//! on this testbed and are recorded in EXPERIMENTS.md.
//!
//! Run all: `cargo bench` (or `make bench`); run one:
//! `cargo run --release --bin repro -- fig18a`.

use crate::baselines::{
    allreduce_cluster_time_ms, central_ps_cluster_time_ms, singa_dist_time_ms, OpParallelModel,
    SystemPolicy,
};
use crate::cluster::ClusterTopology;
use crate::comm::{Codec, CostModel, FaultPlan, LinkModel};
use crate::coordinator::copyqueue::{
    alexnet_like_profiles, iteration_time_us, CopyMode, UpdateRates,
};
use crate::coordinator::{run_job, Algorithm, CheckpointConf, JobConf};
use crate::data::{CharCorpus, DataSource, SyntheticDigits, SyntheticImages};
use crate::model::layer::{Activation, LayerConf, LayerKind};
use crate::model::{NetBuilder, Phase};
use crate::tensor::Blob;
use crate::train::{bp::Bp, TrainOneBatch};
use crate::updater::UpdaterConf;
use crate::utils::rng::Rng;
use crate::utils::timer::{time_iters, Stopwatch};
use std::sync::Arc;

/// The CIFAR convnet used throughout §6.2 (conv-pool-relu ×2 + fc), scaled
/// for this testbed.
pub fn cifar_convnet(batch: usize) -> NetBuilder {
    NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 3, 32, 32] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "conv1",
            LayerKind::Convolution { out_channels: 16, kernel: 5, stride: 1, pad: 2, init_std: 0.05 },
            &["data"],
        ))
        .add(LayerConf::new("pool1", LayerKind::MaxPool { kernel: 2, stride: 2 }, &["conv1"]))
        .add(LayerConf::new("relu1", LayerKind::Activation { act: Activation::Relu }, &["pool1"]))
        .add(LayerConf::new(
            "conv2",
            LayerKind::Convolution { out_channels: 32, kernel: 5, stride: 1, pad: 2, init_std: 0.05 },
            &["relu1"],
        ))
        .add(LayerConf::new("pool2", LayerKind::MaxPool { kernel: 2, stride: 2 }, &["conv2"]))
        .add(LayerConf::new("relu2", LayerKind::Activation { act: Activation::Relu }, &["pool2"]))
        .add(LayerConf::new(
            "fc",
            LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.05 },
            &["relu2"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["fc", "label"]))
}

/// Measure one BP iteration of the convnet at `batch` (ms, mean over iters
/// after warmup — the paper averages iterations 30..80 of 100; we scale
/// counts to the budget).
pub fn measure_convnet_iter_ms(batch: usize, warmup: usize, iters: usize) -> f64 {
    let mut net = cifar_convnet(batch).build(&mut Rng::new(5));
    let data = SyntheticImages::cifar_like(3);
    let mut alg = Bp::new();
    let stats = crate::utils::timer::time_iters(warmup, iters, || {
        let inputs = data.batch(7, batch);
        net.zero_grads();
        alg.train_one_batch(&mut net, &inputs);
    });
    stats.mean()
}

fn header(title: &str, cols: &[&str]) -> String {
    format!("# {title}\n{}\n", cols.join("\t"))
}

// ---------------------------------------------------------------------------
// Steady-state allocation / throughput probe (planned-executor contract)
// ---------------------------------------------------------------------------

/// Result of probing one model's steady-state training loop.
#[derive(Debug, Clone)]
pub struct AllocProbe {
    pub model: &'static str,
    /// Blob allocations during the warm-up iterations (workspace resizes,
    /// lazily-sized scratch — expected non-zero).
    pub warmup_allocs: u64,
    /// Blob allocations per step AFTER warm-up — the zero-allocation
    /// steady-state claim; must be 0.
    pub steady_allocs_per_step: f64,
    /// Gemm pack-scratch allocations during warm-up (pool growth; may be 0
    /// if an earlier probe on this thread already warmed the pool).
    pub warmup_pack_allocs: u64,
    /// Pack-scratch allocations per step AFTER warm-up — the zero-alloc
    /// story one level below the Blob layer; must be 0.
    pub steady_pack_allocs_per_step: f64,
    /// Executor-scratch allocations during warm-up (growth of the reused
    /// src-ref lists, slot stores, and duplicate-source scratch).
    pub warmup_exec_allocs: u64,
    /// Executor-scratch allocations per step AFTER warm-up — the
    /// micro-alloc story one level above the Blob layer; must be 0.
    pub steady_exec_allocs_per_step: f64,
    /// Mean wall time per training step (ms) at steady state.
    pub step_ms: f64,
    pub steps: usize,
}

fn probe_training_loop(
    model: &'static str,
    mut net: crate::model::NeuralNet,
    inputs: std::collections::HashMap<String, Blob>,
    steps: usize,
) -> AllocProbe {
    use crate::tensor::gemm::pack_alloc_count;
    let mut alg = Bp::new();
    let mut run = |net: &mut crate::model::NeuralNet, alg: &mut Bp| {
        net.zero_grads();
        alg.train_one_batch(net, &inputs);
        for p in net.params_mut() {
            p.sgd_step(0.01);
        }
    };
    let before_warm = Blob::alloc_count();
    let before_warm_pack = pack_alloc_count();
    let before_warm_exec = crate::model::net::exec_scratch_alloc_count();
    for _ in 0..2 {
        run(&mut net, &mut alg);
    }
    let warmup_allocs = Blob::alloc_count() - before_warm;
    let warmup_pack_allocs = pack_alloc_count() - before_warm_pack;
    let warmup_exec_allocs = crate::model::net::exec_scratch_alloc_count() - before_warm_exec;
    let before = Blob::alloc_count();
    let before_pack = pack_alloc_count();
    let before_exec = crate::model::net::exec_scratch_alloc_count();
    let sw = Stopwatch::new();
    for _ in 0..steps {
        run(&mut net, &mut alg);
    }
    let step_ms = sw.elapsed_ms() / steps.max(1) as f64;
    let steady = Blob::alloc_count() - before;
    let steady_pack = pack_alloc_count() - before_pack;
    let steady_exec = crate::model::net::exec_scratch_alloc_count() - before_exec;
    AllocProbe {
        model,
        warmup_allocs,
        steady_allocs_per_step: steady as f64 / steps.max(1) as f64,
        warmup_pack_allocs,
        steady_pack_allocs_per_step: steady_pack as f64 / steps.max(1) as f64,
        warmup_exec_allocs,
        steady_exec_allocs_per_step: steady_exec as f64 / steps.max(1) as f64,
        step_ms,
        steps,
    }
}

fn mlp_alloc_probe(model: &'static str, steps: usize) -> AllocProbe {
    let batch = 32;
    let b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 256] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: 128, act: Activation::Relu, init_std: 0.05 },
            &["data"],
        ))
        .add(LayerConf::new(
            "h2",
            LayerKind::InnerProduct { out: 64, act: Activation::Tanh, init_std: 0.05 },
            &["h1"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.05 },
            &["h2"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
    let net = b.build(&mut Rng::new(7));
    let data = SyntheticDigits::new(256, 10, 3);
    probe_training_loop(model, net, data.batch(1, batch), steps)
}

fn convnet_alloc_probe(model: &'static str, steps: usize) -> AllocProbe {
    let batch = 16;
    let net = cifar_convnet(batch).build(&mut Rng::new(9));
    let data = SyntheticImages::cifar_like(4);
    probe_training_loop(model, net, data.batch(1, batch), steps)
}

/// Probe the MLP and CIFAR-convnet training loops: Blob allocations per
/// steady-state step (must be zero after the first iteration sized the
/// workspace) plus per-step wall time. Both models run twice — once under
/// the process's resolved kernel and once forced onto the simd path (the
/// `+simd` entries; scalar fallback off-AVX2 keeps labels stable) — so the
/// zero-allocation steady state is pinned for both microkernel families.
pub fn alloc_probe(steps: usize) -> Vec<AllocProbe> {
    let mut out =
        vec![mlp_alloc_probe("mlp", steps), convnet_alloc_probe("cifar_convnet", steps)];
    let simd = crate::tensor::kernel::resolve(
        Some("simd"),
        crate::tensor::kernel::simd_supported(),
    )
    .chosen;
    crate::runtime::with_kernel(simd, || {
        out.push(mlp_alloc_probe("mlp+simd", steps));
        out.push(convnet_alloc_probe("cifar_convnet+simd", steps));
    });
    out
}

// ---------------------------------------------------------------------------
// Distributed steady-state allocation probe (the worker↔server plane)
// ---------------------------------------------------------------------------

/// Result of probing one topology's full `run_job` training loop: per-group
/// Blob allocations measured INSIDE the worker threads for every step at or
/// after the warm-up boundary.
#[derive(Debug, Clone)]
pub struct DistAllocProbe {
    pub topology: &'static str,
    pub groups: usize,
    /// Warm-up steps excluded per group (workspace sizing, first batch,
    /// updater state growth happen there).
    pub warmup_steps: u64,
    /// Steps measured per group after warm-up.
    pub steady_steps: u64,
    /// Blob allocations per worker group across all measured steps — the
    /// zero-clone parameter-plane claim; every entry must be 0.
    pub steady_allocs: Vec<u64>,
}

/// Probe a full `run_job` across the paper's frameworks: after `warmup`
/// steps, a distributed training step — batch refill, forward/backward,
/// gradient aggregation, server round trip, write-back, and (for hogwild)
/// neighbour server-group syncs — must perform zero Blob allocations in
/// every worker group.
pub fn distributed_alloc_probe(warmup: u64, steps: u64) -> Vec<DistAllocProbe> {
    // The `ckpt` flag arms the asynchronous checkpoint plane (snapshot
    // every 4 steps): cadence requests are one channel send and the export
    // clones on the checkpointer thread, so the worker tally must stay 0
    // with checkpointing enabled too. The `+f16`/`+int8` cases arm the
    // wire codec: steady-state encode/decode and error feedback must run
    // entirely in the workspace scratch sized at construction.
    // The `+chaos` case arms the retry protocol (every first copy dropped,
    // every retransmit delivered): CRC framing, retransmit bookkeeping, and
    // the shared wire timeline must all run in pre-sized scratch.
    let none = FaultPlan::none;
    let lossy = || FaultPlan::none().drop_nth(0, 0, u64::MAX, 0);
    let cases: [(&'static str, ClusterTopology, bool, Codec, FaultPlan); 7] = [
        ("sandblaster(1,1)", ClusterTopology::sandblaster(1, 1), false, Codec::Raw, none()),
        ("sandblaster(1,1)+ckpt", ClusterTopology::sandblaster(1, 1), true, Codec::Raw, none()),
        ("sandblaster(1,1)+f16", ClusterTopology::sandblaster(1, 1), false, Codec::F16, none()),
        ("sandblaster(1,1)+int8", ClusterTopology::sandblaster(1, 1), false, Codec::Int8, none()),
        ("sandblaster(1,1)+chaos", ClusterTopology::sandblaster(1, 1), false, Codec::Raw, lossy()),
        ("downpour(3,1,2)", ClusterTopology::downpour(3, 1, 2), false, Codec::Raw, none()),
        ("hogwild(2,1,10)", ClusterTopology::hogwild(2, 1, 10), false, Codec::Raw, none()),
    ];
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 77));
    cases
        .into_iter()
        .map(|(name, topo, ckpt, codec, faults)| {
            let b = NetBuilder::new()
                .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 64] }, &[]))
                .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
                .add(LayerConf::new(
                    "h1",
                    LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
                    &["data"],
                ))
                .add(LayerConf::new(
                    "logits",
                    LayerKind::InnerProduct { out: 5, act: Activation::Identity, init_std: 0.1 },
                    &["h1"],
                ))
                .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
            let mut conf = JobConf::new("dist_alloc_probe", b);
            conf.batch_size = 16;
            conf.iters = warmup + steps;
            conf.updater = UpdaterConf::sgd(0.1);
            conf.topology = topo.clone();
            conf.alloc_probe_from = Some(warmup);
            conf.wire_codec = codec;
            conf.faults = faults;
            if ckpt {
                conf.checkpoint = Some(CheckpointConf::every(4));
            }
            let report = run_job(&conf, data.clone());
            DistAllocProbe {
                topology: name,
                groups: topo.nworker_groups,
                warmup_steps: warmup,
                steady_steps: steps,
                steady_allocs: report.steady_allocs,
            }
        })
        .collect()
}

/// `alloc_probe` + `distributed_alloc_probe` serialized as the
/// `BENCH_alloc.json` artifact emitted by `cargo bench --bench figures --
/// alloc`.
pub fn alloc_probe_json(steps: usize) -> String {
    let models = alloc_probe(steps);
    let dist = distributed_alloc_probe(3, steps.max(4) as u64);
    alloc_probe_json_from(&models, &dist)
}

/// Serialize already-run probes (lets the bench binary reuse the probe
/// results it asserts on for the `check` gate).
pub fn alloc_probe_json_from(models: &[AllocProbe], dist: &[DistAllocProbe]) -> String {
    let mut s = String::from("{\n  \"probe\": \"steady_state_alloc\",\n  \"models\": [\n");
    for (i, p) in models.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"model\": \"{}\", \"warmup_allocs\": {}, \
             \"steady_allocs_per_step\": {:.3}, \"warmup_pack_allocs\": {}, \
             \"steady_pack_allocs_per_step\": {:.3}, \"warmup_exec_allocs\": {}, \
             \"steady_exec_allocs_per_step\": {:.3}, \"step_ms\": {:.4}, \"steps\": {}}}{}\n",
            p.model,
            p.warmup_allocs,
            p.steady_allocs_per_step,
            p.warmup_pack_allocs,
            p.steady_pack_allocs_per_step,
            p.warmup_exec_allocs,
            p.steady_exec_allocs_per_step,
            p.step_ms,
            p.steps,
            if i + 1 == models.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n  \"distributed\": [\n");
    for (i, d) in dist.iter().enumerate() {
        let allocs: Vec<String> = d.steady_allocs.iter().map(|a| a.to_string()).collect();
        s.push_str(&format!(
            "    {{\"topology\": \"{}\", \"groups\": {}, \"warmup_steps\": {}, \
             \"steady_steps\": {}, \"steady_allocs_per_group\": [{}]}}{}\n",
            d.topology,
            d.groups,
            d.warmup_steps,
            d.steady_steps,
            allocs.join(", "),
            if i + 1 == dist.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Overlap probe: sequential vs overlapped exchange on the simnet clock
// ---------------------------------------------------------------------------

/// Result of racing one job's sequential parameter exchange against the
/// overlapped one (bucketed gradient flush during backward + prefetch)
/// under one cost model. The virtual step times are the honest simnet
/// accounting: sequential sums compute + transfer, overlapped charges each
/// bucket at its flush instant and max-merges the finish times, so the
/// ratio approaches `max(compute, comm) / (compute + comm)` when flushes
/// land early — and can exceed 1 for comm-bound jobs, where per-bucket
/// message latency cannot hide behind compute.
#[derive(Debug, Clone)]
pub struct OverlapProbe {
    pub job: &'static str,
    pub cost: &'static str,
    /// Wire codec of this entry (`"raw"`, `"f16"`, `"int8"`).
    pub codec: &'static str,
    /// Flush buckets the job's net resolves to (default coalescing).
    pub buckets: usize,
    /// Wire bytes of one full-step gradient flush (all buckets) under this
    /// entry's codec — what the simnet link actually carries per step.
    pub step_flush_bytes: usize,
    /// `step_flush_bytes` / the same job's raw flush bytes (1.0 for raw
    /// entries; ≈0.5 for f16, ≈0.25 for int8 on f32 payloads).
    pub wire_ratio_vs_raw: f64,
    pub seq_virt_step_ms: f64,
    pub overlap_virt_step_ms: f64,
    /// overlapped / sequential virtual step time (< 1 ⇒ overlap wins).
    pub virt_ratio: f64,
    pub seq_wall_ms: f64,
    pub overlap_wall_ms: f64,
}

/// Race sequential vs overlapped exchange for the MLP and convnet jobs
/// under the cluster (1 Gbps), lan (10 Gbps), and local (NUMA) cost
/// models. Topology is sandblaster(1, 2) — sharded servers — so the
/// parameter plane crosses the modeled network link; trajectories are
/// bit-identical between the two runs (pinned elsewhere), only the clock
/// accounting differs.
///
/// `Codec::Raw` runs the full cost matrix; the quantizing codecs (f16,
/// int8) run the comm-bound cluster cost only — the configuration where
/// shrinking wire bytes is supposed to pay, and the one the figures gate:
/// the compressed entries must show the wire-byte ratio near the codec's
/// element shrink AND a faster *sequential* virtual step (compute + comm
/// sum, where the deterministic comm saving can't hide behind overlap).
pub fn overlap_probe(iters: u64) -> Vec<OverlapProbe> {
    let costs: [(&'static str, CostModel); 3] = [
        ("cluster", CostModel::cluster()),
        ("lan", CostModel::lan()),
        ("local", CostModel::numa_server()),
    ];
    let mlp = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![32, 256] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![32] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: 128, act: Activation::Relu, init_std: 0.05 },
            &["data"],
        ))
        .add(LayerConf::new(
            "h2",
            LayerKind::InnerProduct { out: 64, act: Activation::Tanh, init_std: 0.05 },
            &["h1"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.05 },
            &["h2"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
    let digits: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(256, 10, 3));
    let images: Arc<dyn DataSource> = Arc::new(SyntheticImages::cifar_like(4));
    let jobs: [(&'static str, NetBuilder, Arc<dyn DataSource>, usize); 2] =
        [("mlp", mlp, digits, 32), ("convnet", cifar_convnet(16), images, 16)];

    let codecs: [(&'static str, Codec); 3] =
        [("raw", Codec::Raw), ("f16", Codec::F16), ("int8", Codec::Int8)];
    let mut out = Vec::new();
    for (job, builder, data, batch) in jobs {
        let make_conf = |overlap: bool, cost: &CostModel, codec: Codec| {
            let mut conf = JobConf::new("overlap_probe", builder.clone());
            conf.batch_size = batch;
            conf.iters = iters;
            conf.updater = UpdaterConf::sgd(0.05);
            conf.topology = ClusterTopology::sandblaster(1, 2);
            conf.cost = *cost;
            conf.overlap_exchange = overlap;
            conf.wire_codec = codec;
            conf
        };
        // Layout + wire accounting from the SAME conf the runs use, so the
        // artifact can never report a layout the measurements didn't.
        let plan_stats = |codec: Codec| {
            let conf = make_conf(true, &costs[0].1, codec);
            let net = conf.net.clone().build(&mut Rng::new(7));
            let ws = crate::coordinator::workspace::ParamWorkspace::new(
                &net,
                conf.bucket_coalesce_bytes,
                codec,
            );
            let flush: usize = ws.plan().buckets.iter().map(|b| b.flush_bytes).sum();
            (ws.nbuckets(), flush)
        };
        let (buckets, raw_flush_bytes) = plan_stats(Codec::Raw);
        for (codec_name, codec) in codecs {
            let step_flush_bytes =
                if codec == Codec::Raw { raw_flush_bytes } else { plan_stats(codec).1 };
            let cost_list: &[(&'static str, CostModel)] =
                if codec == Codec::Raw { &costs } else { &costs[..1] };
            for (cost_name, cost) in cost_list {
                // Best-of-3 runs per mode (the GEMM probe's best-of-iters
                // recipe): virtual step time embeds each run's real measured
                // compute, so single-run scheduler noise on a shared CI
                // runner could otherwise push the gated ratio past 1.0
                // spuriously.
                let run = |overlap: bool| {
                    let mut best_virt = f64::INFINITY;
                    let mut best_wall = f64::INFINITY;
                    for _ in 0..3 {
                        let report = run_job(&make_conf(overlap, cost, codec), data.clone());
                        let virt = report.group_virt_ms.iter().cloned().fold(0.0, f64::max)
                            / iters.max(1) as f64;
                        best_virt = best_virt.min(virt);
                        best_wall = best_wall.min(report.wall_ms);
                    }
                    (best_virt, best_wall)
                };
                let (seq_virt_step_ms, seq_wall_ms) = run(false);
                let (overlap_virt_step_ms, overlap_wall_ms) = run(true);
                out.push(OverlapProbe {
                    job,
                    cost: cost_name,
                    codec: codec_name,
                    buckets,
                    step_flush_bytes,
                    wire_ratio_vs_raw: step_flush_bytes as f64 / raw_flush_bytes as f64,
                    seq_virt_step_ms,
                    overlap_virt_step_ms,
                    virt_ratio: overlap_virt_step_ms / seq_virt_step_ms,
                    seq_wall_ms,
                    overlap_wall_ms,
                });
            }
        }
    }
    out
}

/// Serialize probes as the `BENCH_overlap.json` artifact emitted by
/// `cargo bench --bench figures -- overlap`.
pub fn overlap_probes_json(probes: &[OverlapProbe]) -> String {
    let mut s = String::from("{\n  \"probe\": \"overlap_exchange\",\n  \"cases\": [\n");
    for (i, p) in probes.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"job\": \"{}\", \"cost\": \"{}\", \"codec\": \"{}\", \"buckets\": {}, \
             \"step_flush_bytes\": {}, \"wire_ratio_vs_raw\": {:.4}, \
             \"seq_virt_step_ms\": {:.4}, \"overlap_virt_step_ms\": {:.4}, \
             \"virt_ratio\": {:.4}, \"seq_wall_ms\": {:.2}, \"overlap_wall_ms\": {:.2}}}{}\n",
            p.job,
            p.cost,
            p.codec,
            p.buckets,
            p.step_flush_bytes,
            p.wire_ratio_vs_raw,
            p.seq_virt_step_ms,
            p.overlap_virt_step_ms,
            p.virt_ratio,
            p.seq_wall_ms,
            p.overlap_wall_ms,
            if i + 1 == probes.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Faults probe: recovery overhead on the simnet clock (BENCH_faults.json)
// ---------------------------------------------------------------------------

/// One fault scenario of one job under one cost model: the virtual-clock
/// overhead of checkpoint cadence, kill-and-restore, and stragglers (with
/// and without backup workers), plus the invariant that none of them
/// perturbs training values (`values_bitwise` against the fault-free run).
#[derive(Debug, Clone)]
pub struct FaultsProbe {
    pub job: &'static str,
    pub cost: &'static str,
    pub scenario: &'static str,
    pub iters: u64,
    /// Final virtual clock of the (single) worker group (ms).
    pub virt_ms: f64,
    /// virt_ms / the fault-free baseline's virt_ms (1.0 for the baseline
    /// itself; > 1 ⇒ the scenario costs virtual time).
    pub overhead_ratio: f64,
    pub fault_events: usize,
    pub checkpoints: u64,
    pub backup_rescues: u64,
    /// Summed restart cost (latency + checkpoint re-fetch) on the virtual
    /// clock, excluding replayed steps.
    pub recovery_virt_ms: f64,
    /// Final params bitwise-equal to the fault-free run — faults move the
    /// clock and the ledger, never the math.
    pub values_bitwise: bool,
}

fn params_bitwise_eq(
    a: &std::collections::HashMap<String, Blob>,
    b: &std::collections::HashMap<String, Blob>,
) -> bool {
    a.len() == b.len()
        && a.iter().all(|(name, va)| {
            b.get(name).is_some_and(|vb| {
                va.shape() == vb.shape()
                    && va.data().iter().zip(vb.data()).all(|(x, y)| x.to_bits() == y.to_bits())
            })
        })
}

/// Measure recovery overhead for the MLP and convnet jobs under the
/// cluster (1 Gbps) and lan (10 Gbps) cost models, on sandblaster(1,2)
/// (sole tenant of a sharded server group, so a kill exercises the full
/// checkpoint-restore path). Five scenarios per (job, cost): fault-free
/// baseline, checkpoint cadence alone, checkpoint + mid-run kill, an 8×
/// straggler stretch, and the same straggler hidden by a backup worker.
/// The convnet runs at `iters / 2`; cadence/kill/delay schedules scale
/// with the step budget.
pub fn faults_probe(iters: u64) -> Vec<FaultsProbe> {
    let costs: [(&'static str, CostModel); 2] =
        [("cluster", CostModel::cluster()), ("lan", CostModel::lan())];
    let mlp = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 64] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: 5, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
    let digits: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 77));
    let images: Arc<dyn DataSource> = Arc::new(SyntheticImages::cifar_like(4));
    let jobs: [(&'static str, NetBuilder, Arc<dyn DataSource>, usize, u64); 2] = [
        ("mlp", mlp, digits, 16, iters.max(6)),
        ("convnet", cifar_convnet(8), images, 8, (iters / 2).max(6)),
    ];

    let mut out = Vec::new();
    for (job, builder, data, batch, iters) in jobs {
        // Schedule scaled to the step budget: checkpoint boundaries at
        // thirds, the kill in the last sixth (after at least one
        // boundary), the straggler stretch over the second quarter.
        let every = (iters / 3).max(1);
        let kill_at = (iters * 5 / 6).max(1);
        let (delay_from, delay_to) = (iters / 4, (iters / 2).max(iters / 4 + 1));
        for (cost_name, cost) in &costs {
            let run = |faults: FaultPlan, ckpt: Option<u64>, backups: usize| {
                let mut conf = JobConf::new("faults_probe", builder.clone());
                conf.batch_size = batch;
                conf.iters = iters;
                conf.updater = UpdaterConf::sgd(0.1);
                conf.topology = ClusterTopology::sandblaster(1, 2);
                conf.cost = *cost;
                conf.faults = faults;
                conf.checkpoint = ckpt.map(CheckpointConf::every);
                conf.backup_workers = backups;
                run_job(&conf, data.clone())
            };
            let slow = FaultPlan::none().delay_range(0, delay_from, delay_to, 8.0);
            let base = run(FaultPlan::none(), None, 0);
            let scenarios: [(&'static str, crate::coordinator::JobReport); 4] = [
                ("ckpt", run(FaultPlan::none(), Some(every), 0)),
                ("ckpt+kill", run(FaultPlan::none().kill(0, kill_at), Some(every), 0)),
                ("straggler", run(slow.clone(), None, 0)),
                ("straggler+backup", run(slow, None, 1)),
            ];
            let base_virt = base.group_virt_ms[0];
            let mut push = |scenario: &'static str, r: &crate::coordinator::JobReport| {
                out.push(FaultsProbe {
                    job,
                    cost: cost_name,
                    scenario,
                    iters,
                    virt_ms: r.group_virt_ms[0],
                    overhead_ratio: r.group_virt_ms[0] / base_virt,
                    fault_events: r.fault_events.len(),
                    checkpoints: r.checkpoints,
                    backup_rescues: r.backup_rescues,
                    recovery_virt_ms: r.fault_events.iter().map(|e| e.recovery_virt_ms).sum(),
                    values_bitwise: params_bitwise_eq(&base.params, &r.params),
                });
            };
            push("baseline", &base);
            for (scenario, report) in &scenarios {
                push(scenario, report);
            }
        }
    }
    out
}

/// Serialize probes as the `BENCH_faults.json` artifact emitted by
/// `cargo bench --bench figures -- faults`.
pub fn faults_probes_json(probes: &[FaultsProbe]) -> String {
    let mut s = String::from("{\n  \"probe\": \"fault_recovery\",\n  \"cases\": [\n");
    for (i, p) in probes.iter().enumerate() {
        let metrics = metrics_json(
            "     ",
            &[
                ("virt_ms", p.virt_ms, "ms", "lower_is_better"),
                ("overhead_ratio", p.overhead_ratio, "x", "lower_is_better"),
                ("recovery_virt_ms", p.recovery_virt_ms, "ms", "lower_is_better"),
                ("backup_rescues", p.backup_rescues as f64, "steps", "higher_is_better"),
            ],
        );
        s.push_str(&format!(
            "    {{\"job\": \"{}\", \"cost\": \"{}\", \"scenario\": \"{}\", \"iters\": {}, \
             \"virt_ms\": {:.4}, \"overhead_ratio\": {:.4}, \"fault_events\": {}, \
             \"checkpoints\": {}, \"backup_rescues\": {}, \"recovery_virt_ms\": {:.4}, \
             \"values_bitwise\": {},\n     \"metrics\": {}}}{}\n",
            p.job,
            p.cost,
            p.scenario,
            p.iters,
            p.virt_ms,
            p.overhead_ratio,
            p.fault_events,
            p.checkpoints,
            p.backup_rescues,
            p.recovery_virt_ms,
            p.values_bitwise,
            metrics,
            if i + 1 == probes.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Chaos probe: retry protocol under a lossy wire (BENCH_chaos.json)
// ---------------------------------------------------------------------------

/// One wire-fault scenario of the MLP job under one codec: retransmit and
/// goodput accounting for the retry protocol, the recovery overhead on the
/// virtual clock, and the headline invariant that a lossy run whose buckets
/// all eventually deliver stays bitwise identical to the lossless run.
#[derive(Debug, Clone)]
pub struct ChaosProbe {
    pub codec: &'static str,
    pub scenario: &'static str,
    pub iters: u64,
    /// Final virtual clock of the (single) worker group (ms).
    pub virt_ms: f64,
    /// virt_ms / the lossless baseline's virt_ms — the recovery overhead of
    /// timeouts and retransmits (1.0 for the baseline itself).
    pub overhead_ratio: f64,
    pub drops: u64,
    pub corruptions_detected: u64,
    pub retransmits: u64,
    /// Retransmits per training step — the protocol's retry pressure.
    pub retransmit_rate: f64,
    pub staleness_adoptions: u64,
    /// Distinct degraded steps summed over groups (buckets that exhausted
    /// their retry budget and adopted last-known-fresh values).
    pub degraded_steps: u64,
    /// Bytes charged to attempts that never delivered (honest accounting:
    /// the ledger includes them).
    pub wasted_bytes: u64,
    /// Useful fraction of the parameter-plane traffic:
    /// 1 - wasted_bytes / ledger.param_bytes().
    pub goodput_ratio: f64,
    /// Final params bitwise-equal to the lossless run. True whenever every
    /// bucket eventually delivered; the `severed` scenario degrades to
    /// bounded staleness instead, so it reports false by design.
    pub values_bitwise: bool,
}

/// Measure the retry protocol on sandblaster(1,1) under the Raw and Int8
/// codecs. Four scenarios per codec: lossless baseline (framing armed via a
/// never-firing rule, so the transparency pin is part of the probe), every
/// first copy dropped, every first copy corrupted (CRC-detected), and a
/// link severed halfway (bounded-staleness degradation).
pub fn chaos_probe(iters: u64) -> Vec<ChaosProbe> {
    let iters = iters.max(6);
    let mlp = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 64] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
        .add(LayerConf::new(
            "h1",
            LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: 5, act: Activation::Identity, init_std: 0.1 },
            &["h1"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
    let digits: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(64, 5, 77));

    let mut out = Vec::new();
    for codec in [Codec::Raw, Codec::Int8] {
        let run = |faults: FaultPlan| {
            let mut conf = JobConf::new("chaos_probe", mlp.clone());
            conf.batch_size = 16;
            conf.iters = iters;
            conf.updater = UpdaterConf::sgd(0.1);
            conf.wire_codec = codec;
            conf.faults = faults;
            run_job(&conf, digits.clone())
        };
        // The baseline arms the frame path with a rule that never fires, so
        // overhead_ratio isolates the cost of faults, not of framing.
        let armed = FaultPlan::none().drop_nth(0, u64::MAX - 1, u64::MAX, 0);
        let base = run(armed);
        let scenarios: [(&'static str, crate::coordinator::JobReport); 3] = [
            ("drop+retry", run(FaultPlan::none().drop_nth(0, 0, u64::MAX, 0))),
            ("corrupt+retry", run(FaultPlan::none().corrupt_nth(0, 0, u64::MAX, 0))),
            ("severed", run(FaultPlan::none().sever(0, iters / 2))),
        ];
        let base_virt = base.group_virt_ms[0];
        let mut push = |scenario: &'static str, r: &crate::coordinator::JobReport| {
            let ev = &r.wire_events;
            let total = r.ledger.param_bytes() as f64;
            let goodput = if total > 0.0 {
                (total - ev.wasted_bytes as f64) / total
            } else {
                1.0
            };
            out.push(ChaosProbe {
                codec: codec.name(),
                scenario,
                iters,
                virt_ms: r.group_virt_ms[0],
                overhead_ratio: r.group_virt_ms[0] / base_virt,
                drops: ev.drops,
                corruptions_detected: ev.corruptions_detected,
                retransmits: ev.retransmits,
                retransmit_rate: ev.retransmits as f64 / iters as f64,
                staleness_adoptions: ev.staleness_adoptions,
                degraded_steps: ev.degraded_steps.iter().sum(),
                wasted_bytes: ev.wasted_bytes,
                goodput_ratio: goodput,
                values_bitwise: params_bitwise_eq(&base.params, &r.params),
            });
        };
        push("lossless", &base);
        for (scenario, report) in &scenarios {
            push(scenario, report);
        }
    }
    out
}

/// Serialize probes as the `BENCH_chaos.json` artifact emitted by
/// `cargo bench --bench figures -- chaos`.
pub fn chaos_probes_json(probes: &[ChaosProbe]) -> String {
    let mut s = String::from("{\n  \"probe\": \"wire_chaos\",\n  \"cases\": [\n");
    for (i, p) in probes.iter().enumerate() {
        let metrics = metrics_json(
            "     ",
            &[
                ("virt_ms", p.virt_ms, "ms", "lower_is_better"),
                ("recovery_overhead", p.overhead_ratio, "x", "lower_is_better"),
                ("retransmit_rate", p.retransmit_rate, "retransmits/step", "lower_is_better"),
                ("goodput_ratio", p.goodput_ratio, "fraction", "higher_is_better"),
                ("degraded_steps", p.degraded_steps as f64, "steps", "lower_is_better"),
            ],
        );
        s.push_str(&format!(
            "    {{\"codec\": \"{}\", \"scenario\": \"{}\", \"iters\": {}, \
             \"virt_ms\": {:.4}, \"overhead_ratio\": {:.4}, \"drops\": {}, \
             \"corruptions_detected\": {}, \"retransmits\": {}, \"staleness_adoptions\": {}, \
             \"degraded_steps\": {}, \"wasted_bytes\": {}, \"goodput_ratio\": {:.4}, \
             \"values_bitwise\": {},\n     \"metrics\": {}}}{}\n",
            p.codec,
            p.scenario,
            p.iters,
            p.virt_ms,
            p.overhead_ratio,
            p.drops,
            p.corruptions_detected,
            p.retransmits,
            p.staleness_adoptions,
            p.degraded_steps,
            p.wasted_bytes,
            p.goodput_ratio,
            p.values_bitwise,
            metrics,
            if i + 1 == probes.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// GEMM intra-op scaling probe (Fig 18a's native-path counterpart)
// ---------------------------------------------------------------------------

/// Serial-vs-parallel throughput of one square GEMM size.
#[derive(Debug, Clone)]
pub struct GemmProbe {
    pub n: usize,
    /// Worker count used for the parallel run.
    pub threads: usize,
    /// Best-of-iters wall time (ms) and the derived GFLOP/s.
    pub serial_ms: f64,
    pub serial_gflops: f64,
    pub parallel_ms: f64,
    pub parallel_gflops: f64,
    /// serial_ms / parallel_ms (best-of-iters on both sides).
    pub speedup: f64,
    /// Whether the parallel output was `==`-identical to serial (the
    /// determinism guarantee; always expected true).
    pub bit_identical: bool,
    /// Explicit-kind single-threaded runs pinning scalar vs simd against
    /// each other regardless of the process-wide `PALLAS_KERNEL`
    /// resolution. On hosts without AVX2+FMA the simd request degrades to
    /// scalar, so `simd_speedup` hovers around 1 there.
    pub scalar_ms: f64,
    pub scalar_gflops: f64,
    pub simd_ms: f64,
    pub simd_gflops: f64,
    /// scalar_ms / simd_ms — the CI gate's >= 1.5x input on AVX2 runners.
    pub simd_speedup: f64,
    /// Whether the simd output matched the scalar oracle within the FMA
    /// reordering tolerance (1e-3 + 1e-3|y|); always expected true.
    pub simd_close: bool,
}

/// Measure `n x n x n` GEMMs serial vs `threads`-worker parallel. Uses
/// best-of-`iters` timings so the CI smoke check tolerates noisy runners.
pub fn gemm_scaling_probe(
    sizes: &[usize],
    threads: usize,
    warmup: usize,
    iters: usize,
) -> Vec<GemmProbe> {
    use crate::tensor::gemm::{gemm_with_kernel, gemm_with_threads};
    use crate::tensor::kernel::{resolve, simd_supported};
    use crate::tensor::{KernelKind, Transpose};
    let simd_kind = resolve(Some("simd"), simd_supported()).chosen;
    sizes
        .iter()
        .map(|&n| {
            let mut rng = Rng::new(0x9e37 ^ n as u64);
            let a = rng.uniform_vec(n * n, -1.0, 1.0);
            let b = rng.uniform_vec(n * n, -1.0, 1.0);
            let run = |t: usize, c: &mut [f32]| {
                gemm_with_threads(Transpose::No, Transpose::No, n, n, n, 1.0, &a, &b, 0.0, c, t);
            };
            let run_kind = |kind: KernelKind, c: &mut [f32]| {
                gemm_with_kernel(
                    Transpose::No, Transpose::No, n, n, n, 1.0, &a, &b, 0.0, c, 1, kind,
                );
            };
            let mut c_serial = vec![0.0f32; n * n];
            let mut c_par = vec![0.0f32; n * n];
            run(1, &mut c_serial);
            run(threads, &mut c_par);
            let bit_identical = c_serial == c_par;
            let mut c_scalar = vec![0.0f32; n * n];
            let mut c_simd = vec![0.0f32; n * n];
            run_kind(KernelKind::Scalar, &mut c_scalar);
            run_kind(simd_kind, &mut c_simd);
            let simd_close = c_scalar
                .iter()
                .zip(&c_simd)
                .all(|(y, x)| (x - y).abs() <= 1e-3 + 1e-3 * y.abs());
            let st_serial = time_iters(warmup, iters, || run(1, &mut c_serial));
            let st_par = time_iters(warmup, iters, || run(threads, &mut c_par));
            let st_scalar =
                time_iters(warmup, iters, || run_kind(KernelKind::Scalar, &mut c_scalar));
            let st_simd = time_iters(warmup, iters, || run_kind(simd_kind, &mut c_simd));
            let gflops = |ms: f64| 2.0 * (n as f64).powi(3) / (ms / 1e3) / 1e9;
            let (serial_ms, parallel_ms) = (st_serial.min(), st_par.min());
            let (scalar_ms, simd_ms) = (st_scalar.min(), st_simd.min());
            GemmProbe {
                n,
                threads,
                serial_ms,
                serial_gflops: gflops(serial_ms),
                parallel_ms,
                parallel_gflops: gflops(parallel_ms),
                speedup: serial_ms / parallel_ms,
                bit_identical,
                scalar_ms,
                scalar_gflops: gflops(scalar_ms),
                simd_ms,
                simd_gflops: gflops(simd_ms),
                simd_speedup: scalar_ms / simd_ms,
                simd_close,
            }
        })
        .collect()
}

/// Shared `{name, value, unit, direction}` records carried by every entry
/// in `BENCH_gemm.json` / `BENCH_conv.json`, so downstream tooling can
/// plot or gate any metric without knowing per-probe field names.
/// `direction` is `higher_is_better` or `lower_is_better`.
fn metrics_json(indent: &str, metrics: &[(&str, f64, &str, &str)]) -> String {
    let mut s = String::from("[\n");
    for (i, &(name, value, unit, direction)) in metrics.iter().enumerate() {
        s.push_str(&format!(
            "{indent}  {{\"name\": \"{name}\", \"value\": {value:.4}, \"unit\": \"{unit}\", \
             \"direction\": \"{direction}\"}}{}\n",
            if i + 1 == metrics.len() { "" } else { "," }
        ));
    }
    s.push_str(indent);
    s.push(']');
    s
}

/// Serialize probes as the `BENCH_gemm.json` artifact emitted by
/// `cargo bench --bench figures -- gemm`. The header embeds the process's
/// kernel resolution so recorded numbers stay attributable to a path.
pub fn gemm_probes_json(threads: usize, probes: &[GemmProbe]) -> String {
    let kernel = crate::runtime::manifest::kernel_json(crate::runtime::kernel_choice());
    let mut s = format!(
        "{{\n  \"probe\": \"gemm_scaling\",\n  \"threads\": {threads},\n  \
         \"kernel\": {kernel},\n  \"sizes\": [\n"
    );
    for (i, p) in probes.iter().enumerate() {
        let metrics = metrics_json(
            "     ",
            &[
                ("serial_ms", p.serial_ms, "ms", "lower_is_better"),
                ("serial_gflops", p.serial_gflops, "GFLOP/s", "higher_is_better"),
                ("parallel_ms", p.parallel_ms, "ms", "lower_is_better"),
                ("parallel_gflops", p.parallel_gflops, "GFLOP/s", "higher_is_better"),
                ("speedup", p.speedup, "x", "higher_is_better"),
                ("scalar_ms", p.scalar_ms, "ms", "lower_is_better"),
                ("scalar_gflops", p.scalar_gflops, "GFLOP/s", "higher_is_better"),
                ("simd_ms", p.simd_ms, "ms", "lower_is_better"),
                ("simd_gflops", p.simd_gflops, "GFLOP/s", "higher_is_better"),
                ("simd_speedup", p.simd_speedup, "x", "higher_is_better"),
            ],
        );
        s.push_str(&format!(
            "    {{\"n\": {}, \"serial_ms\": {:.4}, \"serial_gflops\": {:.3}, \
             \"parallel_ms\": {:.4}, \"parallel_gflops\": {:.3}, \"speedup\": {:.3}, \
             \"bit_identical\": {}, \"scalar_ms\": {:.4}, \"scalar_gflops\": {:.3}, \
             \"simd_ms\": {:.4}, \"simd_gflops\": {:.3}, \"simd_speedup\": {:.3}, \
             \"simd_close\": {},\n     \"metrics\": {}}}{}\n",
            p.n,
            p.serial_ms,
            p.serial_gflops,
            p.parallel_ms,
            p.parallel_gflops,
            p.speedup,
            p.bit_identical,
            p.scalar_ms,
            p.scalar_gflops,
            p.simd_ms,
            p.simd_gflops,
            p.simd_speedup,
            p.simd_close,
            metrics,
            if i + 1 == probes.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Conv/im2col intra-op scaling probe (the second pooled hot path)
// ---------------------------------------------------------------------------

/// Serial-vs-parallel throughput of one convolution workload: the raw
/// im2col transform and the full batched conv2d forward (im2col + GEMM +
/// bias), both required bit-identical across thread counts.
#[derive(Debug, Clone)]
pub struct ConvProbe {
    pub name: &'static str,
    /// Task count used for the parallel runs.
    pub threads: usize,
    pub im2col_serial_ms: f64,
    pub im2col_parallel_ms: f64,
    pub im2col_speedup: f64,
    pub conv_serial_ms: f64,
    pub conv_parallel_ms: f64,
    pub conv_speedup: f64,
    /// Whether BOTH parallel outputs were `==`-identical to serial (the
    /// determinism guarantee; always expected true).
    pub bit_identical: bool,
    /// Explicit-kind serial runs pinning scalar vs simd regardless of the
    /// process-wide `PALLAS_KERNEL` resolution (simd degrades to scalar on
    /// hosts without AVX2+FMA, so the speedups hover around 1 there).
    pub im2col_scalar_ms: f64,
    pub im2col_simd_ms: f64,
    pub im2col_simd_speedup: f64,
    pub conv_scalar_ms: f64,
    pub conv_simd_ms: f64,
    pub conv_simd_speedup: f64,
    /// simd im2col AND col2im outputs were `==`-identical to scalar (the
    /// span kernels reorder no arithmetic; always expected true).
    pub transforms_simd_exact: bool,
    /// simd conv forward matched scalar within the FMA reordering
    /// tolerance (the GEMM inside accumulates in a different order).
    pub conv_simd_close: bool,
}

/// Measure im2col and conv2d forward serial vs `threads`-task parallel on
/// convnet-shaped workloads. Best-of-`iters` timings, like the GEMM probe.
pub fn conv_scaling_probe(threads: usize, warmup: usize, iters: usize) -> Vec<ConvProbe> {
    use crate::tensor::conv::{
        col2im_acc_with_kernel, conv2d_forward_into_with_threads, im2col_with_kernel,
        im2col_with_threads, Conv2dGeom, ConvScratch,
    };
    use crate::tensor::kernel::{resolve, simd_supported};
    use crate::tensor::KernelKind;
    let simd_kind = resolve(Some("simd"), simd_supported()).chosen;
    let cases: [(&'static str, Conv2dGeom, usize, usize); 2] = [
        (
            "c16_32x32_k5_b16",
            Conv2dGeom { in_c: 16, in_h: 32, in_w: 32, kernel: 5, stride: 1, pad: 2 },
            16,
            32,
        ),
        (
            "c32_16x16_k3_b16",
            Conv2dGeom { in_c: 32, in_h: 16, in_w: 16, kernel: 3, stride: 1, pad: 1 },
            16,
            64,
        ),
    ];
    cases
        .iter()
        .map(|&(name, g, batch, out_c)| {
            let mut rng = Rng::new(0xc07f_u64 ^ g.in_c as u64);
            let img_len = g.in_c * g.in_h * g.in_w;
            let img = rng.uniform_vec(img_len, -1.0, 1.0);
            let (cr, cc) = (g.col_rows(), g.col_cols());
            let mut col_serial = vec![0.0f32; cr * cc];
            let mut col_par = vec![0.0f32; cr * cc];
            im2col_with_threads(&img, &g, &mut col_serial, 1);
            im2col_with_threads(&img, &g, &mut col_par, threads);
            let mut bit_identical = col_serial == col_par;
            let st_i2c_serial =
                time_iters(warmup, iters, || im2col_with_threads(&img, &g, &mut col_serial, 1));
            let st_i2c_par = time_iters(warmup, iters, || {
                im2col_with_threads(&img, &g, &mut col_par, threads)
            });

            let input = Blob::from_vec(
                &[batch, g.in_c, g.in_h, g.in_w],
                rng.uniform_vec(batch * img_len, -1.0, 1.0),
            );
            let weight = Blob::from_vec(&[out_c, cr], rng.uniform_vec(out_c * cr, -0.5, 0.5));
            let bias = Blob::from_vec(&[out_c], rng.uniform_vec(out_c, -0.1, 0.1));
            let mut out_serial = Blob::default();
            let mut out_par = Blob::default();
            let mut cols = Vec::new();
            let mut scratch = ConvScratch::new();
            conv2d_forward_into_with_threads(
                &input, &weight, &bias, &g, &mut out_serial, &mut cols, &mut scratch, 1,
            );
            conv2d_forward_into_with_threads(
                &input, &weight, &bias, &g, &mut out_par, &mut cols, &mut scratch, threads,
            );
            bit_identical &= out_serial.data() == out_par.data();
            let st_conv_serial = time_iters(warmup, iters, || {
                conv2d_forward_into_with_threads(
                    &input, &weight, &bias, &g, &mut out_serial, &mut cols, &mut scratch, 1,
                )
            });
            let st_conv_par = time_iters(warmup, iters, || {
                conv2d_forward_into_with_threads(
                    &input, &weight, &bias, &g, &mut out_par, &mut cols, &mut scratch, threads,
                )
            });

            // Explicit-kind runs: transforms directly, the full forward
            // through the thread-local kernel override (its GEMM resolves
            // the kind on this thread before fanning out).
            let mut col_scalar = vec![0.0f32; cr * cc];
            let mut col_simd = vec![0.0f32; cr * cc];
            im2col_with_kernel(&img, &g, &mut col_scalar, 1, KernelKind::Scalar);
            im2col_with_kernel(&img, &g, &mut col_simd, 1, simd_kind);
            let mut transforms_simd_exact = col_simd == col_scalar;
            let colm = rng.uniform_vec(cr * cc, -1.0, 1.0);
            let mut acc_scalar = rng.uniform_vec(img_len, -1.0, 1.0);
            let mut acc_simd = acc_scalar.clone();
            col2im_acc_with_kernel(&colm, &g, &mut acc_scalar, 1, KernelKind::Scalar);
            col2im_acc_with_kernel(&colm, &g, &mut acc_simd, 1, simd_kind);
            transforms_simd_exact &= acc_simd == acc_scalar;
            let st_i2c_scalar = time_iters(warmup, iters, || {
                im2col_with_kernel(&img, &g, &mut col_scalar, 1, KernelKind::Scalar)
            });
            let st_i2c_simd = time_iters(warmup, iters, || {
                im2col_with_kernel(&img, &g, &mut col_simd, 1, simd_kind)
            });
            let mut out_scalar = Blob::default();
            let mut out_simd = Blob::default();
            crate::runtime::with_kernel(KernelKind::Scalar, || {
                conv2d_forward_into_with_threads(
                    &input, &weight, &bias, &g, &mut out_scalar, &mut cols, &mut scratch, 1,
                )
            });
            crate::runtime::with_kernel(simd_kind, || {
                conv2d_forward_into_with_threads(
                    &input, &weight, &bias, &g, &mut out_simd, &mut cols, &mut scratch, 1,
                )
            });
            let conv_simd_close = out_scalar
                .data()
                .iter()
                .zip(out_simd.data())
                .all(|(y, x)| (x - y).abs() <= 1e-3 + 1e-3 * y.abs());
            let st_conv_scalar = time_iters(warmup, iters, || {
                crate::runtime::with_kernel(KernelKind::Scalar, || {
                    conv2d_forward_into_with_threads(
                        &input, &weight, &bias, &g, &mut out_scalar, &mut cols, &mut scratch, 1,
                    )
                })
            });
            let st_conv_simd = time_iters(warmup, iters, || {
                crate::runtime::with_kernel(simd_kind, || {
                    conv2d_forward_into_with_threads(
                        &input, &weight, &bias, &g, &mut out_simd, &mut cols, &mut scratch, 1,
                    )
                })
            });

            let (i2c_s, i2c_p) = (st_i2c_serial.min(), st_i2c_par.min());
            let (conv_s, conv_p) = (st_conv_serial.min(), st_conv_par.min());
            let (i2c_sc, i2c_v) = (st_i2c_scalar.min(), st_i2c_simd.min());
            let (conv_sc, conv_v) = (st_conv_scalar.min(), st_conv_simd.min());
            ConvProbe {
                name,
                threads,
                im2col_serial_ms: i2c_s,
                im2col_parallel_ms: i2c_p,
                im2col_speedup: i2c_s / i2c_p,
                conv_serial_ms: conv_s,
                conv_parallel_ms: conv_p,
                conv_speedup: conv_s / conv_p,
                bit_identical,
                im2col_scalar_ms: i2c_sc,
                im2col_simd_ms: i2c_v,
                im2col_simd_speedup: i2c_sc / i2c_v,
                conv_scalar_ms: conv_sc,
                conv_simd_ms: conv_v,
                conv_simd_speedup: conv_sc / conv_v,
                transforms_simd_exact,
                conv_simd_close,
            }
        })
        .collect()
}

/// Serialize probes as the `BENCH_conv.json` artifact emitted by
/// `cargo bench --bench figures -- conv`. The header embeds the process's
/// kernel resolution, mirroring `BENCH_gemm.json`.
pub fn conv_probes_json(threads: usize, probes: &[ConvProbe]) -> String {
    let kernel = crate::runtime::manifest::kernel_json(crate::runtime::kernel_choice());
    let mut s = format!(
        "{{\n  \"probe\": \"conv_scaling\",\n  \"threads\": {threads},\n  \
         \"kernel\": {kernel},\n  \"cases\": [\n"
    );
    for (i, p) in probes.iter().enumerate() {
        let metrics = metrics_json(
            "     ",
            &[
                ("im2col_serial_ms", p.im2col_serial_ms, "ms", "lower_is_better"),
                ("im2col_parallel_ms", p.im2col_parallel_ms, "ms", "lower_is_better"),
                ("im2col_speedup", p.im2col_speedup, "x", "higher_is_better"),
                ("conv_serial_ms", p.conv_serial_ms, "ms", "lower_is_better"),
                ("conv_parallel_ms", p.conv_parallel_ms, "ms", "lower_is_better"),
                ("conv_speedup", p.conv_speedup, "x", "higher_is_better"),
                ("im2col_scalar_ms", p.im2col_scalar_ms, "ms", "lower_is_better"),
                ("im2col_simd_ms", p.im2col_simd_ms, "ms", "lower_is_better"),
                ("im2col_simd_speedup", p.im2col_simd_speedup, "x", "higher_is_better"),
                ("conv_scalar_ms", p.conv_scalar_ms, "ms", "lower_is_better"),
                ("conv_simd_ms", p.conv_simd_ms, "ms", "lower_is_better"),
                ("conv_simd_speedup", p.conv_simd_speedup, "x", "higher_is_better"),
            ],
        );
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"im2col_serial_ms\": {:.4}, \
             \"im2col_parallel_ms\": {:.4}, \"im2col_speedup\": {:.3}, \
             \"conv_serial_ms\": {:.4}, \"conv_parallel_ms\": {:.4}, \
             \"conv_speedup\": {:.3}, \"bit_identical\": {}, \
             \"im2col_scalar_ms\": {:.4}, \"im2col_simd_ms\": {:.4}, \
             \"im2col_simd_speedup\": {:.3}, \"conv_scalar_ms\": {:.4}, \
             \"conv_simd_ms\": {:.4}, \"conv_simd_speedup\": {:.3}, \
             \"transforms_simd_exact\": {}, \"conv_simd_close\": {},\n     \
             \"metrics\": {}}}{}\n",
            p.name,
            p.im2col_serial_ms,
            p.im2col_parallel_ms,
            p.im2col_speedup,
            p.conv_serial_ms,
            p.conv_parallel_ms,
            p.conv_speedup,
            p.bit_identical,
            p.im2col_scalar_ms,
            p.im2col_simd_ms,
            p.im2col_simd_speedup,
            p.conv_scalar_ms,
            p.conv_simd_ms,
            p.conv_simd_speedup,
            p.transforms_simd_exact,
            p.conv_simd_close,
            metrics,
            if i + 1 == probes.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------

/// Table I: feature matrix from code introspection.
pub fn table1() -> String {
    let mut out = header(
        "Table I: features (this reproduction)",
        &["feature", "singa-rs"],
    );
    let rows = [
        ("feed-forward net", "yes (MLP/CNN examples)"),
        ("energy model", "yes (RBM + CD)"),
        ("RNN", "yes (GRU + BPTT)"),
        ("data parallelism", "yes (partition_dim=0)"),
        ("model parallelism", "yes (partition_dim=1 / placement)"),
        ("hybrid parallelism", "yes (per-layer mix)"),
        ("GPU", "simulated devices (DESIGN.md)"),
        ("CPU", "yes (native + XLA/PJRT)"),
        ("python", "build path only (L2/L1 AOT)"),
        ("frameworks", "sandblaster/allreduce/downpour/hogwild"),
    ];
    for (k, v) in rows {
        out.push_str(&format!("{k}\t{v}\n"));
    }
    out
}

/// Fig 16: RBM pre-training for the deep auto-encoder — reports
/// reconstruction error trajectory and a class-separation score of the top
/// codes (the paper shows filters and the 2-d embedding; we report the
/// quantitative equivalents).
pub fn fig16(iters: usize) -> String {
    let mut out = header(
        "Fig 16: RBM pre-training + auto-encoder codes",
        &["stage", "iter", "recon_error"],
    );
    let data = SyntheticDigits::mnist_like(11);
    let batch = 32;
    let mut net = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 784] }, &[]))
        .add(LayerConf::new("rbm1", LayerKind::Rbm { hidden: 256, init_std: 0.05 }, &["data"]))
        .add(LayerConf::new("rbm2", LayerKind::Rbm { hidden: 64, init_std: 0.05 }, &["rbm1"]))
        .build(&mut Rng::new(2));
    for (stage, name) in [(1usize, "rbm1"), (2, "rbm2")] {
        let mut alg = crate::train::cd::Cd::stage(1, name);
        for it in 0..iters {
            let inputs = data.batch(it as u64, batch);
            net.zero_grads();
            let stats = alg.train_one_batch(&mut net, &inputs);
            for p in net.params_mut() {
                p.sgd_step(0.05);
            }
            if it % (iters / 8).max(1) == 0 || it + 1 == iters {
                out.push_str(&format!("{stage}\t{it}\t{:.5}\n", stats.total_loss()));
            }
        }
    }
    // Class separation of top-layer codes: between-class vs within-class
    // mean distance (>1 = clusters separate, the paper's Fig 16b visual).
    let inputs = data.batch(9999, 128);
    net.set_input_ref("data", &inputs["data"]);
    net.forward(Phase::Test);
    let codes = net.feature("rbm2").clone();
    let labels: Vec<usize> = inputs["label"].data().iter().map(|&v| v as usize).collect();
    let sep = class_separation(&codes, &labels);
    out.push_str(&format!("separation\t-\t{sep:.4}\n"));
    out
}

fn class_separation(codes: &Blob, labels: &[usize]) -> f64 {
    let cols = codes.cols();
    let dist = |a: usize, b: usize| -> f64 {
        codes.data()[a * cols..(a + 1) * cols]
            .iter()
            .zip(&codes.data()[b * cols..(b + 1) * cols])
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum::<f64>()
            .sqrt()
    };
    let n = labels.len();
    let (mut within, mut wn, mut between, mut bn) = (0.0, 0u64, 0.0, 0u64);
    for i in 0..n {
        for j in (i + 1)..n {
            if labels[i] == labels[j] {
                within += dist(i, j);
                wn += 1;
            } else {
                between += dist(i, j);
                bn += 1;
            }
        }
    }
    (between / bn.max(1) as f64) / (within / wn.max(1) as f64).max(1e-9)
}

/// Fig 17: Char-RNN training loss and accuracy over iterations.
pub fn fig17(iters: usize) -> String {
    let mut out = header("Fig 17: Char-RNN loss/accuracy", &["iter", "loss", "accuracy"]);
    let steps = 16;
    let corpus = CharCorpus::pseudo_c(64 * 1024, steps, 3);
    let vocab = corpus.vocab_size();
    let batch = 16;
    let mut net = NetBuilder::new()
        .add(LayerConf::new("chars", LayerKind::Input { shape: vec![batch, steps] }, &[]))
        .add(LayerConf::new("labels", LayerKind::Input { shape: vec![batch, steps] }, &[]))
        .add(LayerConf::new("onehot", LayerKind::OneHot { vocab }, &["chars"]))
        .add(LayerConf::new("gru", LayerKind::Gru { hidden: 64, steps, init_std: 0.1 }, &["onehot"]))
        .add(LayerConf::new(
            "proj",
            LayerKind::InnerProduct { out: steps * vocab, act: Activation::Identity, init_std: 0.1 },
            &["gru"],
        ))
        .add(LayerConf::new("loss", LayerKind::SeqSoftmaxLoss { steps }, &["proj", "labels"]))
        .build(&mut Rng::new(4));
    let mut alg = Bp::new();
    let mut upd = crate::updater::Updater::new(UpdaterConf::adagrad(0.1));
    for it in 0..iters {
        let inputs = corpus.batch(it as u64, batch);
        net.zero_grads();
        let stats = alg.train_one_batch(&mut net, &inputs);
        for p in net.params_mut() {
            upd.update_param(p, it as u64);
        }
        if it % (iters / 12).max(1) == 0 || it + 1 == iters {
            out.push_str(&format!(
                "{it}\t{:.4}\t{:.4}\n",
                stats.total_loss(),
                stats.metric()
            ));
        }
    }
    out
}

/// Fig 18(a): synchronous single-node — time/iteration vs threads for
/// SINGA-dist (worker parallelism) vs op-parallel BLAS systems.
pub fn fig18a(measured_ms: Option<f64>) -> String {
    let single = measured_ms.unwrap_or_else(|| measure_convnet_iter_ms(32, 1, 3) * 8.0); // scale to batch 256
    let mut out = header(
        "Fig 18a: time per iteration (ms) on a 24-core node, batch 256",
        &["threads", "singa_dist", "singa_1worker", "caffe", "cxxnet"],
    );
    for &t in &[1usize, 2, 4, 8, 16, 24, 32] {
        out.push_str(&format!(
            "{t}\t{:.1}\t{:.1}\t{:.1}\t{:.1}\n",
            singa_dist_time_ms(single, t, single * 0.004),
            OpParallelModel::singa_single().time_ms(single, t),
            OpParallelModel::caffe().time_ms(single * 1.05, t),
            OpParallelModel::cxxnet().time_ms(single * 1.02, t),
        ));
    }
    out
}

/// Fig 18(b): synchronous cluster scaling — SINGA AllReduce vs Petuum-style
/// central PS, workers 4..128 (batch 512).
pub fn fig18b(measured_ms: Option<f64>) -> String {
    let single = measured_ms.unwrap_or_else(|| measure_convnet_iter_ms(32, 1, 3) * 16.0); // batch 512
    let param_bytes = {
        let net = cifar_convnet(32).build(&mut Rng::new(1));
        net.param_count() * 4
    };
    let net_link = LinkModel::ethernet_1g();
    let mut out = header(
        "Fig 18b: cluster sync scaling, batch 512 (ms/iteration)",
        &["workers", "singa_allreduce", "petuum_central_ps"],
    );
    for &w in &[4usize, 8, 16, 32, 64, 128] {
        let nodes = (w / 4).max(1);
        out.push_str(&format!(
            "{w}\t{:.1}\t{:.1}\n",
            allreduce_cluster_time_ms(single, w, nodes, param_bytes, &net_link),
            central_ps_cluster_time_ms(single * 1.02, w, param_bytes, &net_link),
        ));
    }
    out
}

/// Fig 19(a,b): in-memory asynchronous training — accuracy vs virtual time
/// for 1..`max_groups` worker groups, SINGA Downpour vs Caffe-style Hogwild
/// (worker-side updates ≈ no server thread → slightly slower updates and
/// more contention; modeled by a per-update penalty on the virtual clock).
pub fn fig19ab(max_groups: usize, iters: u64) -> String {
    let mut out = header(
        "Fig 19ab: async in-memory, accuracy vs virtual ms",
        &["system", "groups", "virt_ms_final", "final_acc", "t_to_acc60"],
    );
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(256, 10, 21));
    let mut groups = 1;
    while groups <= max_groups {
        for (system, lr_penalty) in [("singa_downpour", 1.0f64), ("caffe_hogwild", 1.35)] {
            let b = NetBuilder::new()
                .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 256] }, &[]))
                .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
                .add(LayerConf::new(
                    "h1",
                    LayerKind::InnerProduct { out: 64, act: Activation::Relu, init_std: 0.08 },
                    &["data"],
                ))
                .add(LayerConf::new(
                    "logits",
                    LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.08 },
                    &["h1"],
                ))
                .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
            let mut conf = JobConf::new("fig19", b);
            conf.batch_size = 16;
            conf.iters = iters;
            conf.updater = UpdaterConf::sgd(0.15);
            conf.topology = ClusterTopology::downpour(groups, 1, 1);
            let report = run_job(&conf, data.clone());
            let recs = report.log.snapshot();
            let virt_final =
                report.group_virt_ms.iter().cloned().fold(0.0, f64::max) * lr_penalty;
            let final_acc: f32 = {
                let lasts: Vec<f32> = (0..groups)
                    .filter_map(|g| recs.iter().filter(|r| r.group == g).last().map(|r| r.metric))
                    .collect();
                lasts.iter().sum::<f32>() / lasts.len().max(1) as f32
            };
            let tta = report
                .log
                .time_to_metric(0.6, 5)
                .map(|t| format!("{:.1}", t * lr_penalty))
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "{system}\t{groups}\t{virt_final:.1}\t{final_acc:.3}\t{tta}\n"
            ));
        }
        groups *= 2;
    }
    out
}

/// Fig 19(c): distributed asynchronous Downpour — groups fixed, workers per
/// group varying; network-charged virtual clock.
pub fn fig19c(groups: usize, iters: u64) -> String {
    let mut out = header(
        "Fig 19c: distributed async, workers/group sweep",
        &["workers_per_group", "virt_ms_final", "final_acc"],
    );
    let data: Arc<dyn DataSource> = Arc::new(SyntheticDigits::new(256, 10, 33));
    for &wpg in &[1usize, 2, 4] {
        let mut b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 256] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 64, act: Activation::Relu, init_std: 0.08 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.08 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
        if wpg > 1 {
            for c in b.confs_mut().iter_mut() {
                if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
                    c.partition_dim = Some(0);
                }
            }
        }
        let mut conf = JobConf::new("fig19c", b);
        conf.batch_size = 16;
        conf.iters = iters;
        conf.updater = UpdaterConf::sgd(0.15);
        conf.topology = ClusterTopology::downpour(groups, wpg, groups);
        conf.partition_within_group = wpg > 1;
        conf.cost = CostModel::cluster();
        let report = run_job(&conf, data.clone());
        let recs = report.log.snapshot();
        let virt = report.group_virt_ms.iter().cloned().fold(0.0, f64::max);
        let acc: f32 = (0..groups)
            .filter_map(|g| recs.iter().filter(|r| r.group == g).last().map(|r| r.metric))
            .sum::<f32>()
            / groups as f32;
        out.push_str(&format!("{wpg}\t{virt:.1}\t{acc:.3}\n"));
    }
    out
}

/// Fig 20(a): overlap of computation and communication — time/iteration for
/// No/Sync/Async copy vs mini-batch size.
pub fn fig20a() -> String {
    let link = LinkModel::pcie3();
    let rates = UpdateRates::default();
    let mut out = header(
        "Fig 20a: copy modes (ms/iteration, alexnet-like)",
        &["batch", "no_copy", "sync_copy", "async_copy"],
    );
    for &batch in &[16usize, 32, 64, 128, 256] {
        let p = alexnet_like_profiles(batch);
        out.push_str(&format!(
            "{batch}\t{:.2}\t{:.2}\t{:.2}\n",
            iteration_time_us(&p, CopyMode::NoCopy, &link, &rates) / 1e3,
            iteration_time_us(&p, CopyMode::SyncCopy, &link, &rates) / 1e3,
            iteration_time_us(&p, CopyMode::AsyncCopy, &link, &rates) / 1e3,
        ));
    }
    out
}

/// Fig 20(b): reducing data transfer — data-parallel vs hybrid partitioning
/// of the first fully-connected layer, using *real* bridge-byte ledgers
/// from partitioned nets plus the link cost model.
pub fn fig20b() -> String {
    let mut out = header(
        "Fig 20b: partitioning of fc1 across 3 workers (ms/iteration)",
        &["batch", "single", "data_partition", "hybrid_partition", "data_bytes", "hybrid_bytes"],
    );
    for &batch in &[32usize, 64, 128, 256] {
        // fc1-like layer: 2048 -> 2048 (scaled-down AlexNet fc) on 3 workers.
        // Compute time is measured ONCE on the unpartitioned net and split
        // ideally across workers, so the variants differ only in their
        // (real, ledger-measured) communication — the quantity Fig 20b is
        // about.
        let measure = |dim: Option<usize>| -> usize {
            let mut b = NetBuilder::new()
                .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 2048] }, &[]))
                .add(LayerConf::new(
                    "fc1",
                    LayerKind::InnerProduct { out: 2048, act: Activation::Relu, init_std: 0.02 },
                    &["data"],
                ));
            if let Some(d) = dim {
                b.confs_mut()[1].partition_dim = Some(d);
            }
            let workers = if dim.is_some() { 3 } else { 1 };
            let (bp, _) = crate::model::partition::partition_net(&b, workers);
            let mut net = bp.build(&mut Rng::new(1));
            let mut rng = Rng::new(2);
            let x = Blob::from_vec(&[batch, 2048], rng.uniform_vec(batch * 2048, -1.0, 1.0));
            net.set_input("data", x);
            net.forward(Phase::Train);
            net.backward();
            let mut bytes = net.bridge_bytes();
            // data parallelism ships the replicated params instead
            if dim == Some(0) {
                bytes += 2 * 2048 * 2048 * 4; // grads down + values up
            } else if dim == Some(1) {
                bytes *= 2; // features fwd + grads bwd
            }
            bytes
        };
        let compute_ms = {
            let mut b = NetBuilder::new()
                .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 2048] }, &[]))
                .add(LayerConf::new(
                    "fc1",
                    LayerKind::InnerProduct { out: 2048, act: Activation::Relu, init_std: 0.02 },
                    &["data"],
                ));
            let mut net = b.clone().build(&mut Rng::new(1));
            let _ = &mut b;
            let mut rng = Rng::new(2);
            let x = Blob::from_vec(&[batch, 2048], rng.uniform_vec(batch * 2048, -1.0, 1.0));
            net.set_input("data", x);
            let sw = Stopwatch::new();
            net.forward(Phase::Train);
            net.backward();
            sw.elapsed_ms()
        };
        let comm = |bytes: usize| LinkModel::pcie3().transfer_us(bytes) / 1e3;
        let single = compute_ms;
        let db = measure(Some(0));
        let hb = measure(Some(1));
        let datap = compute_ms / 3.0 + comm(db);
        let hybrid = compute_ms / 3.0 + comm(hb);
        out.push_str(&format!(
            "{batch}\t{single:.2}\t{datap:.2}\t{hybrid:.2}\t{db}\t{hb}\n"
        ));
    }
    out
}

/// Fig 21(a): throughput (images/s), per-worker batch 96, workers 1..3.
pub fn fig21a() -> String {
    let link = LinkModel::pcie3();
    let rates = UpdateRates::default();
    let mut out = header(
        "Fig 21a: throughput images/s, batch 96/worker",
        &["workers", "SINGA", "Caffe", "Torch", "TensorFlow", "MxNet"],
    );
    for workers in 1..=3usize {
        let p = alexnet_like_profiles(96);
        let cells: Vec<String> = SystemPolicy::all()
            .iter()
            .map(|s| format!("{:.0}", s.throughput(&p, workers, 96, &link, &rates)))
            .collect();
        out.push_str(&format!("{workers}\t{}\n", cells.join("\t")));
    }
    out
}

/// Fig 21(b): efficiency — total batch fixed at 288, so per-worker batch is
/// 288/n; reports time per iteration (ms).
pub fn fig21b() -> String {
    let link = LinkModel::pcie3();
    let rates = UpdateRates::default();
    let mut out = header(
        "Fig 21b: time/iteration (ms), total batch 288",
        &["workers", "SINGA", "Caffe", "Torch", "TensorFlow", "MxNet"],
    );
    for workers in 1..=3usize {
        let per = 288 / workers;
        let p = alexnet_like_profiles(per);
        let cells: Vec<String> = SystemPolicy::all()
            .iter()
            .map(|s| format!("{:.1}", s.iteration_us(&p, workers, &link, &rates) / 1e3))
            .collect();
        out.push_str(&format!("{workers}\t{}\n", cells.join("\t")));
    }
    out
}

/// Ablation (DESIGN.md design choice): Fig 14's bottom-first priority for
/// fresh-parameter copies vs a top-first queue.
///
/// The copy queue is work-conserving (the link never idles while a copy is
/// available), so the priority only decides ties — which queued copy goes
/// next. Bottom-first therefore *weakly dominates*: it wins when big top-
/// layer transfers create a queue (AlexNet at small/mid batch) because the
/// next forward pass visits bottom layers first (the paper's rule: "the
/// fresh parameters of the bottom layers have higher priority because the
/// bottom layers will be visited earlier"), and ties when updates trickle
/// in slower than the link drains them (no queue, nothing to reorder).
pub fn ablation_priority() -> String {
    use crate::coordinator::copyqueue::{async_iteration_us_with_priority, LayerProfile};
    let link = LinkModel::pcie3();
    let rates = UpdateRates::default();
    let mut out = header(
        "Ablation: copy-queue priority (ms/iteration, async copy)",
        &["workload", "batch", "bottom_first", "top_first"],
    );
    let bottom_heavy = |batch: usize| -> Vec<LayerProfile> {
        let b = batch as f64;
        vec![
            LayerProfile { name: "embed".into(), fwd_us: 20.0 * b, bwd_us: 40.0 * b, param_bytes: 200_000_000 },
            LayerProfile { name: "mid".into(), fwd_us: 60.0 * b, bwd_us: 120.0 * b, param_bytes: 8_000_000 },
            LayerProfile { name: "head".into(), fwd_us: 10.0 * b, bwd_us: 20.0 * b, param_bytes: 1_000_000 },
        ]
    };
    for &batch in &[16usize, 64, 256] {
        let p = alexnet_like_profiles(batch);
        out.push_str(&format!(
            "alexnet\t{batch}\t{:.2}\t{:.2}\n",
            async_iteration_us_with_priority(&p, &link, &rates, true) / 1e3,
            async_iteration_us_with_priority(&p, &link, &rates, false) / 1e3,
        ));
        let p = bottom_heavy(batch);
        out.push_str(&format!(
            "bottom_heavy\t{batch}\t{:.2}\t{:.2}\n",
            async_iteration_us_with_priority(&p, &link, &rates, true) / 1e3,
            async_iteration_us_with_priority(&p, &link, &rates, false) / 1e3,
        ));
    }
    out
}

/// Ablation of the §5.4.1 partitioning rule: data parallelism is costlier
/// than model parallelism when `p > b*d` (replicated parameter bytes exceed
/// the feature bytes). Sweeps the ratio and reports the measured crossover.
pub fn ablation_partition_rule() -> String {
    let mut out = header(
        "Ablation: §5.4.1 rule — data vs model parallel comm bytes (fc layer, K=3)",
        &["batch", "d", "p_bytes", "data_comm", "model_comm", "cheaper"],
    );
    for &(batch, d) in &[(16usize, 512usize), (64, 512), (256, 512), (64, 4096), (512, 256)] {
        let p_bytes = d * d * 4; // square fc layer
        let data_comm = 2 * p_bytes; // grads down + values up, batch-free
        let model_comm = 2 * batch * d * 4; // features fwd + grads bwd
        let cheaper = if data_comm < model_comm { "data" } else { "model" };
        // paper rule: data costlier iff p > b*d
        let rule_says_model = p_bytes > batch * d * 4;
        assert_eq!(
            rule_says_model,
            cheaper == "model",
            "rule and measurement disagree at batch={batch}, d={d}"
        );
        out.push_str(&format!(
            "{batch}\t{d}\t{p_bytes}\t{data_comm}\t{model_comm}\t{cheaper}\n"
        ));
    }
    out
}

/// Run every figure (used by `cargo bench` and `repro all`); `quick` keeps
/// iteration counts small.
pub fn run_all(quick: bool) -> String {
    let (fig16_iters, fig17_iters, fig19_iters) =
        if quick { (80, 60, 40) } else { (400, 400, 200) };
    let measured = Some(measure_convnet_iter_ms(32, 1, if quick { 2 } else { 10 }) * 8.0);
    let mut out = String::new();
    out.push_str(&table1());
    out.push('\n');
    out.push_str(&fig16(fig16_iters));
    out.push('\n');
    out.push_str(&fig17(fig17_iters));
    out.push('\n');
    out.push_str(&fig18a(measured));
    out.push('\n');
    out.push_str(&fig18b(measured.map(|m| m * 2.0)));
    out.push('\n');
    out.push_str(&fig19ab(if quick { 4 } else { 16 }, fig19_iters));
    out.push('\n');
    out.push_str(&fig19c(if quick { 2 } else { 4 }, fig19_iters));
    out.push('\n');
    out.push_str(&fig20a());
    out.push('\n');
    out.push_str(&fig20b());
    out.push('\n');
    out.push_str(&fig21a());
    out.push('\n');
    out.push_str(&fig21b());
    out.push('\n');
    out.push_str(&ablation_priority());
    out.push('\n');
    out.push_str(&ablation_partition_rule());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(line: &str, idx: usize) -> f64 {
        line.split('\t').nth(idx).unwrap().trim().parse().unwrap()
    }

    fn data_lines(tsv: &str) -> Vec<&str> {
        tsv.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).skip(1).collect()
    }

    #[test]
    fn fig18a_shape_singa_dist_wins_and_blas_knees() {
        let tsv = fig18a(Some(800.0));
        let lines = data_lines(&tsv);
        // at 8 threads singa-dist beats every op-parallel system
        let l8 = lines.iter().find(|l| l.starts_with("8\t")).unwrap();
        assert!(col(l8, 1) < col(l8, 2));
        assert!(col(l8, 1) < col(l8, 3));
        // 32-thread BLAS worse than 8-thread BLAS (NUMA knee)
        let l32 = lines.iter().find(|l| l.starts_with("32\t")).unwrap();
        assert!(col(l32, 3) > col(l8, 3));
    }

    #[test]
    fn fig18b_shape_allreduce_scales_ps_saturates() {
        let tsv = fig18b(Some(3000.0));
        let lines = data_lines(&tsv);
        let t4 = col(lines[0], 1);
        let t128 = col(lines[lines.len() - 1], 1);
        assert!(t128 < t4, "allreduce should keep improving");
        let p64 = col(lines[lines.len() - 2], 2);
        let p128 = col(lines[lines.len() - 1], 2);
        assert!(p128 > p64, "petuum-style should degrade at 128");
    }

    #[test]
    fn fig20a_shape_matches_paper() {
        let tsv = fig20a();
        let lines = data_lines(&tsv);
        for l in &lines {
            // async <= sync everywhere
            assert!(col(l, 3) <= col(l, 2) + 1e-6, "{l}");
        }
        // at batch 256 async beats no-copy
        let l256 = lines.iter().find(|l| l.starts_with("256\t")).unwrap();
        assert!(col(l256, 3) < col(l256, 1), "{l256}");
        // at batch 16 no-copy is fastest
        let l16 = lines.iter().find(|l| l.starts_with("16\t")).unwrap();
        assert!(col(l16, 1) < col(l16, 2));
    }

    #[test]
    fn fig20b_shape_hybrid_beats_data_partition() {
        let tsv = fig20b();
        for l in data_lines(&tsv) {
            assert!(col(l, 3) < col(l, 2), "hybrid should beat data partition: {l}");
        }
        // data-partition traffic is dominated by the (batch-independent)
        // parameter payload while hybrid traffic scales with the batch
        // (paper: "for data partitioning only parameter gradients and
        // values are transferred, which is independent of the mini-batch
        // size").
        let lines = data_lines(&tsv);
        let first = lines.first().unwrap();
        let last = lines.last().unwrap();
        let data_growth = col(last, 4) / col(first, 4);
        let hybrid_growth = col(last, 5) / col(first, 5);
        assert!(data_growth < 1.2, "data-parallel bytes ~constant: {data_growth}");
        assert!(hybrid_growth > 4.0, "hybrid bytes scale with batch: {hybrid_growth}");
    }

    #[test]
    fn fig21_shape_singa_wins_caffe_drops() {
        let tsv = fig21a();
        let lines = data_lines(&tsv);
        for l in &lines {
            let singa = col(l, 1);
            for i in 2..=5 {
                assert!(singa >= col(l, i) * 0.98, "singa loses: {l}");
            }
        }
        // caffe throughput drops from 2 to 3 workers
        let c2 = col(lines[1], 2);
        let c3 = col(lines[2], 2);
        assert!(c3 < c2);
        // fig21b: every system's time at 1 worker within a modest spread
        let t = fig21b();
        let l1 = data_lines(&t)[0];
        let vals: Vec<f64> = (1..=5).map(|i| col(l1, i)).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.7, "{vals:?}");
    }

    #[test]
    fn ablation_priority_bottom_first_weakly_dominates() {
        // With a work-conserving priority queue, bottom-first never loses:
        // it wins when several copies are queued (small/mid-batch alexnet,
        // where the big fc transfers create contention) and ties when the
        // link never has a choice to make.
        let tsv = ablation_priority();
        for l in data_lines(&tsv) {
            assert!(col(l, 2) <= col(l, 3) + 1e-6, "bottom-first should not lose: {l}");
        }
        let l = data_lines(&tsv)
            .into_iter()
            .find(|l| l.starts_with("alexnet\t16"))
            .unwrap();
        assert!(col(l, 2) < col(l, 3), "strict win under contention: {l}");
    }

    #[test]
    fn ablation_partition_rule_consistent() {
        // the asserts inside the harness check rule == measurement
        let tsv = ablation_partition_rule();
        assert!(tsv.contains("model"));
        assert!(tsv.contains("data"));
    }

    /// THE acceptance probe for the planned executor: after warm-up, one
    /// full training step (input copy + forward + backward + SGD) performs
    /// zero feature/gradient-blob allocations for both the MLP and the
    /// convnet.
    #[test]
    fn steady_state_training_is_allocation_free() {
        for p in alloc_probe(3) {
            assert_eq!(
                p.steady_allocs_per_step, 0.0,
                "{}: steady-state must not allocate blobs (got {} allocs/step)",
                p.model, p.steady_allocs_per_step
            );
            assert_eq!(
                p.steady_pack_allocs_per_step, 0.0,
                "{}: steady-state must not allocate gemm pack scratch (got {} allocs/step)",
                p.model, p.steady_pack_allocs_per_step
            );
            assert_eq!(
                p.steady_exec_allocs_per_step, 0.0,
                "{}: steady-state must not grow executor scratch (got {} allocs/step)",
                p.model, p.steady_exec_allocs_per_step
            );
            assert!(p.warmup_allocs > 0, "{}: warm-up sizes the workspace", p.model);
        }
    }

    /// THE acceptance probe for the zero-clone parameter plane: after
    /// warm-up, one full `run_job` training step — including the worker↔
    /// server round trip and hogwild's neighbour syncs — performs zero Blob
    /// allocations in every worker group, across all three frameworks.
    #[test]
    fn distributed_training_is_allocation_free() {
        for d in distributed_alloc_probe(3, 12) {
            assert_eq!(d.steady_allocs.len(), d.groups);
            for (g, &a) in d.steady_allocs.iter().enumerate() {
                assert_eq!(
                    a, 0,
                    "{}: worker group {g} allocated {a} blobs across {} post-warm-up steps",
                    d.topology, d.steady_steps
                );
            }
        }
    }

    /// THE acceptance probe for the overlapped exchange's clock modeling:
    /// on the cluster link model the convnet job — compute-heavy enough to
    /// hide its parameter traffic — must see a strictly smaller overlapped
    /// virtual step time than the sequential exchange, and its artifact
    /// must parse.
    #[test]
    fn overlap_probe_convnet_beats_sequential_on_cluster() {
        let probes = overlap_probe(4);
        // Per job: raw × {cluster, lan, local} + {f16, int8} × cluster.
        assert_eq!(probes.len(), 10);
        for p in &probes {
            assert!(p.buckets >= 1, "{}/{}/{}", p.job, p.cost, p.codec);
            assert!(p.seq_virt_step_ms > 0.0 && p.overlap_virt_step_ms > 0.0);
            assert!(p.step_flush_bytes > 0);
            match p.codec {
                "raw" => assert_eq!(p.wire_ratio_vs_raw, 1.0, "{}/{}", p.job, p.cost),
                _ => assert!(
                    p.wire_ratio_vs_raw > 0.0 && p.wire_ratio_vs_raw < 1.0,
                    "{}/{}/{}: ratio {}",
                    p.job,
                    p.cost,
                    p.codec,
                    p.wire_ratio_vs_raw
                ),
            }
        }
        let conv = probes
            .iter()
            .find(|p| p.job == "convnet" && p.cost == "cluster" && p.codec == "raw")
            .expect("convnet/cluster probe present");
        assert!(
            conv.virt_ratio < 1.0,
            "overlapped convnet step must beat sequential on the cluster model: \
             ratio {:.4} (seq {:.4} ms vs overlap {:.4} ms)",
            conv.virt_ratio,
            conv.seq_virt_step_ms,
            conv.overlap_virt_step_ms
        );
        let j = overlap_probes_json(&probes);
        assert!(j.contains("\"overlap_exchange\""));
        assert!(j.contains("\"convnet\""));
        assert!(j.contains("\"virt_ratio\""));
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    #[test]
    fn alloc_probe_json_is_well_formed() {
        let j = alloc_probe_json(2);
        assert!(j.contains("\"steady_state_alloc\""));
        assert!(j.contains("\"mlp\""));
        assert!(j.contains("\"cifar_convnet\""));
        // simd reruns ride in the same artifact (satellite of the kernel
        // dispatch work): both models again, forced onto the simd path
        assert!(j.contains("\"mlp+simd\""));
        assert!(j.contains("\"cifar_convnet+simd\""));
        assert!(j.contains("\"steady_pack_allocs_per_step\""));
        assert!(j.contains("\"steady_exec_allocs_per_step\""));
        // distributed run_job probe rides in the same artifact
        assert!(j.contains("\"distributed\""));
        assert!(j.contains("\"sandblaster(1,1)\""));
        assert!(j.contains("\"sandblaster(1,1)+f16\""));
        assert!(j.contains("\"sandblaster(1,1)+int8\""));
        assert!(j.contains("\"downpour(3,1,2)\""));
        assert!(j.contains("\"hogwild(2,1,10)\""));
        assert!(j.contains("\"steady_allocs_per_group\""));
        // trivially parseable by the in-repo JSON reader
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    /// The fault-recovery probe's invariants: no scenario perturbs training
    /// values, the kill scenario recovers (one fault event, a restored
    /// checkpoint, a strictly positive recovery charge), backups rescue
    /// every delayed step, and the JSON artifact parses. Overhead
    /// magnitudes are machine-dependent and only recorded — except the kill
    /// scenario's, whose restart latency is a pure virtual charge and must
    /// show up as > 1×.
    #[test]
    fn faults_probe_pins_recovery_invariants() {
        let probes = faults_probe(6);
        assert_eq!(probes.len(), 2 * 2 * 5, "2 jobs x 2 costs x 5 scenarios");
        for p in &probes {
            let tag = format!("{}/{}/{}", p.job, p.cost, p.scenario);
            assert!(p.values_bitwise, "{tag}: faults must never perturb values");
            assert!(p.virt_ms > 0.0, "{tag}");
            match p.scenario {
                "baseline" => {
                    assert_eq!(p.fault_events, 0, "{tag}");
                    assert_eq!(p.checkpoints, 0, "{tag}");
                    assert_eq!(p.overhead_ratio, 1.0, "{tag}");
                }
                "ckpt" => {
                    assert_eq!(p.fault_events, 0, "{tag}");
                    assert!(p.checkpoints >= 1, "{tag}: cadence must snapshot");
                }
                "ckpt+kill" => {
                    assert_eq!(p.fault_events, 1, "{tag}: the kill must be recovered");
                    assert!(p.checkpoints >= 1, "{tag}");
                    assert!(p.recovery_virt_ms > 0.0, "{tag}");
                    assert!(
                        p.overhead_ratio > 1.0,
                        "{tag}: restart latency must cost virtual time ({:.4})",
                        p.overhead_ratio
                    );
                }
                "straggler" => assert_eq!(p.backup_rescues, 0, "{tag}"),
                "straggler+backup" => {
                    assert!(p.backup_rescues >= 1, "{tag}: backups must rescue");
                    assert_eq!(p.fault_events, 0, "{tag}: delays are not kills");
                }
                other => panic!("unknown scenario {other}"),
            }
        }
        let j = faults_probes_json(&probes);
        assert!(j.contains("\"fault_recovery\""));
        assert!(j.contains("\"ckpt+kill\""));
        assert!(j.contains("\"straggler+backup\""));
        assert!(j.contains("\"values_bitwise\": true"));
        assert!(j.contains("\"recovery_virt_ms\""));
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    /// The wire-chaos probe must show the retry protocol working: lossy
    /// scenarios that eventually deliver end bitwise identical to the
    /// lossless baseline while paying virtual time and wasted bytes; the
    /// severed scenario degrades to recorded staleness; and the JSON
    /// artifact parses.
    #[test]
    fn chaos_probe_pins_retry_invariants() {
        let probes = chaos_probe(6);
        assert_eq!(probes.len(), 2 * 4, "2 codecs x 4 scenarios");
        for p in &probes {
            let tag = format!("{}/{}", p.codec, p.scenario);
            assert!(p.virt_ms > 0.0, "{tag}");
            match p.scenario {
                "lossless" => {
                    assert_eq!(p.wasted_bytes, 0, "{tag}");
                    assert_eq!(p.retransmits, 0, "{tag}");
                    assert_eq!(p.degraded_steps, 0, "{tag}");
                    assert_eq!(p.overhead_ratio, 1.0, "{tag}");
                }
                "drop+retry" | "corrupt+retry" => {
                    assert!(p.values_bitwise, "{tag}: eventual delivery must be bitwise");
                    assert!(p.retransmits > 0, "{tag}: retries must fire");
                    assert_eq!(p.degraded_steps, 0, "{tag}: retries must prevent degradation");
                    assert!(p.goodput_ratio < 1.0, "{tag}: wasted copies must be charged");
                    assert!(
                        p.overhead_ratio > 1.0,
                        "{tag}: timeouts and retransmits must cost virtual time ({:.4})",
                        p.overhead_ratio
                    );
                }
                "severed" => {
                    assert!(p.degraded_steps > 0, "{tag}: a dead link must degrade");
                    assert!(p.staleness_adoptions > 0, "{tag}");
                }
                other => panic!("unknown scenario {other}"),
            }
        }
        let j = chaos_probes_json(&probes);
        assert!(j.contains("\"wire_chaos\""));
        assert!(j.contains("\"drop+retry\""));
        assert!(j.contains("\"severed\""));
        assert!(j.contains("\"goodput_ratio\""));
        assert!(j.contains("\"retransmit_rate\""));
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    /// The conv scaling probe's determinism flag must hold (parallel ==
    /// serial exactly for both im2col and the full conv2d forward) and its
    /// JSON artifact must parse. Speedup magnitude is machine-dependent and
    /// only recorded.
    #[test]
    fn conv_probe_is_bit_identical_and_json_parses() {
        let probes = conv_scaling_probe(4, 0, 1);
        for p in &probes {
            assert!(p.bit_identical, "{}: parallel must equal serial", p.name);
            assert!(p.im2col_serial_ms > 0.0 && p.im2col_parallel_ms > 0.0, "{}", p.name);
            assert!(p.conv_serial_ms > 0.0 && p.conv_parallel_ms > 0.0, "{}", p.name);
            assert!(p.transforms_simd_exact, "{}: simd transforms must be exact", p.name);
            assert!(p.conv_simd_close, "{}: simd conv must approximate scalar", p.name);
            assert!(p.im2col_simd_ms > 0.0 && p.conv_simd_ms > 0.0, "{}", p.name);
        }
        let j = conv_probes_json(4, &probes);
        assert!(j.contains("\"conv_scaling\""));
        assert!(j.contains("\"bit_identical\": true"));
        assert!(j.contains("\"kernel\""));
        assert!(j.contains("\"transforms_simd_exact\": true"));
        assert!(j.contains("\"metrics\""));
        assert!(j.contains("\"direction\": \"higher_is_better\""));
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    /// The scaling probe's determinism flag must hold (parallel == serial
    /// exactly) and its JSON artifact must parse. Speedup magnitude is
    /// machine-dependent and asserted only by the CI smoke step.
    #[test]
    fn gemm_probe_is_bit_identical_and_json_parses() {
        let probes = gemm_scaling_probe(&[64, 96], 4, 0, 1);
        for p in &probes {
            assert!(p.bit_identical, "n={}: parallel must equal serial", p.n);
            assert!(p.serial_ms > 0.0 && p.parallel_ms > 0.0, "n={}", p.n);
            assert!(p.speedup > 0.0, "n={}", p.n);
            assert!(p.simd_close, "n={}: simd must approximate scalar", p.n);
            assert!(p.scalar_ms > 0.0 && p.simd_ms > 0.0, "n={}", p.n);
            assert!(p.simd_speedup > 0.0, "n={}", p.n);
        }
        let j = gemm_probes_json(4, &probes);
        assert!(j.contains("\"gemm_scaling\""));
        assert!(j.contains("\"bit_identical\": true"));
        assert!(j.contains("\"kernel\""));
        assert!(j.contains("\"simd_close\": true"));
        assert!(j.contains("\"metrics\""));
        assert!(j.contains("\"unit\": \"GFLOP/s\""));
        assert!(crate::utils::json::Json::parse(&j).is_ok());
    }

    #[test]
    fn table1_lists_all_features() {
        let t = table1();
        for f in ["RNN", "hybrid parallelism", "energy model"] {
            assert!(t.contains(f));
        }
    }
}
