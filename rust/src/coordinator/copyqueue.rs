//! The GPU worker's data-copy queue (paper Fig 14, §5.4.2 / §6.3.2): a
//! discrete-event model of one training iteration under the three
//! host↔device communication regimes the paper compares in Fig 20(a).
//!
//! * **NoCopy** — everything (BP + parameter update) on the device; no
//!   host↔device traffic, but the update is serialized after BP.
//! * **SyncCopy** — BP on device, update on host; gradients copied after the
//!   whole backward pass, fresh values copied back before the next
//!   iteration. Copies block the worker.
//! * **AsyncCopy** — each layer's gradient copy is *initiated* the moment
//!   its `ComputeGradient` finishes (BridgeSrc semantics) and overlaps the
//!   remaining backward compute; the host updates as gradients arrive and
//!   enqueues fresh-value copy events, prioritized bottom-layer-first so
//!   the next iteration's forward pass is not blocked.
//!
//! The event simulation runs two iterations and reports the steady-state
//! (second) iteration time.

use crate::comm::LinkModel;

/// Static per-layer profile measured from real executions.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub name: String,
    /// Forward compute time on the device, µs.
    pub fwd_us: f64,
    /// Backward compute time on the device, µs.
    pub bwd_us: f64,
    /// Bytes of parameters (== bytes of gradients) this layer owns.
    pub param_bytes: usize,
}

/// Host/device copy regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyMode {
    NoCopy,
    SyncCopy,
    AsyncCopy,
}

/// Update-throughput assumptions (µs per megabyte of parameters).
#[derive(Debug, Clone, Copy)]
pub struct UpdateRates {
    /// Device-side SGD update rate (NoCopy mode).
    pub device_us_per_mb: f64,
    /// Host-side update rate (server thread).
    pub host_us_per_mb: f64,
}

impl Default for UpdateRates {
    fn default() -> UpdateRates {
        // Device updates are memory-bandwidth-bound and fast; host update
        // runs on a CPU core in parallel with BP.
        UpdateRates { device_us_per_mb: 60.0, host_us_per_mb: 250.0 }
    }
}

fn mb(bytes: usize) -> f64 {
    bytes as f64 / 1e6
}

/// Steady-state time of one training iteration (µs).
pub fn iteration_time_us(
    layers: &[LayerProfile],
    mode: CopyMode,
    link: &LinkModel,
    rates: &UpdateRates,
) -> f64 {
    let fwd_total: f64 = layers.iter().map(|l| l.fwd_us).sum();
    let bwd_total: f64 = layers.iter().map(|l| l.bwd_us).sum();
    let param_total: usize = layers.iter().map(|l| l.param_bytes).sum();

    match mode {
        CopyMode::NoCopy => {
            // BP then device-side update, strictly sequential on the device
            // (paper: "No Copy has to do BP and parameter updating in
            // sequential").
            fwd_total + bwd_total + mb(param_total) * rates.device_us_per_mb
        }
        CopyMode::SyncCopy => {
            // BP, then grads down, host update, values up — all blocking.
            fwd_total
                + bwd_total
                + link.transfer_us(param_total)
                + mb(param_total) * rates.host_us_per_mb
                + link.transfer_us(param_total)
        }
        CopyMode::AsyncCopy => async_iteration_us(layers, link, rates, true),
    }
}

/// AsyncCopy with an explicit up-link priority policy — the Fig 14 design
/// choice. `bottom_first = false` reverses the copy order (top layers
/// first), the ablation in `bench::ablation_priority`.
pub fn async_iteration_us_with_priority(
    layers: &[LayerProfile],
    link: &LinkModel,
    rates: &UpdateRates,
    bottom_first: bool,
) -> f64 {
    async_iteration_us(layers, link, rates, bottom_first)
}

/// Event-driven simulation of the AsyncCopy pipeline across two iterations;
/// returns the second (steady-state) iteration's span.
fn async_iteration_us(
    layers: &[LayerProfile],
    link: &LinkModel,
    rates: &UpdateRates,
    bottom_first: bool,
) -> f64 {
    let n = layers.len();
    // --- Iteration 1: forward then backward, launching grad copies. ---
    let mut t = 0.0f64; // device clock
    for l in layers {
        t += l.fwd_us;
    }
    // Backward visits layers in reverse; record when each layer's gradient
    // is ready on the device.
    let mut grad_ready = vec![0.0f64; n];
    for i in (0..n).rev() {
        t += layers[i].bwd_us;
        grad_ready[i] = t;
    }
    let bp_end = t;

    // Down-link (device→host): FIFO in grad-ready order (top layer first).
    let mut down_free = 0.0f64;
    let mut grad_arrive = vec![0.0f64; n];
    for i in (0..n).rev() {
        if layers[i].param_bytes == 0 {
            grad_arrive[i] = grad_ready[i];
            continue;
        }
        let start = grad_ready[i].max(down_free);
        down_free = start + link.transfer_us(layers[i].param_bytes);
        grad_arrive[i] = down_free;
    }

    // Host server updates as gradients arrive (single server thread).
    let mut host_free = 0.0f64;
    let mut upd_done = vec![0.0f64; n];
    for i in (0..n).rev() {
        if layers[i].param_bytes == 0 {
            upd_done[i] = grad_arrive[i];
            continue;
        }
        let start = grad_arrive[i].max(host_free);
        host_free = start + mb(layers[i].param_bytes) * rates.host_us_per_mb;
        upd_done[i] = host_free;
    }

    // Up-link (host→device): a priority queue over the copy events. When
    // the link frees, the highest-priority *available* event is sent —
    // bottom-first priority (paper: "fresh parameters of the bottom layers
    // have higher priority because the bottom layers will be visited
    // earlier in the next iteration") vs the top-first ablation. The link
    // never idles while any copy is available.
    let mut up_free = 0.0f64;
    let mut param_ready = vec![0.0f64; n];
    let mut pending: Vec<usize> = (0..n).filter(|&i| layers[i].param_bytes > 0).collect();
    while !pending.is_empty() {
        // Advance to the next availability if nothing is ready.
        let earliest = pending.iter().map(|&i| upd_done[i]).fold(f64::INFINITY, f64::min);
        if up_free < earliest {
            up_free = earliest;
        }
        // Highest-priority available event.
        let pick_pos = pending
            .iter()
            .enumerate()
            .filter(|(_, &i)| upd_done[i] <= up_free)
            .min_by_key(|(_, &i)| if bottom_first { i as isize } else { -(i as isize) })
            .map(|(pos, _)| pos)
            .expect("some event is available after advancing");
        let i = pending.swap_remove(pick_pos);
        up_free += link.transfer_us(layers[i].param_bytes);
        param_ready[i] = up_free;
    }

    // --- Iteration 2: forward blocked per-layer on fresh params. ---
    let mut dev = bp_end; // device continues immediately (data loading etc.)
    for (i, l) in layers.iter().enumerate() {
        dev = dev.max(param_ready[i]);
        dev += l.fwd_us;
    }
    for i in (0..n).rev() {
        dev += layers[i].bwd_us;
    }
    dev - bp_end
}

/// Build layer profiles for an AlexNet-like net scaled by mini-batch size:
/// compute scales with batch; parameter bytes do not (paper Fig 20's x-axis
/// behaviour). `conv_heavy` matches Krizhevsky's 90/5 compute/param split.
pub fn alexnet_like_profiles(batch: usize) -> Vec<LayerProfile> {
    let b = batch as f64;
    vec![
        LayerProfile { name: "conv1".into(), fwd_us: 90.0 * b, bwd_us: 180.0 * b, param_bytes: 140_000 },
        LayerProfile { name: "pool1".into(), fwd_us: 8.0 * b, bwd_us: 10.0 * b, param_bytes: 0 },
        LayerProfile { name: "conv2".into(), fwd_us: 130.0 * b, bwd_us: 260.0 * b, param_bytes: 1_200_000 },
        LayerProfile { name: "pool2".into(), fwd_us: 6.0 * b, bwd_us: 8.0 * b, param_bytes: 0 },
        LayerProfile { name: "conv3".into(), fwd_us: 75.0 * b, bwd_us: 150.0 * b, param_bytes: 3_500_000 },
        LayerProfile { name: "fc1".into(), fwd_us: 18.0 * b, bwd_us: 36.0 * b, param_bytes: 150_000_000 },
        LayerProfile { name: "fc2".into(), fwd_us: 7.0 * b, bwd_us: 14.0 * b, param_bytes: 64_000_000 },
        LayerProfile { name: "softmax".into(), fwd_us: 2.0 * b, bwd_us: 2.0 * b, param_bytes: 16_000_000 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiles(batch: usize) -> Vec<LayerProfile> {
        alexnet_like_profiles(batch)
    }

    #[test]
    fn async_never_slower_than_sync() {
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        for batch in [16, 32, 64, 128, 256] {
            let p = profiles(batch);
            let sync = iteration_time_us(&p, CopyMode::SyncCopy, &link, &rates);
            let async_ = iteration_time_us(&p, CopyMode::AsyncCopy, &link, &rates);
            assert!(
                async_ <= sync + 1.0,
                "batch {batch}: async {async_} vs sync {sync}"
            );
        }
    }

    #[test]
    fn gap_shrinks_with_batch_size() {
        // Paper Fig 20a: larger batches → more compute to overlap with →
        // smaller relative Sync-vs-Async gap.
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let rel_gap = |batch: usize| {
            let p = profiles(batch);
            let sync = iteration_time_us(&p, CopyMode::SyncCopy, &link, &rates);
            let async_ = iteration_time_us(&p, CopyMode::AsyncCopy, &link, &rates);
            (sync - async_) / sync
        };
        assert!(rel_gap(16) > rel_gap(256), "{} vs {}", rel_gap(16), rel_gap(256));
    }

    #[test]
    fn async_beats_nocopy_at_large_batch() {
        // Paper: at batch 256 AsyncCopy is faster than NoCopy because the
        // server updates in parallel with BP while NoCopy serializes them.
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let p = profiles(256);
        let nocopy = iteration_time_us(&p, CopyMode::NoCopy, &link, &rates);
        let async_ = iteration_time_us(&p, CopyMode::AsyncCopy, &link, &rates);
        assert!(async_ < nocopy, "async {async_} vs nocopy {nocopy}");
    }

    #[test]
    fn nocopy_fastest_at_small_batch() {
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let p = profiles(16);
        let nocopy = iteration_time_us(&p, CopyMode::NoCopy, &link, &rates);
        let sync = iteration_time_us(&p, CopyMode::SyncCopy, &link, &rates);
        assert!(nocopy < sync);
    }

    #[test]
    fn zero_param_layers_add_no_traffic() {
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let p = vec![LayerProfile { name: "relu".into(), fwd_us: 10.0, bwd_us: 10.0, param_bytes: 0 }];
        let sync = iteration_time_us(&p, CopyMode::SyncCopy, &link, &rates);
        // only the two zero-byte "transfers" (latency) separate from compute
        assert!((sync - (20.0 + 2.0 * link.latency_us)).abs() < 1e-6);
        let async_ = iteration_time_us(&p, CopyMode::AsyncCopy, &link, &rates);
        assert!((async_ - 20.0).abs() < 1e-6);
    }
}
