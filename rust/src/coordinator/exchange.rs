//! The per-worker-group parameter exchange: bucketed gradient flush during
//! backward plus fresh-value prefetch, overlapping communication with
//! computation (paper §5: a layer's gradients are transferred as soon as
//! its `ComputeGradient` finishes, so network time hides behind the
//! remaining backward work and step time approaches `max(compute, comm)`).
//!
//! One [`GroupExchange`] per worker group owns the persistent
//! [`ParamWorkspace`] (routing + bucket buffers) and, in overlap mode, a
//! *comm driver* thread. The worker thread implements [`GradObserver`]:
//! when the backward hook completes a bucket's last contributing layer, it
//! aggregates the replica gradients into the bucket's persistent sum slots
//! (historical order — bit-identical) and enqueues the bucket; the comm
//! driver drains the queue FIFO, pushing each slot through the server's
//! fused updater into the bucket's fresh slots and publishing a new epoch.
//! The next step's forward adopts fresh values bucket by bucket, blocking
//! per-bucket on its epoch's condvar — never on the whole exchange — and
//! the initial fetch is just a prefetch of the first forward's buckets.
//!
//! On the simnet clock, each bucket's wire bytes are charged to a
//! [`LinkTimeline`] at the virtual instant the bucket was flushed;
//! consumers max-merge the finish times instead of summing transfer costs,
//! so overlapped virtual step time is honestly `max`-composed (see
//! [`crate::bench::overlap_probe`] for the sequential-vs-overlapped
//! comparison). Sequential mode (`JobConf::overlap_exchange = false`)
//! keeps the PR 4 blocking exchange, bit-identical in values and in
//! virtual-clock accounting to the historical code.

use super::workspace::{
    self, BucketStore, ExchangePlan, ParamWorkspace, WireCounters, WireOp, WirePlane,
};
use super::JobConf;
use crate::comm::{LinkModel, LinkTimeline, VirtualClock};
use crate::model::net::GradObserver;
use crate::model::NeuralNet;
use crate::server::ServerGroup;
use crate::tensor::Blob;
use crate::utils::timer::Stopwatch;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

/// Work items handed to the comm driver over its mpsc channel, processed
/// FIFO. Dropping the sender retires the driver (its `recv` errors out),
/// so shutdown needs no dedicated message.
enum CommJob {
    /// Fill the bucket's fresh slots from the server (initial prefetch).
    /// `flush_us` is the virtual send instant — the armed (retry-protocol)
    /// driver charges the shared wire timeline itself; unarmed mode ignores
    /// it (the observer already stamped the timeline inline).
    Prefetch { bucket: usize, flush_us: f64 },
    /// Push the bucket's aggregated sums through the server's updater and
    /// receive fresh values (the steady-state flush of step `step`).
    Flush { bucket: usize, step: u64, flush_us: f64 },
}

/// Body of the comm-driver thread: drain bucket jobs against the server
/// group, publishing epochs as buckets complete; exits when the worker
/// drops its sender. Blob allocations made while processing flushes of
/// probed steps (`>= probe_from`) are tallied into `allocs` — the comm
/// driver is part of the worker group's zero-alloc steady-state claim.
fn comm_driver_loop(
    plan: &ExchangePlan,
    store: &BucketStore,
    sg: &ServerGroup,
    jobs: mpsc::Receiver<CommJob>,
    allocs: &AtomicU64,
    probe_from: Option<u64>,
    base: u64,
    wire: Option<&WirePlane>,
) {
    while let Ok(job) = jobs.recv() {
        match job {
            CommJob::Prefetch { bucket, flush_us } => match wire {
                Some(w) => {
                    let op = WireOp::Prefetch;
                    workspace::deliver(plan, store, sg, w, bucket, op, base, flush_us);
                }
                None => workspace::fill_fresh(plan, store, sg, bucket),
            },
            CommJob::Flush { bucket, step, flush_us } => {
                let probed = probe_from.is_some_and(|from| step >= from);
                let before = if probed { Blob::alloc_count() } else { 0 };
                match wire {
                    Some(w) => {
                        let op = WireOp::Flush { step };
                        workspace::deliver(plan, store, sg, w, bucket, op, base, flush_us);
                    }
                    None => workspace::apply_flush(plan, store, sg, bucket, step, base),
                }
                if probed {
                    allocs.fetch_add(Blob::alloc_count() - before, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Runs when the comm-driver thread exits — cleanly or by panic: marks
/// the driver dead and wakes every bucket condvar (holding each bucket's
/// lock around the notify so the wakeup cannot be lost), so a worker
/// waiting on an epoch the dead driver will never publish panics visibly
/// instead of hanging the job. Sequential mode never mirrors the server
/// panic this guards against — the same panic would surface inline — so
/// overlap mode must not trade it for a silent deadlock.
struct DriverExitGuard {
    store: Arc<BucketStore>,
    dead: Arc<AtomicBool>,
}

impl Drop for DriverExitGuard {
    fn drop(&mut self) {
        self.dead.store(true, Ordering::SeqCst);
        for (mx, cv) in &self.store.bufs {
            // Acquire the bucket lock (poisoned or not) around the notify:
            // a waiter is either inside `cv.wait` (woken) or holds the lock
            // checking the dead flag (sees it) — never in between.
            let guard = mx.lock();
            cv.notify_all();
            drop(guard);
        }
    }
}

/// One worker group's parameter-exchange pipeline (see module docs).
pub struct GroupExchange {
    ws: ParamWorkspace,
    overlap: bool,
    link: LinkModel,
    /// Ideal intra-group compute split (workers per group) — flush
    /// timestamps scale by it exactly like the step's compute charge.
    k: f64,
    /// Serialized virtual timeline of the group's parameter link (unarmed
    /// mode; the armed protocol's shared timeline lives in [`WirePlane`]).
    timeline: LinkTimeline,
    /// The retry protocol, present iff the fault plan carries wire rules:
    /// link + fault stream + retry knobs + shared timeline + counters,
    /// shared with the comm driver. `None` runs the historical (frameless,
    /// retry-free) plane bit-for-bit.
    wire: Option<Arc<WirePlane>>,
    /// Job channel to the comm driver; dropped to retire it.
    tx: Option<mpsc::Sender<CommJob>>,
    comm: Option<std::thread::JoinHandle<()>>,
    /// Set by [`DriverExitGuard`] when the comm driver exits; epoch waits
    /// check it so a dead driver fails fast instead of hanging.
    driver_dead: Arc<AtomicBool>,
    comm_allocs: Arc<AtomicU64>,
    /// Per-bucket countdown of contributing nodes for the current step.
    outstanding: Vec<usize>,
    step: u64,
    /// First step this exchange will run (0 for a fresh job; the resume
    /// step after a worker-group restart). Bucket epochs count relative to
    /// it, so a restarted exchange's prefetch (epoch 1) satisfies its
    /// first consumer exactly like step 0's did.
    base: u64,
    step_start_virt_us: f64,
    sw: Stopwatch,
}

impl GroupExchange {
    /// Resolve the workspace for `net` and, in overlap mode, start the
    /// comm driver against `servers[server_group]`. `start_step` is the
    /// first step this exchange will run (non-zero when a worker group
    /// restarts mid-job — see [`super::worker_group_loop`]). `group` is the
    /// worker-group index the fault plan's wire rules key on, and
    /// `wire_counters` the group's job-lifetime wire tallies — required
    /// (and the retry protocol armed) iff the plan carries wire rules.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        net: &NeuralNet,
        conf: &JobConf,
        servers: &Arc<Vec<ServerGroup>>,
        server_group: usize,
        link: LinkModel,
        workers: usize,
        start_step: u64,
        group: usize,
        wire_counters: Option<Arc<WireCounters>>,
    ) -> GroupExchange {
        let wire = if conf.faults.has_wire_faults() {
            let counters =
                wire_counters.expect("wire-faulted jobs must supply the group's wire counters");
            let plane = WirePlane::new(group, link, conf.faults.clone(), conf.retry, counters);
            Some(Arc::new(plane))
        } else {
            None
        };
        let ws = ParamWorkspace::new_framed(
            net,
            conf.bucket_coalesce_bytes,
            conf.wire_codec,
            wire.is_some(),
        );
        let outstanding = vec![0usize; ws.nbuckets()]; // lint: alloc-ok(exchange construction, once per job)
        let comm_allocs = Arc::new(AtomicU64::new(0));
        let driver_dead = Arc::new(AtomicBool::new(false));
        let (tx, comm) = if conf.overlap_exchange {
            let (tx, rx) = mpsc::channel();
            let plan = ws.plan().clone();
            let store = ws.store().clone();
            let servers = servers.clone();
            let allocs = comm_allocs.clone();
            let dead = driver_dead.clone();
            let probe_from = conf.alloc_probe_from;
            let driver_wire = wire.clone();
            let handle = std::thread::Builder::new()
                .name(format!("comm-sg{server_group}"))
                .spawn(move || {
                    let _wake_on_exit =
                        DriverExitGuard { store: store.clone(), dead: dead.clone() };
                    comm_driver_loop(
                        &plan,
                        &store,
                        &servers[server_group],
                        rx,
                        &allocs,
                        probe_from,
                        start_step,
                        driver_wire.as_deref(),
                    )
                })
                .expect("spawn comm driver");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        GroupExchange {
            ws,
            overlap: conf.overlap_exchange,
            link,
            k: workers.max(1) as f64,
            timeline: LinkTimeline::new(),
            wire,
            tx,
            comm,
            driver_dead,
            comm_allocs,
            outstanding,
            step: start_step,
            base: start_step,
            step_start_virt_us: 0.0,
            sw: Stopwatch::new(),
        }
    }

    pub fn workspace(&self) -> &ParamWorkspace {
        &self.ws
    }

    /// Initial parameter fetch. Overlap mode enqueues one prefetch per
    /// bucket (the comm driver fills fresh slots while the worker loads
    /// its first batch) with pipelined per-bucket transfer charges;
    /// sequential mode fetches inline and charges one bulk transfer — the
    /// historical accounting, bit for bit.
    pub fn prefetch(&mut self, sg: &ServerGroup, clock: &mut VirtualClock) {
        if self.overlap {
            for b in 0..self.ws.nbuckets() {
                if self.wire.is_none() {
                    // Unarmed: the historical inline timeline stamp. The
                    // armed driver charges the shared timeline itself
                    // (faults included) and stamps the finish in `deliver`.
                    let bytes = self.ws.plan().buckets[b].fetch_bytes;
                    let finish = self.timeline.flush(&self.link, clock.us, bytes);
                    self.ws.store().bufs[b].0.lock().unwrap().finish_virt_us = finish;
                }
                self.send(CommJob::Prefetch { bucket: b, flush_us: clock.us });
            }
            return;
        }
        let plan = self.ws.plan();
        let store = self.ws.store();
        if let Some(w) = &self.wire {
            // Armed sequential: each bucket runs the full retry protocol
            // inline, serialized on the shared timeline; the clock
            // max-merges every bucket's delivery (or degradation) instant.
            let op = WireOp::Prefetch;
            for b in 0..plan.buckets.len() {
                let fin = workspace::deliver(plan, store, sg, w, b, op, self.base, clock.us);
                clock.merge_us(fin);
            }
            return;
        }
        let mut bytes = 0usize;
        for b in 0..plan.buckets.len() {
            workspace::fill_fresh(plan, store, sg, b);
            store.bufs[b].0.lock().unwrap().finish_virt_us = clock.us;
            bytes += plan.buckets[b].fetch_bytes;
        }
        clock.transfer(&self.link, bytes);
    }

    /// Adopt the fresh values every bucket produced for `step`, waiting
    /// per-bucket on its epoch (the paper's per-param blocking — bottom
    /// buckets, needed first by the forward pass, are waited on first) and
    /// max-merging each bucket's virtual finish time into the clock.
    /// The exchange's first step adopts the prefetched server state without
    /// a version bump (the historical initial distribute); later steps bump
    /// versions like the historical write-back.
    pub fn consume_fresh(&self, net: &mut NeuralNet, step: u64, clock: &mut VirtualClock) {
        debug_assert!(step >= self.base, "consume_fresh before the exchange's start step");
        let rel = step - self.base;
        let plan = self.ws.plan();
        let store = self.ws.store();
        let mut params = net.params_mut();
        for (spec, (mx, cv)) in plan.buckets.iter().zip(&store.bufs) {
            let mut buf = mx.lock().unwrap();
            while buf.epoch < rel + 1 {
                assert!(
                    !self.driver_dead.load(Ordering::SeqCst),
                    "comm driver died before publishing a bucket epoch"
                );
                if self.wire.is_some() {
                    // Bounded wait under the retry plane: every bucket's
                    // protocol terminates (delivery or degradation after
                    // max_attempts), so a 30s real-time stall means the
                    // driver wedged — fail loudly instead of hanging.
                    let dur = std::time::Duration::from_secs(30);
                    let (guard, timed_out) = cv.wait_timeout(buf, dur).unwrap();
                    buf = guard;
                    assert!(
                        !(timed_out && buf.epoch < rel + 1),
                        "bucket epoch wait exceeded 30s under the retry plane"
                    );
                } else {
                    buf = cv.wait(buf).unwrap();
                }
            }
            clock.merge_us(buf.finish_virt_us);
            for (i, &s) in spec.slots.iter().enumerate() {
                for &j in &plan.slots[s].params {
                    let p = &mut params[j];
                    if rel == 0 {
                        assert_eq!(
                            buf.fresh[i].shape(),
                            p.data.shape(),
                            "server/local shape mismatch for {} (logical {})",
                            p.name,
                            plan.slots[s].logical
                        );
                    }
                    p.data.copy_from(&buf.fresh[i]);
                    if rel > 0 {
                        p.version += 1;
                    }
                }
            }
        }
    }

    /// Arm the per-step flush state: reset each bucket's contributing-node
    /// countdown and start the step's compute stopwatch (flush timestamps
    /// are measured against it).
    pub fn begin_step(&mut self, step: u64, clock_us: f64) {
        self.step = step;
        self.step_start_virt_us = clock_us;
        self.sw = Stopwatch::new();
        for (o, spec) in self.outstanding.iter_mut().zip(&self.ws.plan().buckets) {
            *o = spec.node_list.len();
        }
    }

    /// Real µs since [`GroupExchange::begin_step`] — the step's measured
    /// compute time (the same stopwatch the flush timestamps use, so a
    /// flush can never appear later than the compute it overlapped).
    pub fn step_elapsed_us(&self) -> f64 {
        self.sw.elapsed_us()
    }

    /// Sequential-mode exchange (no-op under overlap): aggregate every
    /// bucket, push each slot through the server's updater, receive fresh
    /// values, and charge one bulk transfer — the historical blocking
    /// recipe, preserved bit for bit for comparison and fallback.
    pub fn flush_sequential(
        &self,
        net: &NeuralNet,
        sg: &ServerGroup,
        step: u64,
        clock: &mut VirtualClock,
    ) {
        if self.overlap {
            return;
        }
        let plan = self.ws.plan();
        let store = self.ws.store();
        if let Some(w) = &self.wire {
            // Armed sequential: aggregate then run each bucket's flush
            // through the retry protocol inline, max-merging delivery (or
            // degradation) instants instead of the bulk transfer charge.
            let op = WireOp::Flush { step };
            for b in 0..plan.buckets.len() {
                self.ws.aggregate_bucket(net, b);
                let fin = workspace::deliver(plan, store, sg, w, b, op, self.base, clock.us);
                clock.merge_us(fin);
            }
            return;
        }
        let mut total = 0usize;
        for b in 0..plan.buckets.len() {
            self.ws.aggregate_bucket(net, b);
            workspace::apply_flush(plan, store, sg, b, step, self.base);
            store.bufs[b].0.lock().unwrap().finish_virt_us = clock.us;
            total += plan.buckets[b].flush_bytes;
        }
        clock.transfer(&self.link, total);
    }

    /// Wire bytes of one full-step gradient flush (all buckets) — what a
    /// backup worker's discarded duplicate flush charges to the ledger.
    pub fn step_flush_bytes(&self) -> usize {
        self.ws.plan().buckets.iter().map(|b| b.flush_bytes).sum()
    }

    /// Block until every bucket's flush for `step` has been applied,
    /// merging the finish times into the clock. Called before neighbour
    /// server-group syncs (averaging half-flushed replicas would diverge
    /// from the sequential semantics), before releasing the warm-up gate,
    /// and at job end. No-op in sequential mode.
    pub fn drain(&self, step: u64, clock: &mut VirtualClock) {
        if !self.overlap {
            return;
        }
        debug_assert!(step >= self.base, "drain before the exchange's start step");
        let rel = step - self.base;
        for (mx, cv) in &self.ws.store().bufs {
            let mut buf = mx.lock().unwrap();
            while buf.epoch < rel + 2 {
                assert!(
                    !self.driver_dead.load(Ordering::SeqCst),
                    "comm driver died before publishing a bucket epoch"
                );
                if self.wire.is_some() {
                    // See `consume_fresh`: bounded wait so a wedged driver
                    // under the retry plane fails loudly, never hangs.
                    let dur = std::time::Duration::from_secs(30);
                    let (guard, timed_out) = cv.wait_timeout(buf, dur).unwrap();
                    buf = guard;
                    assert!(
                        !(timed_out && buf.epoch < rel + 2),
                        "bucket epoch wait exceeded 30s under the retry plane"
                    );
                } else {
                    buf = cv.wait(buf).unwrap();
                }
            }
            clock.merge_us(buf.finish_virt_us);
        }
    }

    /// Hand a job to the comm driver. A dead driver (panicked) would
    /// otherwise strand the worker on a never-published epoch, so a failed
    /// send surfaces immediately.
    fn send(&self, job: CommJob) {
        self.tx
            .as_ref()
            .expect("overlap mode must have a comm channel")
            .send(job)
            .expect("comm driver died");
    }

    /// Retire the comm driver: dropping the channel sender ends its recv
    /// loop after any in-flight flushes, so all server effects land before
    /// this returns. Propagates a comm-driver panic.
    pub fn shutdown(&mut self) {
        self.tx = None;
        if let Some(handle) = self.comm.take() {
            handle.join().expect("comm driver panicked");
        }
    }

    /// Blob allocations the comm driver performed while processing probed
    /// steps (see `JobConf::alloc_probe_from`) — charged to the worker
    /// group's steady-state tally.
    pub fn comm_steady_allocs(&self) -> u64 {
        self.comm_allocs.load(Ordering::Relaxed)
    }
}

/// Every exit path retires the comm driver — a worker panic (a shape
/// assert, a poisoned layer) must not leak a thread parked on the channel.
/// Unlike [`GroupExchange::shutdown`], a driver panic is swallowed here:
/// panicking during unwind would abort the process.
impl Drop for GroupExchange {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(handle) = self.comm.take() {
            let _ = handle.join();
        }
    }
}

impl GradObserver for GroupExchange {
    /// The backward hook: count down the completed node's bucket; when the
    /// bucket's last contributing layer lands, aggregate its replica
    /// gradients (historical order) into the persistent sums, stamp the
    /// flush on the virtual link timeline, and hand the bucket to the comm
    /// driver — all while the backward pass continues below.
    fn grads_ready(&mut self, net: &NeuralNet, node: usize) {
        if !self.overlap {
            return;
        }
        let b = self.ws.plan().node_bucket[node];
        if b == usize::MAX || self.outstanding[b] == 0 {
            return;
        }
        self.outstanding[b] -= 1;
        if self.outstanding[b] > 0 {
            return;
        }
        self.ws.aggregate_bucket(net, b);
        let flush_us = self.step_start_virt_us + self.sw.elapsed_us() / self.k;
        if self.wire.is_none() {
            // Unarmed: historical inline timeline stamp. The armed driver
            // charges the shared timeline per attempt (faults included) and
            // stamps the delivery finish in `deliver`.
            let bytes = self.ws.plan().buckets[b].flush_bytes;
            let finish = self.timeline.flush(&self.link, flush_us, bytes);
            self.ws.store().bufs[b].0.lock().unwrap().finish_virt_us = finish;
        }
        self.send(CommJob::Flush { bucket: b, step: self.step, flush_us });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterTopology;
    use crate::comm::{ByteLedger, FaultPlan};
    use crate::data::{shard_index, DataSource, SyntheticDigits};
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::partition::logical_param_name;
    use crate::model::NetBuilder;
    use crate::train::{bp::Bp, TrainOneBatch};
    use crate::updater::UpdaterConf;
    use crate::utils::rng::Rng;
    use std::collections::HashMap;

    fn digit_mlp() -> NetBuilder {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![16, 64] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![16] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 5, act: Activation::Identity, init_std: 0.1 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
    }

    /// Deterministic lockstep driver over the REAL exchange machinery:
    /// worker groups execute their steps round-robin on this one thread,
    /// draining the comm channel after every group-step, so the cross-
    /// group order of server operations is fixed. That makes overlapped
    /// and sequential runs bitwise comparable even on topologies whose
    /// free-running threads race (shared-server downpour, syncing
    /// hogwild) — within a group-step the overlapped driver still runs
    /// for real: observer flushes mid-backward, comm thread applies them
    /// concurrently.
    fn lockstep_run(
        topo: &ClusterTopology,
        overlap: bool,
        iters: u64,
        codec: crate::comm::Codec,
        faults: FaultPlan,
    ) -> (Vec<Vec<(u32, u32)>>, Vec<HashMap<String, Blob>>) {
        let mut conf = JobConf::new("lockstep", digit_mlp());
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = topo.clone();
        conf.overlap_exchange = overlap;
        conf.bucket_coalesce_bytes = 0; // per-layer buckets
        conf.wire_codec = codec;
        conf.faults = faults;
        let ledger = Arc::new(ByteLedger::new());
        let servers: Arc<Vec<ServerGroup>> = Arc::new(
            (0..topo.nserver_groups)
                .map(|_| {
                    ServerGroup::new(
                        topo.nservers_per_group,
                        conf.updater.clone(),
                        ledger.clone(),
                    )
                })
                .collect(),
        );
        {
            let probe = conf.net.clone().build(&mut Rng::new(conf.seed));
            let mut seen = std::collections::HashSet::new();
            for p in probe.params() {
                let logical = logical_param_name(&p.name);
                if seen.insert(logical.clone()) {
                    for sg in servers.iter() {
                        sg.put(&logical, p.data.clone(), p.lr_mult, p.wd_mult);
                    }
                }
            }
        }
        let groups = topo.nworker_groups;
        let data = SyntheticDigits::new(64, 5, 77);
        let mut nets: Vec<NeuralNet> =
            (0..groups).map(|_| conf.net.clone().build(&mut Rng::new(conf.seed))).collect();
        let mut exs: Vec<GroupExchange> = (0..groups)
            .map(|g| {
                let link = *topo.param_link(&conf.cost);
                let wc = conf.faults.has_wire_faults().then(|| Arc::new(WireCounters::new()));
                let sg_idx = topo.server_group_of(g);
                GroupExchange::new(&nets[g], &conf, &servers, sg_idx, link, 1, 0, g, wc)
            })
            .collect();
        let mut algs: Vec<Bp> = (0..groups).map(|_| Bp::new()).collect();
        let mut clocks: Vec<crate::comm::VirtualClock> =
            (0..groups).map(|_| crate::comm::VirtualClock::new()).collect();
        for g in 0..groups {
            exs[g].prefetch(&servers[topo.server_group_of(g)], &mut clocks[g]);
        }
        let mut losses: Vec<Vec<(u32, u32)>> = vec![Vec::new(); groups];
        for step in 0..iters {
            for g in 0..groups {
                let sg_idx = topo.server_group_of(g);
                let sg = &servers[sg_idx];
                let inputs = data.batch(shard_index(step, g, groups), 16);
                exs[g].consume_fresh(&mut nets[g], step, &mut clocks[g]);
                nets[g].zero_grads();
                exs[g].begin_step(step, clocks[g].us);
                let stats =
                    algs[g].train_one_batch_observed(&mut nets[g], &inputs, &mut exs[g]);
                losses[g].push((stats.total_loss().to_bits(), stats.metric().to_bits()));
                exs[g].flush_sequential(&nets[g], sg, step, &mut clocks[g]);
                // Lockstep barrier: all of this group-step's server effects
                // land before the next group steps.
                exs[g].drain(step, &mut clocks[g]);
                // Hogwild neighbour sync, on the run_job schedule (after
                // the drain — the mid-flush sync contract).
                if topo.group_sync_interval > 0
                    && step > 0
                    && step % topo.group_sync_interval == 0
                    && topo.nserver_groups > 1
                {
                    let neighbour = (sg_idx + 1) % servers.len();
                    if neighbour != sg_idx {
                        sg.sync_with(&servers[neighbour]);
                    }
                }
            }
        }
        for ex in &mut exs {
            ex.shutdown();
        }
        let group_params: Vec<HashMap<String, Blob>> = servers
            .iter()
            .map(|sg| {
                sg.param_names()
                    .into_iter()
                    .map(|name| {
                        let (v, _) = sg.get(&name);
                        (name, v)
                    })
                    .collect()
            })
            .collect();
        (losses, group_params)
    }

    fn assert_bitwise_equal(
        seq: &(Vec<Vec<(u32, u32)>>, Vec<HashMap<String, Blob>>),
        ovl: &(Vec<Vec<(u32, u32)>>, Vec<HashMap<String, Blob>>),
    ) {
        assert_eq!(seq.0, ovl.0, "loss/metric trajectories diverged");
        assert_eq!(seq.1.len(), ovl.1.len());
        for (sp, op) in seq.1.iter().zip(&ovl.1) {
            assert_eq!(sp.len(), op.len());
            for (name, sv) in sp {
                let ov = op.get(name).unwrap_or_else(|| panic!("missing param {name}"));
                assert_eq!(sv.shape(), ov.shape(), "{name}");
                for (x, y) in sv.data().iter().zip(ov.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged");
                }
            }
        }
    }

    /// Downpour(3,1,2): three worker groups hammering one shared, sharded
    /// server group. Under the deterministic lockstep schedule the
    /// overlapped exchange must reproduce the sequential exchange bit for
    /// bit — same per-step losses, same final server replicas.
    #[test]
    fn downpour_3_1_2_overlap_matches_sequential_bitwise() {
        let topo = ClusterTopology::downpour(3, 1, 2);
        let seq = lockstep_run(&topo, false, 12, crate::comm::Codec::Raw, FaultPlan::none());
        let ovl = lockstep_run(&topo, true, 12, crate::comm::Codec::Raw, FaultPlan::none());
        assert_bitwise_equal(&seq, &ovl);
    }

    /// The seq-vs-overlap bit-identity contract holds under quantizing
    /// codecs too: both modes route through the same `apply_flush`
    /// (error-feedback encode included) and residuals live per-slot, so
    /// cross-bucket completion order cannot perturb them.
    #[test]
    fn downpour_int8_overlap_matches_sequential_bitwise() {
        let topo = ClusterTopology::downpour(3, 1, 2);
        let seq = lockstep_run(&topo, false, 12, crate::comm::Codec::Int8, FaultPlan::none());
        let ovl = lockstep_run(&topo, true, 12, crate::comm::Codec::Int8, FaultPlan::none());
        assert_bitwise_equal(&seq, &ovl);
    }

    /// Hogwild(2,1,3) with syncs firing every 3 steps — in overlap mode
    /// the sync request lands while that step's flushes are still in the
    /// comm channel, so the drain-before-sync contract is what keeps the
    /// averaged replicas bit-identical to the sequential exchange.
    #[test]
    fn hogwild_sync_mid_flush_overlap_matches_sequential_bitwise() {
        let topo = ClusterTopology::hogwild(2, 1, 3);
        let seq = lockstep_run(&topo, false, 10, crate::comm::Codec::Raw, FaultPlan::none());
        let ovl = lockstep_run(&topo, true, 10, crate::comm::Codec::Raw, FaultPlan::none());
        assert_bitwise_equal(&seq, &ovl);
    }

    /// The lockstep harness itself is deterministic in overlap mode (two
    /// identical runs agree) — a guard on the harness, so the equivalence
    /// asserts above can't pass vacuously on noisy trajectories.
    #[test]
    fn lockstep_overlap_is_deterministic() {
        let topo = ClusterTopology::downpour(3, 1, 2);
        let a = lockstep_run(&topo, true, 6, crate::comm::Codec::Raw, FaultPlan::none());
        let b = lockstep_run(&topo, true, 6, crate::comm::Codec::Raw, FaultPlan::none());
        assert_bitwise_equal(&a, &b);
    }

    /// Arming the retry plane with a rule that never fires (it waits for
    /// attempt 1000 of steps the run never reaches) must leave training
    /// bit-identical to the unarmed exchange: CRC framing, sequence
    /// numbering, and the per-slot sized server calls are value-transparent.
    #[test]
    fn armed_lossless_matches_unarmed_bitwise() {
        let topo = ClusterTopology::downpour(2, 1, 2);
        let never = FaultPlan::none().drop_nth(0, 1_000, 1_001, 0);
        for codec in [crate::comm::Codec::Raw, crate::comm::Codec::Int8] {
            for overlap in [false, true] {
                let clean = lockstep_run(&topo, overlap, 8, codec, FaultPlan::none());
                let armed = lockstep_run(&topo, overlap, 8, codec, never.clone());
                assert_bitwise_equal(&clean, &armed);
            }
        }
    }

    /// The headline robustness pin: a lossy run whose buckets all
    /// eventually deliver (every first copy dropped, every retransmit
    /// clean) ends bit-identical to the lossless run — retries change
    /// virtual time and wasted bytes, never values.
    #[test]
    fn armed_lossy_eventually_delivered_matches_lossless_bitwise() {
        let topo = ClusterTopology::downpour(2, 1, 2);
        let mut lossy = FaultPlan::none();
        for g in 0..topo.nworker_groups {
            lossy = lossy.drop_nth(g, 0, 100, 0);
        }
        for codec in [crate::comm::Codec::Raw, crate::comm::Codec::Int8] {
            for overlap in [false, true] {
                let clean = lockstep_run(&topo, overlap, 8, codec, FaultPlan::none());
                let faulted = lockstep_run(&topo, overlap, 8, codec, lossy.clone());
                assert_bitwise_equal(&clean, &faulted);
            }
        }
    }
}
