//! Persistent per-worker-group parameter workspace (the ROADMAP's
//! "partition-aware workspaces" item): aggregation sums, fresh-value slots,
//! and per-logical-param routing resolved once at job start from the
//! replica's parameter list, so the steady-state worker↔server exchange —
//! aggregate dim-0 shard gradients, push, copy fresh values back into every
//! replica — performs zero Blob allocations.
//!
//! The group stub of the paper (§5.1: "aggregates local messages and
//! forwards them") previously re-materialized its aggregation state every
//! iteration: a fresh `HashMap`, one `grad.clone()` per logical param, and
//! 3–4 more Blob clones per value round-tripped through the server. This is
//! the planned-executor pattern (PR 1) applied across the distributed
//! boundary instead.

use crate::model::partition::logical_slot_map;
use crate::model::NeuralNet;
use crate::tensor::Blob;

/// One logical parameter's persistent slots.
pub struct ParamSlot {
    /// Logical (server-side) parameter name, e.g. `"h1/weight"`.
    pub logical: String,
    /// Replica gradient sum; after [`ParamWorkspace::aggregate_grads`] it
    /// holds the mean gradient shipped to the server.
    pub sum: Blob,
    /// Fresh value the server writes back (via `update_into`/`get_into`).
    pub fresh: Blob,
    /// Number of net params (dim-0 replicas) contributing gradients.
    /// (The lr/wd multipliers live server-side, registered at `put` time.)
    pub replicas: usize,
}

/// Persistent aggregation + routing state for one worker group's replica
/// net. Built once per group thread; every per-step method is Blob-
/// allocation-free once the slots are sized.
pub struct ParamWorkspace {
    slots: Vec<ParamSlot>,
    /// net param index (positional, `NeuralNet::params` order) → slot.
    param_slot: Vec<usize>,
    /// Per-step "slot already written" flags (reset, never reallocated).
    seen: Vec<bool>,
}

impl ParamWorkspace {
    /// Resolve the logical routing for `net`'s parameter list and size the
    /// aggregation/fresh buffers. The net's param order must stay stable
    /// for the workspace's lifetime (it is: the layer graph is fixed after
    /// `build`).
    pub fn new(net: &NeuralNet) -> ParamWorkspace {
        let params = net.params();
        let names: Vec<&str> = params.iter().map(|p| p.name.as_str()).collect();
        let (logicals, param_slot) = logical_slot_map(&names);
        let mut slots: Vec<ParamSlot> = logicals
            .into_iter()
            .map(|logical| ParamSlot {
                logical,
                sum: Blob::default(),
                fresh: Blob::default(),
                replicas: 0,
            })
            .collect();
        for (j, p) in params.iter().enumerate() {
            let s = &mut slots[param_slot[j]];
            if s.replicas == 0 {
                s.sum.resize(p.data.shape());
                s.fresh.resize(p.data.shape());
            } else {
                assert_eq!(
                    s.sum.shape(),
                    p.data.shape(),
                    "replica shape mismatch for {} (logical {})",
                    p.name,
                    s.logical
                );
            }
            s.replicas += 1;
        }
        let seen = vec![false; slots.len()];
        ParamWorkspace { slots, param_slot, seen }
    }

    /// Sum `net`'s per-replica gradients into the slots and average: after
    /// this every slot's `sum` holds the mean gradient over its replicas —
    /// the value the group stub forwards to the server. Zero Blob
    /// allocations; arithmetic order matches the historical HashMap path
    /// (first replica copied, later replicas `add_assign`ed in param order,
    /// then one `scale(1/count)`), so trajectories are bit-identical.
    pub fn aggregate_grads(&mut self, net: &NeuralNet) {
        self.seen.iter_mut().for_each(|s| *s = false);
        for (j, p) in net.params().iter().enumerate() {
            let si = self.param_slot[j];
            let slot = &mut self.slots[si];
            if self.seen[si] {
                slot.sum.add_assign(&p.grad);
            } else {
                slot.sum.copy_from(&p.grad);
                self.seen[si] = true;
            }
        }
        for slot in &mut self.slots {
            slot.sum.scale(1.0 / slot.replicas as f32);
        }
    }

    /// Copy each slot's fresh server value back into every local replica,
    /// bumping replica versions. Zero Blob allocations.
    pub fn write_back(&self, net: &mut NeuralNet) {
        for (j, p) in net.params_mut().into_iter().enumerate() {
            p.data.copy_from(&self.slots[self.param_slot[j]].fresh);
            p.version += 1;
        }
    }

    /// Copy each slot's fresh value into every replica WITHOUT bumping
    /// versions (the initial fetch: replicas adopt the server state).
    /// Asserts server/local shape agreement, like the historical fetch.
    pub fn distribute_fresh(&self, net: &mut NeuralNet) {
        for (j, p) in net.params_mut().into_iter().enumerate() {
            let slot = &self.slots[self.param_slot[j]];
            assert_eq!(
                slot.fresh.shape(),
                p.data.shape(),
                "server/local shape mismatch for {} (logical {})",
                p.name,
                slot.logical
            );
            p.data.copy_from(&slot.fresh);
        }
    }

    pub fn slots(&self) -> &[ParamSlot] {
        &self.slots
    }

    pub fn slots_mut(&mut self) -> impl Iterator<Item = &mut ParamSlot> {
        self.slots.iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::partition::{logical_param_name, partition_net};
    use crate::model::NetBuilder;
    use crate::utils::rng::Rng;
    use std::collections::HashMap;

    fn partitioned_mlp(workers: usize) -> NeuralNet {
        let mut b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![8, 6] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![8] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 10, act: Activation::Relu, init_std: 0.2 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.2 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
        for c in b.confs_mut().iter_mut() {
            if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
                c.partition_dim = Some(0);
            }
        }
        let (bp, _) = partition_net(&b, workers);
        bp.build(&mut Rng::new(11))
    }

    /// The workspace aggregation must reproduce the historical HashMap
    /// recipe (clone-first, add_assign-later, scale by 1/count) bit for
    /// bit, including replica counting on a dim-0 partitioned net.
    #[test]
    fn aggregation_matches_hashmap_reference_bitwise() {
        let mut net = partitioned_mlp(2);
        // Give every param a distinct, deterministic gradient.
        let mut rng = Rng::new(5);
        for p in net.params_mut() {
            let n = p.grad.len();
            p.grad = Blob::from_vec(p.data.shape(), rng.uniform_vec(n, -1.0, 1.0));
        }
        // Historical reference.
        let mut agg: HashMap<String, (Blob, usize)> = HashMap::new();
        for p in net.params() {
            let logical = logical_param_name(&p.name);
            match agg.get_mut(&logical) {
                Some((sum, count)) => {
                    sum.add_assign(&p.grad);
                    *count += 1;
                }
                None => {
                    agg.insert(logical, (p.grad.clone(), 1));
                }
            }
        }
        for (_, (sum, count)) in agg.iter_mut() {
            sum.scale(1.0 / *count as f32);
        }

        let mut ws = ParamWorkspace::new(&net);
        ws.aggregate_grads(&net);
        assert_eq!(ws.slots().len(), agg.len());
        for slot in ws.slots() {
            let (want, count) = agg.get(&slot.logical).expect("slot has a reference entry");
            assert_eq!(slot.replicas, *count, "{}", slot.logical);
            assert_eq!(slot.sum.shape(), want.shape());
            for (x, y) in slot.sum.data().iter().zip(want.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} diverged", slot.logical);
            }
        }
    }

    /// Steady-state aggregate + write-back cycles allocate zero Blobs.
    #[test]
    fn steady_state_cycle_is_allocation_free() {
        let mut net = partitioned_mlp(2);
        let mut ws = ParamWorkspace::new(&net);
        let mut cycle = |ws: &mut ParamWorkspace, net: &mut NeuralNet| {
            ws.aggregate_grads(net);
            for slot in ws.slots_mut() {
                slot.fresh.copy_from(&slot.sum); // stand-in for the server reply
            }
            ws.write_back(net);
        };
        cycle(&mut ws, &mut net); // warm (nothing to size — already sized at new)
        let before = Blob::alloc_count();
        for _ in 0..5 {
            cycle(&mut ws, &mut net);
        }
        assert_eq!(Blob::alloc_count(), before, "workspace cycle must not allocate");
    }

    /// Write-back copies one slot value into every replica and bumps each
    /// replica's version; the unpartitioned case is one replica per slot.
    #[test]
    fn write_back_updates_all_replicas() {
        let mut net = partitioned_mlp(3);
        let mut ws = ParamWorkspace::new(&net);
        for (i, slot) in ws.slots.iter_mut().enumerate() {
            slot.fresh.fill(i as f32 + 1.0);
        }
        let versions_before: Vec<u64> = net.params().iter().map(|p| p.version).collect();
        ws.write_back(&mut net);
        for (j, p) in net.params().iter().enumerate() {
            let slot = &ws.slots()[ws.param_slot[j]];
            assert_eq!(p.data.data(), slot.fresh.data(), "{}", p.name);
            assert_eq!(p.version, versions_before[j] + 1);
        }
    }
}
