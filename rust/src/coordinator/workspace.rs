//! Persistent per-worker-group parameter workspace: aggregation sums,
//! fresh-value slots, logical routing, AND the fixed-order flush-bucket
//! layout — all resolved once at job start from the replica's parameter
//! list, so the steady-state worker↔server exchange (sequential or
//! overlapped) performs zero Blob allocations.
//!
//! PR 4 made the exchange zero-clone but kept it strictly sequential:
//! aggregate everything, push everything, fetch everything, blocking. This
//! revision splits the state into *buckets* (default: one per owning
//! layer, coalescing tiny layers up to a byte threshold — see
//! [`crate::model::partition::bucket_slots`]) whose buffers live behind
//! per-bucket locks with ready *epochs*, so a comm driver can drain
//! completed buckets while the backward pass is still producing the rest
//! (paper §5: transfer each layer's gradients as soon as its
//! `ComputeGradient` finishes). Within a bucket the aggregation order
//! (first replica copied, later replicas added in ascending param order,
//! one scale) and the per-slot updater application are exactly the
//! historical recipe, so sequential and overlapped exchanges are
//! bit-identical.

use crate::comm::codec::{self, Codec};
use crate::comm::faults::{FaultPlan, RetryConf, WireEvents, WireFault};
use crate::comm::{LinkModel, LinkTimeline, Msg};
use crate::model::partition::{bucket_slots, logical_slot_map};
use crate::model::NeuralNet;
use crate::runtime::sync::{
    OrderedCondvar, OrderedMutex, RANK_LINK_TIMELINE, RANK_WORKSPACE_BUCKET,
};
use crate::server::ServerGroup;
use crate::tensor::Blob;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One logical parameter's routing record.
pub struct SlotInfo {
    /// Logical (server-side) parameter name, e.g. `"h1/weight"`.
    pub logical: String,
    /// Number of net params (dim-0 replicas) contributing gradients.
    pub replicas: usize,
    /// Global param indices (`NeuralNet::params` order) of those replicas,
    /// ascending — the fixed aggregation order.
    pub params: Vec<usize>,
    /// Payload bytes of one value (all replicas share the shape).
    pub byte_size: usize,
}

/// One flush bucket's static layout.
pub struct BucketSpec {
    /// Slot indices covered, ascending (a contiguous range).
    pub slots: Vec<usize>,
    /// Update+response wire bytes of one steady-state flush under the
    /// plan's codec ([`Msg::exchange_wire_size_coded`] summed over the
    /// slots; `Codec::Raw` reproduces the historical charge exactly).
    pub flush_bytes: usize,
    /// Initial-fetch wire bytes (encoded value × replicas; the historical
    /// per-replica fetch charge under `Codec::Raw`).
    pub fetch_bytes: usize,
    /// Param-bearing nodes contributing gradients, ascending — their
    /// count is the per-step completion target for the backward hook, and
    /// walking them in order reproduces the global param order without
    /// materializing the whole net's param list.
    pub node_list: Vec<usize>,
}

/// One bucket's shared buffers, guarded by its mutex in
/// [`BucketStore::bufs`]. The worker writes `sums` (aggregation) and reads
/// `fresh` (write-back); the comm driver reads `sums` and writes `fresh`
/// (`update_into` / `get_into`); `epoch` orders the hand-offs.
pub struct BucketBuf {
    pub sums: Vec<Blob>,
    pub fresh: Vec<Blob>,
    /// Per-slot error-feedback residuals (quantization error carried into
    /// the next flush). Empty under `Codec::Raw`.
    pub residual: Vec<Blob>,
    /// Per-slot decoded-gradient scratch — the dequantized payload the
    /// server's updater consumes. Empty under `Codec::Raw`.
    pub dec: Vec<Blob>,
    /// Encoded-chunk scratch, reserved at construction to the bucket's
    /// largest slot so steady-state encodes never grow it. Empty under
    /// `Codec::Raw`.
    pub enc: Vec<u8>,
    /// CRC-framed-chunk scratch for the retry protocol, reserved at
    /// construction to the bucket's largest slot (frame header + encoded
    /// chunk). Empty on unframed (retry-free) plans.
    pub frame: Vec<u8>,
    /// Last sequence number this bucket accepted (`u32::MAX` = none yet):
    /// the receiver-side dedup that discards duplicate and reordered
    /// frames. Only the framed protocol advances it.
    pub last_seq: u32,
    /// Completed exchanges, counted relative to the exchange's start step
    /// `b` (0 for a fresh job; the resume step after a worker-group
    /// restart): the initial prefetch publishes epoch 1, the flush of step
    /// `s` publishes `s - b + 2`. A consumer of step `s` waits for
    /// `epoch >= s - b + 1`.
    pub epoch: u64,
    /// Absolute virtual time (µs) at which the exchange that produced
    /// `epoch` finished on the modeled link (what the consumer's clock
    /// max-merges with).
    pub finish_virt_us: f64,
}

/// The immutable routing + bucket layout, shared between the worker thread
/// and its comm driver.
pub struct ExchangePlan {
    pub slots: Vec<SlotInfo>,
    /// net param index (positional, `NeuralNet::params` order) → slot.
    pub param_slot: Vec<usize>,
    /// node index → bucket (`usize::MAX` for parameter-less nodes).
    pub node_bucket: Vec<usize>,
    /// node index → per-param aggregation action, in the node's own param
    /// order: (position of the param's slot within its bucket, whether
    /// this param is the slot's FIRST contributor — copy vs add). Lets
    /// aggregation walk only a bucket's contributing nodes instead of
    /// collecting the whole net's param list per flush.
    pub node_actions: Vec<Vec<(usize, bool)>>,
    pub buckets: Vec<BucketSpec>,
    /// Wire codec every flush/fetch of this plan encodes with (and the
    /// codec its `flush_bytes`/`fetch_bytes` were computed under).
    pub codec: Codec,
    /// Whether the plan's wire accounting includes the retry protocol's
    /// integrity frame ([`Msg::exchange_wire_size_framed`] per slot) and
    /// its buckets carry frame scratch. Armed jobs (wire faults present)
    /// frame every codec, `Raw` included; unframed plans are byte-for-byte
    /// the historical accounting.
    pub framed: bool,
}

/// The mutable bucket buffers, shared between the worker thread and its
/// comm driver. One `(Mutex, Condvar)` pair per bucket: the next step's
/// forward blocks per-bucket on the condvar, not on the whole exchange.
/// The bucket lock ranks *below* the server route/shard locks —
/// [`apply_flush`]/[`fill_fresh`] hold a bucket while calling into the
/// server — and no two buckets are ever held together.
pub struct BucketStore {
    pub bufs: Vec<(OrderedMutex<BucketBuf>, OrderedCondvar)>,
}

/// THE prefetch recipe for one bucket — fill its fresh slots from the
/// server and publish epoch 1. The single definition shared by the comm
/// driver (overlap mode) and the inline sequential fetch, so the two modes
/// cannot drift apart. Under a quantizing codec the value crosses the
/// modeled wire encoded: the worker adopts what a receiver would decode,
/// and the ledger is charged the compressed response size.
pub fn fill_fresh(plan: &ExchangePlan, store: &BucketStore, sg: &ServerGroup, b: usize) {
    let (mx, cv) = &store.bufs[b];
    let mut buf = mx.lock().unwrap();
    let BucketBuf { fresh, enc, epoch, .. } = &mut *buf;
    for (i, &s) in plan.buckets[b].slots.iter().enumerate() {
        let info = &plan.slots[s];
        match plan.codec {
            Codec::Raw => {
                sg.get_into(&info.logical, &mut fresh[i]);
            }
            coded => {
                let down = Msg::HEADER + coded.wire_bytes(info.byte_size);
                sg.get_into_sized(&info.logical, &mut fresh[i], down);
                coded.encode_into(fresh[i].data(), enc);
                coded
                    .decode_into(enc, fresh[i].data_mut())
                    .expect("self-encoded value chunk must decode");
            }
        }
    }
    *epoch = 1;
    cv.notify_all();
}

/// THE flush recipe for one bucket — push its aggregated sums through the
/// server's updater (slot order, the historical per-slot application),
/// receive fresh values, and publish epoch `step - base + 2` (`base` is
/// the exchange's start step; the server sees the absolute `step`). The
/// single definition shared by the comm driver and the sequential
/// exchange: the bit-identity contract between the two modes reduces to
/// "same aggregation + same `apply_flush`".
///
/// Under a quantizing codec each slot runs the error-feedback encode
/// ([`codec::feedback_encode`]): the residual carried from the previous
/// flush is added to the aggregated gradient, the compensated gradient is
/// encoded, the server's updater consumes the *decoded* payload, and the
/// fresh quantization error is stored back for the next flush. The fresh
/// value returns as an encoded chunk too; ledger charges use the
/// compressed chunk sizes. `Codec::Raw` is the historical body, untouched.
pub fn apply_flush(
    plan: &ExchangePlan,
    store: &BucketStore,
    sg: &ServerGroup,
    b: usize,
    step: u64,
    base: u64,
) {
    let (mx, cv) = &store.bufs[b];
    let mut buf = mx.lock().unwrap();
    let BucketBuf { sums, fresh, residual, dec, enc, epoch, .. } = &mut *buf;
    for (i, &s) in plan.buckets[b].slots.iter().enumerate() {
        let info = &plan.slots[s];
        match plan.codec {
            Codec::Raw => {
                sg.update_into(&info.logical, &sums[i], step, &mut fresh[i]);
            }
            coded => {
                codec::feedback_encode(
                    coded,
                    sums[i].data_mut(),
                    residual[i].data_mut(),
                    enc,
                    dec[i].data_mut(),
                );
                let chunk = coded.wire_bytes(info.byte_size);
                let up = Msg::HEADER + info.logical.len() + chunk;
                let down = Msg::HEADER + chunk;
                sg.update_into_sized(&info.logical, &dec[i], step, &mut fresh[i], up, down);
                coded.encode_into(fresh[i].data(), enc);
                coded
                    .decode_into(enc, fresh[i].data_mut())
                    .expect("self-encoded value chunk must decode");
            }
        }
    }
    *epoch = step - base + 2;
    cv.notify_all();
}

/// Atomic tallies of one worker group's wire-protocol events, owned by the
/// group thread across kill/restart stints (each stint builds a fresh
/// [`WirePlane`], but the counters accumulate for the whole job) and
/// snapshotted into [`WireEvents`] at job end.
pub struct WireCounters {
    pub drops: AtomicU64,
    pub corruptions_detected: AtomicU64,
    pub duplicates_discarded: AtomicU64,
    pub reorders_discarded: AtomicU64,
    pub retransmits: AtomicU64,
    pub staleness_adoptions: AtomicU64,
    pub wasted_bytes: AtomicU64,
    degraded_steps: AtomicU64,
    /// Dedup sentinel: the step most recently marked degraded, so several
    /// buckets degrading within one step count the step once.
    last_degraded_step: AtomicU64,
}

impl WireCounters {
    pub fn new() -> WireCounters {
        WireCounters {
            drops: AtomicU64::new(0),
            corruptions_detected: AtomicU64::new(0),
            duplicates_discarded: AtomicU64::new(0),
            reorders_discarded: AtomicU64::new(0),
            retransmits: AtomicU64::new(0),
            staleness_adoptions: AtomicU64::new(0),
            wasted_bytes: AtomicU64::new(0),
            degraded_steps: AtomicU64::new(0),
            last_degraded_step: AtomicU64::new(u64::MAX),
        }
    }

    /// Record that `step` degraded (a bucket exhausted its attempts),
    /// counting each step at most once however many buckets degrade in it.
    pub fn mark_degraded(&self, step: u64) {
        self.staleness_adoptions.fetch_add(1, Ordering::Relaxed);
        if self.last_degraded_step.swap(step, Ordering::Relaxed) != step {
            self.degraded_steps.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One group's tally as a [`WireEvents`] (its `degraded_steps` holds
    /// exactly this group's entry; `run_job` appends them in group order).
    pub fn snapshot(&self) -> WireEvents { // lint: alloc-ok(job-end snapshot, once per group)
        WireEvents {
            drops: self.drops.load(Ordering::Relaxed),
            corruptions_detected: self.corruptions_detected.load(Ordering::Relaxed),
            duplicates_discarded: self.duplicates_discarded.load(Ordering::Relaxed),
            reorders_discarded: self.reorders_discarded.load(Ordering::Relaxed),
            retransmits: self.retransmits.load(Ordering::Relaxed),
            staleness_adoptions: self.staleness_adoptions.load(Ordering::Relaxed),
            wasted_bytes: self.wasted_bytes.load(Ordering::Relaxed),
            degraded_steps: vec![self.degraded_steps.load(Ordering::Relaxed)],
        }
    }
}

impl Default for WireCounters {
    fn default() -> WireCounters {
        WireCounters::new()
    }
}

/// Shared state of one worker group's unreliable-wire protocol, built per
/// stint by the exchange when the fault plan carries wire rules: the link
/// model, the deterministic fault stream, the retry knobs, the group's
/// serialized wire timeline (shared worker ↔ comm driver, hence behind a
/// rank-15 lock — above the bucket locks, below the server locks), and the
/// job-lifetime event counters.
pub struct WirePlane {
    /// Worker-group index the plan's wire rules (and fault coins) key on.
    pub group: usize,
    pub link: LinkModel,
    pub faults: FaultPlan,
    pub retry: RetryConf,
    timeline: OrderedMutex<LinkTimeline>,
    pub counters: Arc<WireCounters>,
}

impl WirePlane {
    pub fn new(
        group: usize,
        link: LinkModel,
        faults: FaultPlan,
        retry: RetryConf,
        counters: Arc<WireCounters>,
    ) -> WirePlane {
        retry.validate();
        WirePlane {
            group,
            link,
            faults,
            retry,
            timeline: OrderedMutex::new(RANK_LINK_TIMELINE, "wire.timeline", LinkTimeline::new()),
            counters,
        }
    }
}

/// Which framed bucket transfer [`deliver`] is running.
#[derive(Debug, Clone, Copy)]
pub enum WireOp {
    /// Initial fetch of the bucket's fresh values (sequence number 0).
    Prefetch,
    /// Steady-state flush of `step` (sequence number `step - base + 1`).
    Flush { step: u64 },
}

/// THE armed (retry-protocol) delivery recipe for one bucket — the framed
/// counterpart of [`fill_fresh`]/[`apply_flush`], shared by the comm driver
/// and the sequential exchange. Starting at virtual instant `flush_us`, it
/// walks the retry attempts against the fault plan: every lost, corrupt,
/// duplicate, or reordered copy is charged to the shared wire timeline AND
/// the byte ledger (wasted bytes are honest bytes), a failed attempt
/// retransmits at its backoff deadline, and the delivering attempt runs the
/// exact value recipe of the unframed plane — so a lossy schedule whose
/// buckets all eventually deliver is bit-identical to the lossless run.
/// A bucket that exhausts `max_attempts` degrades: its epoch publishes with
/// the fresh slots untouched (the consumer adopts the last-known values —
/// bounded staleness; before any delivery that is the replica's initial
/// params) and the server never sees its gradient. Every path publishes the
/// epoch, so no consumer can hang on a dead link. Returns the bucket's
/// virtual finish time (delivery instant, or the final deadline when
/// degraded).
pub fn deliver(
    plan: &ExchangePlan,
    store: &BucketStore,
    sg: &ServerGroup,
    wire: &WirePlane,
    b: usize,
    op: WireOp,
    base: u64,
    flush_us: f64,
) -> f64 {
    debug_assert!(plan.framed, "the retry protocol needs a framed plan");
    let (mx, cv) = &store.bufs[b];
    let mut buf = mx.lock().unwrap();
    let BucketBuf { sums, fresh, residual, dec, enc, frame, last_seq, epoch, finish_virt_us } =
        &mut *buf;
    let (step, seq, bytes, publish) = match op {
        WireOp::Prefetch => (base, 0u32, plan.buckets[b].fetch_bytes, 1),
        WireOp::Flush { step } => {
            (step, (step - base + 1) as u32, plan.buckets[b].flush_bytes, step - base + 2)
        }
    };
    let c = &*wire.counters;
    let mut send = flush_us;
    let mut delivered = None;
    for attempt in 0..wire.retry.max_attempts {
        match wire.faults.wire_fault(wire.group, step, seq, attempt) {
            Some(fault @ (WireFault::Drop | WireFault::Corrupt)) => {
                // A wasted copy: charged to the timeline and the ledger,
                // never applied. The sender only learns at the deadline.
                wire.timeline.lock().unwrap().deliver(&wire.link, send, bytes, Some(fault));
                sg.ledger.add_param(bytes);
                c.wasted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                if fault == WireFault::Corrupt {
                    // Detection is real, not assumed: frame the bucket's
                    // first slot, flip the scheduled bit, and require the
                    // receiver checks to reject the frame — CRC32 for a
                    // payload/CRC flip, the sequence dedup for a flip that
                    // lands in the seq field itself.
                    let payload: &[f32] = match op {
                        WireOp::Prefetch => fresh[0].data(),
                        WireOp::Flush { .. } => sums[0].data(),
                    };
                    codec::frame_chunk(plan.codec, seq, payload, frame);
                    let bits = (frame.len() * 8) as u64;
                    let bit = wire.faults.corrupt_bit(wire.group, step, seq, attempt, bits);
                    frame[(bit / 8) as usize] ^= 1 << (bit % 8);
                    let rejected = match codec::frame_verify(frame) {
                        Err(_) => true,
                        Ok((got, _)) => got != seq,
                    };
                    assert!(rejected, "a flipped frame bit must never be accepted");
                    c.corruptions_detected.fetch_add(1, Ordering::Relaxed);
                } else {
                    c.drops.fetch_add(1, Ordering::Relaxed);
                }
                send += wire.retry.timeout_after(attempt);
                if attempt + 1 < wire.retry.max_attempts {
                    c.retransmits.fetch_add(1, Ordering::Relaxed);
                }
            }
            fault => {
                // This attempt delivers. A duplicate charges both copies
                // back to back inside the timeline (`Delivery` model); a
                // reorder charges the overtaking stale frame first, then
                // the in-order one — each discarded copy is counted and
                // its bytes burned on the ledger.
                let finish = {
                    let mut tl = wire.timeline.lock().unwrap();
                    if fault == Some(WireFault::Reorder) {
                        tl.deliver(&wire.link, send, bytes, fault);
                        tl.deliver(&wire.link, send, bytes, None).1
                    } else {
                        tl.deliver(&wire.link, send, bytes, fault).1
                    }
                };
                match fault {
                    Some(WireFault::Duplicate) => {
                        c.duplicates_discarded.fetch_add(1, Ordering::Relaxed);
                        c.wasted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                        sg.ledger.add_param(bytes);
                    }
                    Some(WireFault::Reorder) => {
                        c.reorders_discarded.fetch_add(1, Ordering::Relaxed);
                        c.wasted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                        sg.ledger.add_param(bytes);
                    }
                    _ => {}
                }
                delivered = Some(finish);
                break;
            }
        }
    }
    let finish = match delivered {
        Some(finish) => {
            // Receiver-side dedup: the accepted frame's sequence number
            // must advance the bucket's last one (the driver is FIFO, so
            // an in-order frame always does).
            assert!(
                *last_seq == u32::MAX || seq > *last_seq,
                "bucket {b} accepted a stale sequence number {seq}"
            );
            *last_seq = seq;
            for (i, &s) in plan.buckets[b].slots.iter().enumerate() {
                let info = &plan.slots[s];
                let elems = info.byte_size / 4;
                let down = Msg::HEADER + plan.codec.framed_len(elems);
                match op {
                    WireOp::Prefetch => {
                        sg.get_into_sized(&info.logical, &mut fresh[i], down);
                    }
                    WireOp::Flush { step } => {
                        let up = Msg::HEADER + info.logical.len() + plan.codec.framed_len(elems);
                        match plan.codec {
                            Codec::Raw => {
                                // Raw decode is the identity: verify the
                                // gradient frame, then hand the sums to the
                                // server bit-exact.
                                codec::frame_chunk(Codec::Raw, seq, sums[i].data(), frame);
                                codec::frame_verify(frame)
                                    .expect("clean raw gradient frame must verify");
                                sg.update_into_sized(
                                    &info.logical,
                                    &sums[i],
                                    step,
                                    &mut fresh[i],
                                    up,
                                    down,
                                );
                            }
                            coded => {
                                // The unframed error-feedback recipe, with
                                // the compensated chunk framed + verified
                                // (re-encoding `sums` reproduces `enc`'s
                                // bytes — encoding is deterministic).
                                codec::feedback_encode(
                                    coded,
                                    sums[i].data_mut(),
                                    residual[i].data_mut(),
                                    enc,
                                    dec[i].data_mut(),
                                );
                                codec::frame_chunk(coded, seq, sums[i].data(), frame);
                                codec::frame_verify(frame)
                                    .expect("clean gradient frame must verify");
                                sg.update_into_sized(
                                    &info.logical,
                                    &dec[i],
                                    step,
                                    &mut fresh[i],
                                    up,
                                    down,
                                );
                            }
                        }
                    }
                }
                // The fresh value comes back framed: verify, and (under a
                // quantizing codec) adopt what the frame's chunk decodes
                // to — the unframed plane's encode/decode roundtrip.
                codec::frame_chunk(plan.codec, seq, fresh[i].data(), frame);
                match plan.codec {
                    Codec::Raw => {
                        codec::frame_verify(frame).expect("clean raw value frame must verify");
                    }
                    coded => {
                        let (_, chunk) =
                            codec::frame_verify(frame).expect("clean value frame must verify");
                        coded
                            .decode_into(chunk, fresh[i].data_mut())
                            .expect("self-encoded value chunk must decode");
                    }
                }
            }
            finish
        }
        None => {
            // Exhausted: bounded staleness. Fresh slots keep their last
            // delivered values (initial params before any delivery), the
            // server never sees this bucket's gradient, and the bucket
            // finishes at its final deadline.
            c.mark_degraded(step);
            send
        }
    };
    *epoch = publish;
    *finish_virt_us = finish;
    cv.notify_all();
    finish
}

/// Persistent parameter-plane state for one worker group's replica net.
/// Built once per group thread; every per-step method is Blob-allocation-
/// free once the slots are sized.
pub struct ParamWorkspace {
    plan: Arc<ExchangePlan>,
    store: Arc<BucketStore>,
}

impl ParamWorkspace {
    /// Resolve the logical routing and bucket layout for `net`'s parameter
    /// list and size the aggregation/fresh buffers. The net's param order
    /// must stay stable for the workspace's lifetime (it is: the layer
    /// graph is fixed after `build`). `coalesce_bytes` is the bucket
    /// coalescing threshold (see [`bucket_slots`]); `wire_codec` selects
    /// the flush-bucket encoding — residual slots and encode/decode
    /// scratch are sized here, so compression adds zero steady-state Blob
    /// allocations.
    pub fn new(net: &NeuralNet, coalesce_bytes: usize, wire_codec: Codec) -> ParamWorkspace {
        ParamWorkspace::new_framed(net, coalesce_bytes, wire_codec, false)
    }

    /// [`ParamWorkspace::new`] with the retry protocol's framing selected:
    /// `framed` plans account every flush/fetch at the CRC-framed chunk
    /// sizes ([`Msg::exchange_wire_size_framed`]; `Raw` included — integrity
    /// needs the frame), carry per-bucket frame scratch sized to the largest
    /// slot, and pre-seed the fresh slots with the replica's initial params
    /// (the degraded path's last-known values before any delivery). Unframed
    /// plans are byte-for-byte the historical construction.
    pub fn new_framed( // lint: alloc-ok(plan construction, once per job)
        net: &NeuralNet,
        coalesce_bytes: usize,
        wire_codec: Codec,
        framed: bool,
    ) -> ParamWorkspace {
        let params = net.params();
        let names: Vec<&str> = params.iter().map(|p| p.name.as_str()).collect();
        let (logicals, param_slot) = logical_slot_map(&names);
        let mut slots: Vec<SlotInfo> = logicals
            .into_iter()
            .map(|logical| SlotInfo { logical, replicas: 0, params: Vec::new(), byte_size: 0 })
            .collect();
        let mut shapes: Vec<&[usize]> = vec![&[]; slots.len()];
        for (j, p) in params.iter().enumerate() {
            let s = &mut slots[param_slot[j]];
            if s.replicas == 0 {
                s.byte_size = p.data.byte_size();
                shapes[param_slot[j]] = p.data.shape();
            } else {
                assert_eq!(
                    shapes[param_slot[j]],
                    p.data.shape(),
                    "replica shape mismatch for {} (logical {})",
                    p.name,
                    s.logical
                );
            }
            s.replicas += 1;
            s.params.push(j);
        }

        // Fixed-order flush buckets over the slot list.
        let keyed: Vec<(String, usize)> =
            slots.iter().map(|s| (s.logical.clone(), s.byte_size)).collect();
        let layout = bucket_slots(&keyed, coalesce_bytes);
        let mut slot_bucket = vec![0usize; slots.len()];
        let mut slot_pos = vec![0usize; slots.len()];
        let mut buckets: Vec<BucketSpec> = Vec::with_capacity(layout.len());
        for (b, bucket) in layout.into_iter().enumerate() {
            let mut spec = BucketSpec {
                slots: bucket,
                flush_bytes: 0,
                fetch_bytes: 0,
                node_list: Vec::new(),
            };
            for (pos, &s) in spec.slots.iter().enumerate() {
                slot_bucket[s] = b;
                slot_pos[s] = pos;
                if framed {
                    let framed_len = wire_codec.framed_len(slots[s].byte_size / 4);
                    spec.flush_bytes +=
                        Msg::exchange_wire_size_framed(wire_codec, slots[s].byte_size);
                    spec.fetch_bytes += framed_len * slots[s].replicas;
                } else {
                    spec.flush_bytes +=
                        Msg::exchange_wire_size_coded(wire_codec, slots[s].byte_size);
                    spec.fetch_bytes +=
                        wire_codec.wire_bytes(slots[s].byte_size) * slots[s].replicas;
                }
            }
            buckets.push(spec);
        }

        // Node → bucket + per-param aggregation actions. A node's params
        // all share one owning layer, hence one bucket.
        let mut node_bucket = vec![usize::MAX; net.len()];
        let mut node_actions: Vec<Vec<(usize, bool)>> = vec![Vec::new(); net.len()];
        let mut j = 0usize;
        for (i, node) in net.nodes().iter().enumerate() {
            let nparams = node.layer.params().len();
            if nparams == 0 {
                continue;
            }
            let b = slot_bucket[param_slot[j]];
            for jj in j..j + nparams {
                let s = param_slot[jj];
                assert_eq!(
                    slot_bucket[s],
                    b,
                    "params of node '{}' span buckets",
                    node.layer.name()
                );
                node_actions[i].push((slot_pos[s], slots[s].params[0] == jj));
            }
            node_bucket[i] = b;
            buckets[b].node_list.push(i);
            j += nparams;
        }

        let bufs = buckets
            .iter()
            .map(|spec| {
                let mut sums: Vec<Blob> = spec.slots.iter().map(|_| Blob::default()).collect();
                let mut fresh: Vec<Blob> = spec.slots.iter().map(|_| Blob::default()).collect();
                for (i, &s) in spec.slots.iter().enumerate() {
                    sums[i].resize(shapes[s]);
                    fresh[i].resize(shapes[s]);
                }
                let (mut residual, mut dec) = (Vec::new(), Vec::new());
                let mut enc = Vec::new();
                let max_elems =
                    spec.slots.iter().map(|&s| slots[s].byte_size / 4).max().unwrap_or(0);
                if wire_codec != Codec::Raw {
                    residual = spec.slots.iter().map(|&s| Blob::zeros(shapes[s])).collect();
                    dec = spec.slots.iter().map(|&s| Blob::zeros(shapes[s])).collect();
                    enc.reserve(wire_codec.encoded_len(max_elems));
                }
                let mut frame = Vec::new();
                if framed {
                    frame.reserve(codec::FRAME_HEADER + wire_codec.encoded_len(max_elems));
                    // Degraded buckets adopt their last-known fresh values;
                    // before any delivery that is the replica's initial
                    // params (same seed as the server registration).
                    for (i, &s) in spec.slots.iter().enumerate() {
                        fresh[i].copy_from(&params[slots[s].params[0]].data);
                    }
                }
                let buf = BucketBuf {
                    sums,
                    fresh,
                    residual,
                    dec,
                    enc,
                    frame,
                    last_seq: u32::MAX,
                    epoch: 0,
                    finish_virt_us: 0.0,
                };
                (
                    OrderedMutex::new(RANK_WORKSPACE_BUCKET, "workspace.bucket", buf),
                    OrderedCondvar::new(),
                )
            })
            .collect();

        ParamWorkspace {
            plan: Arc::new(ExchangePlan {
                slots,
                param_slot,
                node_bucket,
                node_actions,
                buckets,
                codec: wire_codec,
                framed,
            }),
            store: Arc::new(BucketStore { bufs }),
        }
    }

    pub fn plan(&self) -> &Arc<ExchangePlan> {
        &self.plan
    }

    pub fn store(&self) -> &Arc<BucketStore> {
        &self.store
    }

    pub fn nbuckets(&self) -> usize {
        self.plan.buckets.len()
    }

    pub fn slots(&self) -> &[SlotInfo] {
        &self.plan.slots
    }

    /// Aggregate bucket `b`'s replica gradients from `net` into its
    /// persistent sum slots: walking the bucket's contributing nodes in
    /// ascending order (= ascending global param order), the first replica
    /// of each slot is copied, later replicas `add_assign`ed, then one
    /// `scale(1/replicas)` per slot — bit-identical to the historical
    /// whole-net HashMap recipe, restricted to this bucket, without ever
    /// materializing the full param list. Zero Blob allocations.
    pub fn aggregate_bucket(&self, net: &NeuralNet, b: usize) {
        let spec = &self.plan.buckets[b];
        let mut buf = self.store.bufs[b].0.lock().unwrap();
        for &ni in &spec.node_list {
            let nparams = net.nodes()[ni].layer.params();
            for (p, &(i, first)) in nparams.iter().zip(&self.plan.node_actions[ni]) {
                if first {
                    buf.sums[i].copy_from(&p.grad);
                } else {
                    buf.sums[i].add_assign(&p.grad);
                }
            }
        }
        for (i, &s) in spec.slots.iter().enumerate() {
            buf.sums[i].scale(1.0 / self.plan.slots[s].replicas as f32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::partition::{logical_param_name, partition_net};
    use crate::model::NetBuilder;
    use crate::utils::rng::Rng;
    use std::collections::HashMap;

    fn partitioned_mlp(workers: usize) -> NeuralNet {
        let mut b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![8, 6] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![8] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 10, act: Activation::Relu, init_std: 0.2 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.2 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));
        for c in b.confs_mut().iter_mut() {
            if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
                c.partition_dim = Some(0);
            }
        }
        let (bp, _) = partition_net(&b, workers);
        bp.build(&mut Rng::new(11))
    }

    /// The bucketed aggregation must reproduce the historical HashMap
    /// recipe (clone-first, add_assign-later, scale by 1/count) bit for
    /// bit, including replica counting on a dim-0 partitioned net.
    #[test]
    fn aggregation_matches_hashmap_reference_bitwise() {
        let mut net = partitioned_mlp(2);
        // Give every param a distinct, deterministic gradient.
        let mut rng = Rng::new(5);
        for p in net.params_mut() {
            let n = p.grad.len();
            p.grad = Blob::from_vec(p.data.shape(), rng.uniform_vec(n, -1.0, 1.0));
        }
        // Historical reference.
        let mut agg: HashMap<String, (Blob, usize)> = HashMap::new();
        for p in net.params() {
            let logical = logical_param_name(&p.name);
            match agg.get_mut(&logical) {
                Some((sum, count)) => {
                    sum.add_assign(&p.grad);
                    *count += 1;
                }
                None => {
                    agg.insert(logical, (p.grad.clone(), 1));
                }
            }
        }
        for (_, (sum, count)) in agg.iter_mut() {
            sum.scale(1.0 / *count as f32);
        }

        let ws = ParamWorkspace::new(&net, 0, Codec::Raw);
        for b in 0..ws.nbuckets() {
            ws.aggregate_bucket(&net, b);
        }
        assert_eq!(ws.slots().len(), agg.len());
        for b in 0..ws.nbuckets() {
            let buf = ws.store().bufs[b].0.lock().unwrap();
            for (i, &s) in ws.plan().buckets[b].slots.iter().enumerate() {
                let info = &ws.slots()[s];
                let (want, count) =
                    agg.get(&info.logical).expect("slot has a reference entry");
                assert_eq!(info.replicas, *count, "{}", info.logical);
                assert_eq!(buf.sums[i].shape(), want.shape());
                for (x, y) in buf.sums[i].data().iter().zip(want.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{} diverged", info.logical);
                }
            }
        }
    }

    /// Steady-state aggregation cycles allocate zero Blobs: the sums were
    /// sized at construction and reused every step.
    #[test]
    fn steady_state_aggregation_is_allocation_free() {
        let net = partitioned_mlp(2);
        let ws = ParamWorkspace::new(&net, 0, Codec::Raw);
        for b in 0..ws.nbuckets() {
            ws.aggregate_bucket(&net, b); // warm (already sized)
        }
        let before = Blob::alloc_count();
        for _ in 0..5 {
            for b in 0..ws.nbuckets() {
                ws.aggregate_bucket(&net, b);
            }
        }
        assert_eq!(Blob::alloc_count(), before, "aggregation must not allocate");
    }

    /// Bucket layout over a replicated (dim-0) net: replicas share slots,
    /// each layer's slots land in one bucket at threshold 0, the node →
    /// bucket map covers every param-bearing node, and per-bucket node
    /// counts equal the replica fan-in.
    #[test]
    fn bucket_layout_on_partitioned_net() {
        let net = partitioned_mlp(3);
        let ws = ParamWorkspace::new(&net, 0, Codec::Raw);
        let plan = ws.plan();
        // Two logical layers with params (h1, logits) → two buckets.
        assert_eq!(ws.nbuckets(), 2);
        for spec in &plan.buckets {
            // 3 replica sub-layers contribute to every bucket, ascending.
            assert_eq!(spec.node_list.len(), 3);
            assert!(spec.node_list.windows(2).all(|w| w[0] < w[1]));
            for &s in &spec.slots {
                assert_eq!(plan.slots[s].replicas, 3);
                assert_eq!(plan.slots[s].params.len(), 3);
            }
            assert!(spec.flush_bytes > 0 && spec.fetch_bytes > 0);
        }
        // Every param-bearing node maps to a bucket (with one action per
        // param); others to MAX.
        for (i, node) in net.nodes().iter().enumerate() {
            let nparams = node.layer.params().len();
            assert_eq!(plan.node_bucket[i] != usize::MAX, nparams > 0);
            assert_eq!(plan.node_actions[i].len(), nparams);
        }
        // Coalescing everything yields the single-bucket degenerate case.
        let one = ParamWorkspace::new(&net, usize::MAX, Codec::Raw);
        assert_eq!(one.nbuckets(), 1);
        assert_eq!(one.plan().buckets[0].node_list.len(), 6);
    }

    /// Flush wire accounting matches the historical per-slot formula
    /// (`2 * payload + 128`) summed over the bucket, and fetch accounting
    /// matches the per-replica value charge.
    #[test]
    fn bucket_wire_bytes_match_historical_formulas() {
        let net = partitioned_mlp(2);
        let ws = ParamWorkspace::new(&net, usize::MAX, Codec::Raw);
        let spec = &ws.plan().buckets[0];
        let want_flush: usize =
            ws.slots().iter().map(|s| 2 * s.byte_size + 128).sum();
        let want_fetch: usize =
            ws.slots().iter().map(|s| s.byte_size * s.replicas).sum();
        assert_eq!(spec.flush_bytes, want_flush);
        assert_eq!(spec.fetch_bytes, want_fetch);
    }

    /// Under a quantizing codec the plan's wire accounting uses the
    /// encoded chunk sizes ([`Msg::exchange_wire_size_coded`] per slot for
    /// flushes, `wire_bytes × replicas` for fetches), and the scratch
    /// buffers (residual, dec, enc) are sized at construction.
    #[test]
    fn coded_bucket_wire_bytes_match_codec_formulas() {
        let net = partitioned_mlp(2);
        for codec in [Codec::F16, Codec::Int8] {
            let ws = ParamWorkspace::new(&net, usize::MAX, codec);
            let spec = &ws.plan().buckets[0];
            let want_flush: usize = ws
                .slots()
                .iter()
                .map(|s| Msg::exchange_wire_size_coded(codec, s.byte_size))
                .sum();
            let want_fetch: usize =
                ws.slots().iter().map(|s| codec.wire_bytes(s.byte_size) * s.replicas).sum();
            assert_eq!(spec.flush_bytes, want_flush, "{} flush", codec.name());
            assert_eq!(spec.fetch_bytes, want_fetch, "{} fetch", codec.name());
            // Coded plans get per-slot residual + decode scratch and an
            // encode buffer big enough for the largest slot.
            let buf = ws.store().bufs[0].0.lock().unwrap();
            assert_eq!(buf.residual.len(), spec.slots.len());
            assert_eq!(buf.dec.len(), spec.slots.len());
            let max_elems = ws.slots().iter().map(|s| s.byte_size / 4).max().unwrap();
            assert!(buf.enc.capacity() >= codec.encoded_len(max_elems));
        }
        // Raw plans carry no codec scratch at all.
        let raw = ParamWorkspace::new(&net, usize::MAX, Codec::Raw);
        let buf = raw.store().bufs[0].0.lock().unwrap();
        assert!(buf.residual.is_empty() && buf.dec.is_empty() && buf.enc.capacity() == 0);
    }

    /// Framed (retry-protocol) plans account every slot at the CRC-framed
    /// chunk sizes — `Raw` included — carry frame scratch sized to the
    /// largest slot, and pre-seed the fresh slots with the replica's
    /// initial params (the degraded path's last-known values). Unframed
    /// plans carry no frame scratch at all.
    #[test]
    fn framed_bucket_wire_bytes_and_scratch() {
        let net = partitioned_mlp(2);
        for wire_codec in [Codec::Raw, Codec::Int8] {
            let ws = ParamWorkspace::new_framed(&net, usize::MAX, wire_codec, true);
            assert!(ws.plan().framed);
            let spec = &ws.plan().buckets[0];
            let want_flush: usize = ws
                .slots()
                .iter()
                .map(|s| Msg::exchange_wire_size_framed(wire_codec, s.byte_size))
                .sum();
            let want_fetch: usize = ws
                .slots()
                .iter()
                .map(|s| wire_codec.framed_len(s.byte_size / 4) * s.replicas)
                .sum();
            assert_eq!(spec.flush_bytes, want_flush, "{} framed flush", wire_codec.name());
            assert_eq!(spec.fetch_bytes, want_fetch, "{} framed fetch", wire_codec.name());
            let buf = ws.store().bufs[0].0.lock().unwrap();
            let max_elems = ws.slots().iter().map(|s| s.byte_size / 4).max().unwrap();
            assert!(
                buf.frame.capacity() >= codec::FRAME_HEADER + wire_codec.encoded_len(max_elems),
                "{} frame scratch",
                wire_codec.name()
            );
            assert_eq!(buf.last_seq, u32::MAX);
            // Fresh slots start at the replica's initial params, bitwise.
            let params = net.params();
            for (i, &s) in spec.slots.iter().enumerate() {
                let init = &params[ws.slots()[s].params[0]].data;
                for (x, y) in buf.fresh[i].data().iter().zip(init.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "fresh slot {i} not pre-seeded");
                }
            }
        }
        let unframed = ParamWorkspace::new(&net, usize::MAX, Codec::Raw);
        assert!(!unframed.plan().framed);
        let buf = unframed.store().bufs[0].0.lock().unwrap();
        assert_eq!(buf.frame.capacity(), 0, "unframed plans carry no frame scratch");
    }

    /// `WireCounters::mark_degraded` counts each degraded step once no
    /// matter how many buckets of that step degrade, and the snapshot
    /// carries the group's tally as the single `degraded_steps` entry.
    #[test]
    fn wire_counters_dedup_degraded_steps() {
        let c = WireCounters::new();
        c.mark_degraded(3);
        c.mark_degraded(3);
        c.mark_degraded(7);
        let snap = c.snapshot();
        assert_eq!(snap.staleness_adoptions, 3);
        assert_eq!(snap.degraded_steps, vec![2]);
        assert!(!snap.is_clean());
        assert!(WireCounters::new().snapshot().drops == 0);
    }
}
