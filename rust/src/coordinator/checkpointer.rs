//! Asynchronous checkpointing of server-group params, off the hot path:
//! worker group 0 requests a snapshot at its cadence boundary (one channel
//! send — no Blob allocation, no serialization on the worker thread) and a
//! background *checkpointer* thread snapshots server group 0's params,
//! keeps the latest snapshot in memory as the recovery source, and — when
//! a directory is configured — writes it durably through
//! [`Checkpoint::write_to`] via a temp-file + rename (a crash mid-write
//! never leaves a torn `.ckpt` behind).
//!
//! Recovery ([`Checkpointer::latest_blocking`]) waits until every requested
//! snapshot has completed before returning the latest one, so a restart
//! that follows a cadence boundary deterministically sees that boundary's
//! state — the property the bit-identical restart test pins.

use crate::model::checkpoint::Checkpoint;
use crate::runtime::sync::{
    OrderedCondvar, OrderedMutex, RANK_CKPT_CHANNEL, RANK_CKPT_STATE, RANK_CKPT_WRITER,
};
use crate::server::ServerGroup;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

/// Checkpoint cadence + durability knobs ([`super::JobConf::checkpoint`]).
#[derive(Debug, Clone)]
pub struct CheckpointConf {
    /// Snapshot after every `every_steps` completed steps of worker group 0
    /// (0 never snapshots — the checkpointer idles).
    pub every_steps: u64,
    /// When set, each snapshot is also written durably to
    /// `<dir>/<job>.step<N>.ckpt` (temp-file + rename).
    pub dir: Option<PathBuf>,
}

impl CheckpointConf {
    pub fn every(steps: u64) -> CheckpointConf {
        CheckpointConf { every_steps: steps, dir: None }
    }

    pub fn with_dir(mut self, dir: impl Into<PathBuf>) -> CheckpointConf {
        self.dir = Some(dir.into());
        self
    }
}

struct State {
    /// Snapshots requested by the worker plane (monotone).
    requested: u64,
    /// Snapshots captured in memory (export done, durable write possibly
    /// still in flight). The requester waits on this — the export must
    /// observe the exact cadence boundary, before later flushes mutate the
    /// server — while the expensive serialization stays asynchronous.
    exported: u64,
    /// Snapshots fully completed, including the durable write when one is
    /// configured (trails `exported`).
    completed: u64,
    /// The newest snapshot: (completed steps, params). `Arc` so recovery
    /// can hold it without cloning tensor payloads under the lock.
    latest: Option<Arc<(u64, Checkpoint)>>,
    /// Durable-write failures (recorded, not fatal: the in-memory snapshot
    /// still serves recovery; the job surfaces these at shutdown).
    io_errors: Vec<String>,
    /// Set when the writer thread exits — waiters must not block on
    /// snapshots a dead writer will never complete.
    writer_dead: bool,
}

/// Handle shared by the worker threads (request/recover) and `run_job`
/// (shutdown). See the module docs for the protocol.
pub struct Checkpointer {
    /// Ranked above the channel slot: [`Checkpointer::request`] holds `tx`
    /// while bumping `requested` under `state`.
    state: OrderedMutex<State>,
    cv: OrderedCondvar,
    tx: OrderedMutex<Option<mpsc::Sender<u64>>>,
    writer: OrderedMutex<Option<std::thread::JoinHandle<()>>>,
}

impl Checkpointer {
    /// Start the background writer against `servers[0]` (the authoritative
    /// replica in single-server-group topologies; group 0's replica under
    /// hogwild).
    pub fn spawn(
        conf: CheckpointConf,
        servers: Arc<Vec<ServerGroup>>,
        job: &str,
    ) -> Arc<Checkpointer> {
        let (tx, rx) = mpsc::channel::<u64>();
        let ck = Arc::new(Checkpointer {
            state: OrderedMutex::new(
                RANK_CKPT_STATE,
                "ckpt.state",
                State {
                    requested: 0,
                    exported: 0,
                    completed: 0,
                    latest: None,
                    io_errors: Vec::new(),
                    writer_dead: false,
                },
            ),
            cv: OrderedCondvar::new(),
            tx: OrderedMutex::new(RANK_CKPT_CHANNEL, "ckpt.channel", Some(tx)),
            writer: OrderedMutex::new(RANK_CKPT_WRITER, "ckpt.writer", None),
        });
        let me = ck.clone();
        let job = job.to_string();
        let handle = std::thread::Builder::new()
            .name("checkpointer".into())
            .spawn(move || {
                // Mark the writer dead on every exit path (including a
                // panic in `export_params`) so `latest_blocking` waiters
                // wake instead of hanging on a snapshot that never lands.
                struct ExitGuard(Arc<Checkpointer>);
                impl Drop for ExitGuard {
                    fn drop(&mut self) {
                        let mut st = self.0.state.lock().unwrap();
                        st.writer_dead = true;
                        drop(st);
                        self.0.cv.notify_all();
                    }
                }
                let _mark_dead = ExitGuard(me.clone());
                while let Ok(step) = rx.recv() {
                    let snap = Arc::new((step, Checkpoint {
                        tensors: servers[0].export_params(),
                    }));
                    // Publish the in-memory snapshot immediately: the
                    // requester blocked on `wait_exported` resumes training
                    // (and mutating the servers) as soon as the boundary is
                    // captured, while the durable write proceeds below.
                    {
                        let mut st = me.state.lock().unwrap();
                        st.latest = Some(snap.clone());
                        st.exported += 1;
                        drop(st);
                        me.cv.notify_all();
                    }
                    let mut io_err = None;
                    if let Some(dir) = &conf.dir {
                        let tmp = dir.join(format!(".{job}.step{step}.ckpt.tmp"));
                        let fin = dir.join(format!("{job}.step{step}.ckpt"));
                        let write = || -> Result<(), String> {
                            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                            snap.1.save(&tmp).map_err(|e| e.to_string())?;
                            std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
                            Ok(())
                        };
                        if let Err(e) = write() {
                            io_err = Some(format!("checkpoint step {step}: {e}"));
                        }
                    }
                    let mut st = me.state.lock().unwrap();
                    st.completed += 1;
                    if let Some(e) = io_err {
                        st.io_errors.push(e);
                    }
                    drop(st);
                    me.cv.notify_all();
                }
            })
            .expect("spawn checkpointer");
        *ck.writer.lock().unwrap() = Some(handle);
        ck
    }

    /// Request a snapshot of the state after `step` completed steps. One
    /// channel send — the worker hot path never serializes or allocates.
    pub fn request(&self, step: u64) {
        let tx = self.tx.lock().unwrap();
        if let Some(tx) = tx.as_ref() {
            let mut st = self.state.lock().unwrap();
            if tx.send(step).is_ok() {
                st.requested += 1;
            }
        }
    }

    /// Block until every requested snapshot has been captured in memory.
    /// Called by the requester right after [`Checkpointer::request`]: the
    /// export is a memcpy on the writer thread (no worker-thread Blob
    /// allocation), but it must land before the worker's next flush mutates
    /// the servers or the snapshot would smear past its step boundary.
    pub fn wait_exported(&self) {
        let mut st = self.state.lock().unwrap();
        while st.exported < st.requested && !st.writer_dead {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// The latest snapshot, after every requested one has completed (a
    /// recovering group must not race the writer and restore a stale
    /// boundary). `None` when nothing was ever requested.
    pub fn latest_blocking(&self) -> Option<Arc<(u64, Checkpoint)>> {
        let mut st = self.state.lock().unwrap();
        while st.completed < st.requested && !st.writer_dead {
            st = self.cv.wait(st).unwrap();
        }
        st.latest.clone()
    }

    /// Snapshots completed so far.
    pub fn completed(&self) -> u64 {
        self.state.lock().unwrap().completed
    }

    /// Durable-write failures recorded so far.
    pub fn io_errors(&self) -> Vec<String> {
        self.state.lock().unwrap().io_errors.clone()
    }

    /// Retire the writer thread (any queued snapshots land first); returns
    /// the total snapshots taken. Idempotent.
    pub fn shutdown(&self) -> u64 {
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
        self.completed()
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ByteLedger;
    use crate::tensor::Blob;
    use crate::updater::UpdaterConf;

    fn one_group() -> Arc<Vec<ServerGroup>> {
        let g = ServerGroup::new(2, UpdaterConf::sgd(0.1), Arc::new(ByteLedger::new()));
        g.put("w", Blob::full(&[6], 1.0), 1.0, 1.0);
        g.put("b", Blob::full(&[2], -1.0), 1.0, 1.0);
        Arc::new(vec![g])
    }

    #[test]
    fn request_complete_latest_roundtrip() {
        let servers = one_group();
        let ck = Checkpointer::spawn(CheckpointConf::every(4), servers.clone(), "t");
        assert!(ck.latest_blocking().is_none(), "nothing requested yet");
        ck.request(4);
        let snap = ck.latest_blocking().expect("snapshot lands");
        assert_eq!(snap.0, 4);
        assert_eq!(snap.1.tensors.len(), 2);
        assert_eq!(snap.1.tensors["w"].data(), &[1.0; 6]);
        // A later request observes the mutated server state.
        servers[0].update("w", &Blob::full(&[6], 1.0), 0);
        ck.request(8);
        let snap = ck.latest_blocking().expect("second snapshot");
        assert_eq!(snap.0, 8);
        assert!(snap.1.tensors["w"].data()[0] < 1.0);
        assert_eq!(ck.shutdown(), 2);
        assert!(ck.io_errors().is_empty());
    }

    #[test]
    fn durable_snapshots_land_as_loadable_files() {
        let dir = std::env::temp_dir().join(format!("singa_ckpt_dir_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let servers = one_group();
        let conf = CheckpointConf::every(2).with_dir(&dir);
        let ck = Checkpointer::spawn(conf, servers, "job");
        ck.request(2);
        ck.request(4);
        assert_eq!(ck.latest_blocking().unwrap().0, 4);
        ck.shutdown();
        for step in [2u64, 4] {
            let path = dir.join(format!("job.step{step}.ckpt"));
            let loaded = Checkpoint::load(&path)
                .unwrap_or_else(|e| panic!("{} must load: {e}", path.display()));
            assert_eq!(loaded.tensors.len(), 2);
        }
        // No temp files left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files must be renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Requests after shutdown are dropped, not panics; `latest_blocking`
    /// never hangs on them.
    #[test]
    fn request_after_shutdown_is_ignored() {
        let ck = Checkpointer::spawn(CheckpointConf::every(1), one_group(), "t");
        ck.request(1);
        ck.shutdown();
        ck.request(2);
        let snap = ck.latest_blocking().expect("first snapshot still served");
        assert_eq!(snap.0, 1);
    }
}
