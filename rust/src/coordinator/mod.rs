//! The job coordinator (paper §3, §5): given a job configuration —
//! NeuralNet + TrainOneBatch + Updater + ClusterTopology — it materializes
//! server groups, spawns one thread per worker group, shards the data
//! stream, moves parameters between workers and servers, and collects
//! metrics on both wall and virtual clocks.
//!
//! Worker groups run asynchronously (real threads, real interleaving);
//! workers *within* a group run synchronously over a partitioned net. On
//! this single-core testbed the intra-group parallel speedup is modeled on
//! the virtual clock (ideal compute split + measured comm charges via the
//! [`CostModel`]) while training semantics are exact — see DESIGN.md
//! §Hardware-Adaptation.

pub mod checkpointer;
pub mod copyqueue;
pub mod exchange;
pub mod workspace;

use crate::cluster::ClusterTopology;
use crate::comm::{
    ByteLedger, Codec, CostModel, FaultPlan, FaultRecord, RetryConf, VirtualClock, WireEvents,
};
use crate::data::DataSource;
use crate::metrics::{Record, TrainingLog};
use crate::model::partition::{logical_param_name, partition_net};
use crate::model::NetBuilder;
use crate::server::ServerGroup;
use crate::train::{bp::Bp, cd::Cd, TrainOneBatch};
use crate::tensor::Blob;
use crate::updater::UpdaterConf;
use crate::utils::rng::Rng;
use crate::utils::timer::Stopwatch;
use crate::runtime::sync::{OrderedCondvar, OrderedMutex, RANK_WARMUP_GATE};
use std::collections::HashMap;
use std::sync::Arc;
use self::checkpointer::Checkpointer;
pub use self::checkpointer::CheckpointConf;
use self::exchange::GroupExchange;
use self::workspace::WireCounters;

/// Which `TrainOneBatch` algorithm the job uses (paper §4.1.3).
#[derive(Debug, Clone, PartialEq)]
pub enum Algorithm {
    Bp,
    Cd { k: usize, stage: Option<String> },
}

impl Algorithm {
    fn instantiate(&self) -> Box<dyn TrainOneBatch> {
        match self {
            Algorithm::Bp => Box::new(Bp::new()),
            Algorithm::Cd { k, stage } => Box::new(match stage {
                Some(s) => Cd::stage(*k, s),
                None => Cd::new(*k),
            }),
        }
    }
}

/// Full job configuration (the four components of paper §3).
#[derive(Clone)]
pub struct JobConf {
    pub name: String,
    pub net: NetBuilder,
    pub algorithm: Algorithm,
    pub updater: UpdaterConf,
    pub topology: ClusterTopology,
    /// Mini-batch per worker group.
    pub batch_size: usize,
    pub iters: u64,
    pub seed: u64,
    /// Partition the net across the group's workers (dim hints must be set
    /// on the layer confs). When false, group workers only model throughput.
    pub partition_within_group: bool,
    /// Cost model for the simulated deployment's virtual clock.
    pub cost: CostModel,
    /// Overlap the parameter exchange with computation: flush gradient
    /// buckets to the servers during the backward pass and prefetch fresh
    /// values for the next forward (paper §5's overlap claim). `false`
    /// restores the strictly sequential post-step exchange; trajectories
    /// are bit-identical either way — only the timing (and the virtual
    /// clock's accounting of it) changes.
    pub overlap_exchange: bool,
    /// Flush buckets default to one per owning layer; consecutive layers
    /// coalesce into one bucket while its payload stays below this many
    /// bytes (tiny params ride along instead of paying a message each).
    /// 0 = pure per-layer buckets; `usize::MAX` = a single bucket (the
    /// sequential degenerate case).
    pub bucket_coalesce_bytes: usize,
    /// Wire codec for the steady-state parameter exchange: flush buckets
    /// (gradients up, fresh values down) are encoded per chunk with a
    /// quantization scale, with an error-feedback residual per slot so the
    /// gradient compression error is re-injected into the next flush (see
    /// [`crate::comm::codec`]). [`Codec::Raw`] (the default) is
    /// bit-identical to the uncompressed plane in values AND in byte
    /// accounting; f16/int8 shrink the modeled wire ~2×/~4×.
    pub wire_codec: Codec,
    /// Log every n-th iteration; 0 logs only the final step.
    pub log_every: u64,
    /// Warm-up: group 0 trains alone for this many iterations before the
    /// other groups start (paper §6.2.3: "a warm-up stage, which trains the
    /// model using a single worker group at the beginning, may help to
    /// stabilize the training as reported in Google's DistBelief"). Targets
    /// beyond `iters` are clamped — group 0 cannot complete more steps than
    /// it runs, and the gate opens unconditionally when it exits.
    pub warmup_iters: u64,
    /// When `Some(w)`: every worker group counts the Blob allocations its
    /// thread performs in steps `>= w` and reports the per-group totals in
    /// [`JobReport::steady_allocs`] — the distributed zero-alloc probe.
    pub alloc_probe_from: Option<u64>,
    /// Deterministic fault-injection schedule on the simnet clock —
    /// per-group kills and straggler delays ([`FaultPlan::none`] for the
    /// perfect cluster). Kills are recovered, not fatal: the group restarts
    /// from the latest checkpoint and resumes its shard stream.
    pub faults: FaultPlan,
    /// Retry/timeout/backoff knobs for the wire protocol, active when the
    /// fault plan schedules wire faults: each bucket flush arms a
    /// virtual-clock deadline, lost/corrupt deliveries retransmit with
    /// exponential backoff, and a bucket that exhausts `max_attempts`
    /// degrades to its last-known value (bounded staleness) instead of
    /// hanging the worker. Ignored on fault-free plans — the historical
    /// frameless exchange runs bit-for-bit.
    pub retry: RetryConf,
    /// Periodic asynchronous checkpointing of server group 0's params —
    /// the recovery source for worker-group restarts. Worker group 0
    /// requests a snapshot every `every_steps` steps (one channel send; the
    /// serialization happens on the background checkpointer thread, so
    /// worker `steady_allocs` stays 0). `None` disables.
    pub checkpoint: Option<CheckpointConf>,
    /// Backup workers per group for straggler mitigation (sandblaster's
    /// duplicate-flush-discard): with backups, a delayed step's compute
    /// charge stays at the healthy per-worker time — the backup's copy of
    /// the straggler's shard wins the race — while the duplicate flush is
    /// charged to the wire and discarded. Training values are identical
    /// with or without backups; only clock/ledger accounting and
    /// [`JobReport::backup_rescues`] change. 0 disables.
    pub backup_workers: usize,
}

impl JobConf {
    pub fn new(name: &str, net: NetBuilder) -> JobConf {
        JobConf {
            name: name.to_string(),
            net,
            algorithm: Algorithm::Bp,
            updater: UpdaterConf::sgd(0.1),
            topology: ClusterTopology::sandblaster(1, 1),
            batch_size: 16,
            iters: 100,
            seed: 0x51464a,
            partition_within_group: false,
            cost: CostModel::numa_server(),
            overlap_exchange: true,
            bucket_coalesce_bytes: 4096,
            wire_codec: Codec::Raw,
            log_every: 1,
            warmup_iters: 0,
            alloc_probe_from: None,
            faults: FaultPlan::none(),
            retry: RetryConf::default(),
            checkpoint: None,
            backup_workers: 0,
        }
    }
}

/// Warm-up gate (paper §6.2.3): group 0 publishes its completed-step count;
/// groups 1+ sleep on the condvar until it reaches the (clamped) warm-up
/// target instead of busy-spinning. [`WarmupGate::release`] opens the gate
/// unconditionally — called from a drop guard when group 0's thread exits,
/// so a `warmup_iters >= iters` job (or a panicking group 0) can never
/// strand the other groups.
struct WarmupGate {
    steps: OrderedMutex<u64>,
    cv: OrderedCondvar,
}

impl WarmupGate {
    fn new() -> WarmupGate {
        WarmupGate {
            steps: OrderedMutex::new(RANK_WARMUP_GATE, "warmup.gate", 0),
            cv: OrderedCondvar::new(),
        }
    }

    /// Group 0: publish `done` completed steps (monotone).
    fn advance(&self, done: u64) {
        let mut s = self.steps.lock().unwrap();
        if *s < done {
            *s = done;
            self.cv.notify_all();
        }
    }

    /// Open the gate for every waiter, regardless of progress.
    fn release(&self) {
        self.advance(u64::MAX);
    }

    /// Groups 1+: block until group 0 has completed `target` steps.
    fn wait(&self, target: u64) {
        let mut s = self.steps.lock().unwrap();
        while *s < target {
            s = self.cv.wait(s).unwrap();
        }
    }
}

/// RAII opener: group 0 holds one for its thread's lifetime so the gate
/// releases on every exit path, including panics.
struct GateRelease<'a>(&'a WarmupGate);

impl Drop for GateRelease<'_> {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// Result of a job run.
pub struct JobReport {
    pub log: Arc<TrainingLog>,
    pub ledger: Arc<ByteLedger>,
    pub wall_ms: f64,
    /// Final virtual clock per worker group (ms).
    pub group_virt_ms: Vec<f64>,
    /// Trained parameters by logical name (from server group 0).
    pub params: HashMap<String, Blob>,
    /// Final parameters of EVERY server group, by logical name — lets tests
    /// see replicas that only neighbour syncs connect (distributed Hogwild).
    pub group_params: Vec<HashMap<String, Blob>>,
    /// Per worker group: Blob allocations its thread performed in steps at
    /// or after [`JobConf::alloc_probe_from`] (all zeros when the probe is
    /// off — the zero-clone parameter-plane claim).
    pub steady_allocs: Vec<u64>,
    /// Per worker group: `Some(panic message)` when the group's thread
    /// panicked (an *unscheduled* death — scheduled kills are recovered and
    /// land in [`JobReport::fault_events`] instead). A failed group zeroes
    /// its `group_virt_ms`/`steady_allocs` entries; healthy groups complete
    /// normally — a dead group no longer tears the job down.
    pub group_failures: Vec<Option<String>>,
    /// Every recovered kill, across all groups: where each group died,
    /// where it resumed, what recovery cost on its virtual clock.
    pub fault_events: Vec<FaultRecord>,
    /// Straggler steps hidden by backup workers (duplicate flush charged
    /// and discarded), summed over groups.
    pub backup_rescues: u64,
    /// Wire-plane tallies under the retry protocol: drops, detected
    /// corruptions, discarded duplicates/reorders, retransmits, staleness
    /// adoptions, wasted bytes (scalars summed over groups) and per-group
    /// degraded-step counts. All-zero on fault-free plans.
    pub wire_events: WireEvents,
    /// Asynchronous checkpoints taken by the background checkpointer.
    pub checkpoints: u64,
}

/// What one worker-group thread hands back to `run_job`.
struct GroupRun {
    virt_ms: f64,
    steady_allocs: u64,
    faults: Vec<FaultRecord>,
    backup_rescues: u64,
    /// The group's job-lifetime wire tallies (`degraded_steps` holds this
    /// one group's count; `run_job` absorbs them in join order).
    wire: WireEvents,
}

/// Render a worker thread's panic payload for [`JobReport::group_failures`].
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker group panicked".to_string()
    }
}

/// Run a training job to completion.
pub fn run_job(conf: &JobConf, data: Arc<dyn DataSource>) -> JobReport {
    let topo = &conf.topology;
    // A fault rule naming a worker group the job does not have would never
    // fire and the chaos scenario would silently test nothing — reject it
    // before any thread spawns. Retry knobs are checked only when the plan
    // actually arms the wire protocol.
    if let Err(e) = conf.faults.validate(topo.nworker_groups) {
        panic!("{e}");
    }
    if conf.faults.has_wire_faults() {
        conf.retry.validate();
    }
    let ledger = Arc::new(ByteLedger::new());

    // Register this job's worker groups for intra-op thread budgeting
    // BEFORE any group thread starts computing: while these guards live,
    // the default (env-unset) `runtime::threads()` budget is divided by the
    // active group count, so W groups × intra-op tasks never oversubscribe
    // the machine. Budget changes never change results (the parallel
    // kernels are bit-identical at every thread count).
    let _intra_op_budget: Vec<crate::runtime::WorkerGroupGuard> =
        (0..topo.nworker_groups).map(|_| crate::runtime::register_worker_group()).collect();

    // Build the (possibly partitioned) group-level net once to register
    // parameters, then per-group replicas in their threads.
    let (group_builder, _plan) = if conf.partition_within_group && topo.nworkers_per_group > 1 {
        partition_net(&conf.net, topo.nworkers_per_group)
    } else {
        (conf.net.clone(), Default::default())
    };

    // Server groups.
    let servers: Arc<Vec<ServerGroup>> = Arc::new(
        (0..topo.nserver_groups)
            .map(|_| ServerGroup::new(topo.nservers_per_group, conf.updater.clone(), ledger.clone()))
            .collect(),
    );

    // Register logical params (one probe net; same seed as the replicas so
    // initial values match everywhere).
    {
        let probe = group_builder.clone().build(&mut Rng::new(conf.seed));
        let mut seen = std::collections::HashSet::new();
        for p in probe.params() {
            let logical = logical_param_name(&p.name);
            if seen.insert(logical.clone()) {
                for sg in servers.iter() {
                    sg.put(&logical, p.data.clone(), p.lr_mult, p.wd_mult);
                }
            }
        }
    }

    // Asynchronous checkpoint plane: snapshots requested by worker group 0
    // land on this background thread, off every worker's hot path.
    let ckpt: Option<Arc<Checkpointer>> = conf
        .checkpoint
        .as_ref()
        .map(|cc| Checkpointer::spawn(cc.clone(), servers.clone(), &conf.name));

    let log = Arc::new(TrainingLog::new());
    let job_sw = Stopwatch::new();
    // Warm-up gate: group 0 publishes its completed-step count; groups 1+
    // sleep until it reaches the clamped target. The target can never
    // exceed `iters` (group 0 cannot complete more steps than it runs) and
    // group 0 opens the gate unconditionally on exit.
    let warmup_gate = Arc::new(WarmupGate::new());
    let warmup_target = conf.warmup_iters.min(conf.iters);

    let mut handles = Vec::new();
    for g in 0..topo.nworker_groups {
        let conf = conf.clone();
        let group_builder = group_builder.clone();
        let servers = servers.clone();
        let data = data.clone();
        let log = log.clone();
        let topo = topo.clone();
        let job_sw = job_sw.clone();
        let warmup_gate = warmup_gate.clone();
        let ckpt = ckpt.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("wg{g}"))
                .spawn(move || {
                    let _open_on_exit =
                        if g == 0 { Some(GateRelease(&*warmup_gate)) } else { None };
                    if g > 0 && conf.warmup_iters > 0 {
                        warmup_gate.wait(warmup_target);
                    }
                    worker_group_loop(
                        g, &conf, group_builder, &topo, &servers, &*data, &log, &job_sw,
                        &warmup_gate, ckpt.as_deref(),
                    )
                })
                .expect("spawn worker group"),
        );
    }
    let mut group_virt_ms = Vec::with_capacity(handles.len());
    let mut steady_allocs = Vec::with_capacity(handles.len());
    let mut group_failures = Vec::with_capacity(handles.len());
    let mut fault_events = Vec::new();
    let mut backup_rescues = 0u64;
    let mut wire_events = WireEvents::default();
    for h in handles {
        // A panicking group is a per-group failure, not a job abort: its
        // message lands in the report and the healthy groups still join
        // and deliver their results.
        match h.join() {
            Ok(run) => {
                group_virt_ms.push(run.virt_ms);
                steady_allocs.push(run.steady_allocs);
                group_failures.push(None);
                fault_events.extend(run.faults);
                backup_rescues += run.backup_rescues;
                wire_events.absorb(run.wire);
            }
            Err(payload) => {
                group_virt_ms.push(0.0);
                steady_allocs.push(0);
                group_failures.push(Some(panic_message(&*payload)));
                wire_events.degraded_steps.push(0);
            }
        }
    }
    // Retire the checkpointer (queued snapshots land first). Durable-write
    // failures are surfaced, never fatal — the in-memory snapshots already
    // served any recovery.
    let checkpoints = match &ckpt {
        Some(c) => {
            let n = c.shutdown();
            for e in c.io_errors() {
                eprintln!("[{}] checkpoint write failed: {e}", conf.name);
            }
            n
        }
        None => 0,
    };

    // Collect final params from every server group (group 0's replica also
    // exposed as `params` for compatibility).
    let group_params: Vec<HashMap<String, Blob>> = servers
        .iter()
        .map(|sg| {
            sg.param_names()
                .into_iter()
                .map(|name| {
                    let (v, _) = sg.get(&name);
                    (name, v)
                })
                .collect()
        })
        .collect();
    let params = group_params[0].clone();

    JobReport {
        log,
        ledger,
        wall_ms: job_sw.elapsed_ms(),
        group_virt_ms,
        params,
        group_params,
        steady_allocs,
        group_failures,
        fault_events,
        backup_rescues,
        wire_events,
        checkpoints,
    }
}

/// How one stint — an uninterrupted run of steps on one net/exchange —
/// ended: all steps done, or a scheduled kill at the top of `step`.
enum StintEnd {
    Completed,
    Killed { step: u64 },
}

/// Body of one worker-group thread: run stints until the step budget is
/// exhausted, recovering from every scheduled kill in between (restart
/// latency on the virtual clock, checkpoint restore or cold start for a
/// sole-tenant server group, live rejoin for a shared one).
#[allow(clippy::too_many_arguments)]
fn worker_group_loop(
    g: usize,
    conf: &JobConf,
    group_builder: NetBuilder,
    topo: &ClusterTopology,
    servers: &Arc<Vec<ServerGroup>>,
    data: &dyn DataSource,
    log: &TrainingLog,
    job_sw: &Stopwatch,
    warmup_gate: &WarmupGate,
    ckpt: Option<&Checkpointer>,
) -> GroupRun {
    let sg_idx = topo.server_group_of(g);
    let link = *topo.param_link(&conf.cost);
    let sg = &servers[sg_idx];
    let mut clock = VirtualClock::new();
    // Reused input slots: `batch_into` refills the same blobs every step.
    // Hoisted above the stint loop so replayed steps stay allocation-free.
    let mut inputs: HashMap<String, Blob> = HashMap::new();
    let mut steady_allocs = 0u64;
    let mut backup_rescues = 0u64;
    let mut faults: Vec<FaultRecord> = Vec::new();
    // Kill steps already taken: a restarted stint that replays its kill
    // step must not die twice on the same schedule entry.
    let mut fired: Vec<u64> = Vec::new();
    let mut start_step = 0u64;
    // Job-lifetime wire tallies, shared with every stint's exchange (and
    // its comm driver) so kill/restart cycles keep accumulating into one
    // set of counters. `None` on plans without wire faults.
    let wire_counters: Option<Arc<WireCounters>> =
        conf.faults.has_wire_faults().then(|| Arc::new(WireCounters::new()));

    loop {
        let end = run_worker_stint(
            g,
            conf,
            &group_builder,
            topo,
            servers,
            data,
            log,
            job_sw,
            warmup_gate,
            ckpt,
            start_step,
            &mut clock,
            &mut inputs,
            &mut steady_allocs,
            &mut backup_rescues,
            &fired,
            &wire_counters,
        );
        let step = match end {
            StintEnd::Completed => break,
            StintEnd::Killed { step } => step,
        };
        fired.push(step);
        let before_ms = clock.ms();
        // Process respawn + scheduler placement for the replacement group.
        clock.advance(conf.faults.restart_latency_us);
        // Sole tenant of its server group → only this (now dead) group
        // advanced that state, so recovery rolls it back to the latest
        // checkpoint (re-fetching it over the param link) and replays from
        // that boundary — or cold-starts from the seed params when nothing
        // was ever checkpointed. A shared server group (downpour) keeps the
        // healthy groups' progress: the restarted group rejoins the live
        // state at its kill step.
        let sole_tenant =
            topo.nworker_groups == 1 || topo.nserver_groups >= topo.nworker_groups;
        let (resume, restored_from) = if sole_tenant {
            match ckpt.and_then(|c| c.latest_blocking()) {
                Some(snap) => {
                    let (cstep, checkpoint) = &*snap;
                    sg.restore_params(&checkpoint.tensors)
                        .expect("checkpoint/server param planes diverged");
                    clock.transfer(&link, checkpoint.byte_size());
                    (*cstep, Some(*cstep))
                }
                None => {
                    // Cold restart: re-seed the replica with the initial
                    // params (same RNG stream as run_job's registration
                    // probe) and replay the whole shard stream.
                    let probe = group_builder.clone().build(&mut Rng::new(conf.seed));
                    let mut seen = std::collections::HashSet::new();
                    for p in probe.params() {
                        let logical = logical_param_name(&p.name);
                        if seen.insert(logical.clone()) {
                            sg.put(&logical, p.data.clone(), p.lr_mult, p.wd_mult);
                        }
                    }
                    (0, None)
                }
            }
        } else {
            (step, None)
        };
        faults.push(FaultRecord {
            group: g,
            killed_at_step: step,
            resumed_at_step: resume,
            restored_from,
            recovery_virt_ms: clock.ms() - before_ms,
        });
        start_step = resume;
    }
    let wire = match wire_counters {
        Some(c) => c.snapshot(),
        None => WireEvents { degraded_steps: vec![0], ..WireEvents::default() },
    };
    GroupRun { virt_ms: clock.ms(), steady_allocs, faults, backup_rescues, wire }
}

/// One uninterrupted run of steps `[start_step, conf.iters)` on a freshly
/// built net + exchange. Every return path retires the comm driver first
/// (in-flight flushes land on the servers), so a kill arriving mid-flush
/// can never deadlock the bucket condvars or leak the driver thread — the
/// partially-flushed server state it leaves behind is exactly what a real
/// mid-exchange crash leaves, and recovery owns making sense of it.
#[allow(clippy::too_many_arguments)]
fn run_worker_stint(
    g: usize,
    conf: &JobConf,
    group_builder: &NetBuilder,
    topo: &ClusterTopology,
    servers: &Arc<Vec<ServerGroup>>,
    data: &dyn DataSource,
    log: &TrainingLog,
    job_sw: &Stopwatch,
    warmup_gate: &WarmupGate,
    ckpt: Option<&Checkpointer>,
    start_step: u64,
    clock: &mut VirtualClock,
    inputs: &mut HashMap<String, Blob>,
    steady_allocs: &mut u64,
    backup_rescues: &mut u64,
    fired: &[u64],
    wire_counters: &Option<Arc<WireCounters>>,
) -> StintEnd {
    let mut net = group_builder.clone().build(&mut Rng::new(conf.seed));
    let sg_idx = topo.server_group_of(g);
    let link = *topo.param_link(&conf.cost);
    let k = topo.nworkers_per_group.max(1);
    // Persistent parameter-plane state — routing, bucket layout, and
    // sum/fresh buffers resolved once — plus (overlap mode) the comm
    // driver thread that drains flushed buckets while backward continues.
    // The steady-state loop below performs zero Blob allocations.
    let wc = wire_counters.clone();
    let mut ex = GroupExchange::new(&net, conf, servers, sg_idx, link, k, start_step, g, wc);
    let mut alg = conf.algorithm.instantiate();
    let sg = &servers[sg_idx];
    let warmup_target = conf.warmup_iters.min(conf.iters);
    // Wire cost of one full gradient flush — what a backup worker's
    // duplicate flush charges when it outruns a straggler.
    let duplicate_flush_bytes = ex.step_flush_bytes();

    // Initial fetch: overlap mode prefetches the first forward's buckets
    // through the comm channel; sequential mode fetches inline.
    ex.prefetch(sg, clock);

    for step in start_step..conf.iters {
        // Scheduled kill: die at the top of the step, before any work.
        if conf.faults.kill_at(g, step) && !fired.contains(&step) {
            ex.shutdown();
            *steady_allocs += ex.comm_steady_allocs();
            return StintEnd::Killed { step };
        }
        let allocs_before = Blob::alloc_count();
        let batch_index = crate::data::shard_index(step, g, topo.nworker_groups);
        data.batch_into(batch_index, conf.batch_size, inputs);

        // Adopt this step's fresh parameter values bucket by bucket — each
        // bucket blocks only on its own ready epoch, not on the whole
        // exchange, and merges its transfer's virtual finish time.
        ex.consume_fresh(&mut net, step, clock);

        net.zero_grads();
        ex.begin_step(step, clock.us);
        // Overlap mode: the exchange observer flushes each gradient bucket
        // the moment its last layer's ComputeGradient finishes, while the
        // backward pass continues on the layers below.
        let stats = alg.train_one_batch_observed(&mut net, inputs, &mut ex);
        let compute_us = ex.step_elapsed_us();
        // Within-group workers split the compute ideally on the virtual
        // clock. A scheduled straggler stretches the step by the delay
        // factor — unless backup workers absorb it: the backup's copy of
        // the slow shard wins the race at the healthy per-worker time, and
        // its duplicate flush is charged to the wire and discarded
        // (sandblaster's duplicate-update discard; values are identical
        // either way, only clock/ledger accounting moves).
        let per_worker_us = compute_us / k as f64;
        let delay = conf.faults.delay_factor(g, step);
        if delay > 1.0 && conf.backup_workers > 0 {
            *backup_rescues += 1;
            sg.ledger.add_param(duplicate_flush_bytes);
            clock.advance(per_worker_us);
        } else {
            clock.advance(per_worker_us * delay);
        }
        let bridge_bytes = net.bridge_bytes();
        if bridge_bytes > 0 {
            sg.ledger.add_feature(bridge_bytes);
            clock.transfer(&conf.cost.intra_node, bridge_bytes);
        }

        // Sequential mode: the whole aggregate → update → receive exchange
        // happens here, blocking (the historical PR 4 recipe, bit for bit).
        ex.flush_sequential(&net, sg, step, clock);

        // Distributed Hogwild: neighbour server-group sync. In-flight
        // flushes must land first — averaging a half-flushed replica would
        // diverge from the sequential semantics.
        if topo.group_sync_interval > 0
            && step > 0
            && step % topo.group_sync_interval == 0
            && topo.nserver_groups > 1
        {
            let neighbour = (sg_idx + 1) % servers.len();
            if neighbour != sg_idx {
                ex.drain(step, clock);
                let bytes = sg.sync_with(&servers[neighbour]);
                clock.transfer(&conf.cost.network, bytes);
            }
        }

        if g == 0 {
            if conf.warmup_iters > 0 && step + 1 == warmup_target {
                // Groups released from warm-up must see the fully warmed
                // server state, not a half-flushed one.
                ex.drain(step, clock);
            }
            warmup_gate.advance(step + 1);
            // Checkpoint cadence: drain in-flight flushes so the snapshot
            // sees a full-step boundary, hand off to the background
            // checkpointer (one channel send), and wait only for the
            // in-memory export — serialization and the durable write stay
            // off this thread, and the export clones on the checkpointer
            // thread, so this group's Blob alloc tally stays untouched.
            if let (Some(ck), Some(cc)) = (ckpt, conf.checkpoint.as_ref()) {
                if cc.every_steps > 0 && (step + 1) % cc.every_steps == 0 {
                    ex.drain(step, clock);
                    ck.request(step + 1);
                    ck.wait_exported();
                }
            }
        }
        if let Some(from) = conf.alloc_probe_from {
            if step >= from {
                *steady_allocs += Blob::alloc_count() - allocs_before;
            }
        }
        let final_step = step + 1 == conf.iters;
        if final_step || (conf.log_every > 0 && step % conf.log_every == 0) {
            log.push(Record {
                group: g,
                step,
                wall_ms: job_sw.elapsed_ms(),
                virt_ms: clock.ms(),
                loss: stats.total_loss(),
                metric: stats.metric(),
            });
        }
    }
    // Wait out the final step's flushes (merging their virtual finish
    // times into the group clock) and retire the comm driver; its
    // post-warm-up Blob allocations count against this group's tally.
    if conf.iters > start_step {
        ex.drain(conf.iters - 1, clock);
    }
    ex.shutdown();
    *steady_allocs += ex.comm_steady_allocs();
    StintEnd::Completed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDigits;
    use crate::model::layer::{Activation, LayerConf, LayerKind};

    fn digit_mlp(batch: usize, dim: usize, classes: usize) -> NetBuilder {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
    }

    fn digits() -> Arc<dyn DataSource> {
        Arc::new(SyntheticDigits::new(64, 5, 77))
    }

    #[test]
    fn sandblaster_sync_training_converges() {
        let mut conf = JobConf::new("sync", digit_mlp(16, 64, 5));
        conf.iters = 120;
        conf.updater = UpdaterConf::sgd(0.2);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        assert_eq!(recs.len(), 120);
        let last = &recs[recs.len() - 1];
        assert!(last.metric > 0.9, "sync training accuracy {}", last.metric);
        assert!(report.ledger.param_bytes() > 0);
        assert!(!report.params.is_empty());
    }

    /// Synchronous training with K in-group workers must match the K=1
    /// trajectory exactly (paper §5.2.1: "the training convergence rate is
    /// the same as that on a single node").
    #[test]
    fn sync_partitioned_matches_single_worker_semantics() {
        let make = |workers: usize, partition: bool| {
            let mut b = digit_mlp(16, 64, 5);
            if partition {
                for c in b.confs_mut().iter_mut() {
                    if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
                        c.partition_dim = Some(0);
                    }
                }
            }
            let mut conf = JobConf::new("p", b);
            conf.iters = 30;
            conf.updater = UpdaterConf::sgd(0.2);
            conf.topology = ClusterTopology::sandblaster(workers, 1);
            conf.partition_within_group = partition;
            run_job(&conf, digits())
        };
        let single = make(1, false);
        let multi = make(2, true);
        let s = single.log.snapshot();
        let m = multi.log.snapshot();
        assert_eq!(s.len(), m.len());
        for (a, b) in s.iter().zip(&m) {
            // losses: multi logs the SUM over 2 half-batch loss layers; the
            // mean of the shards equals the full-batch loss.
            let multi_mean = b.loss / 2.0;
            assert!(
                (a.loss - multi_mean).abs() < 2e-3,
                "step {}: single {} vs multi-mean {}",
                a.step,
                a.loss,
                multi_mean
            );
        }
    }

    #[test]
    fn downpour_async_groups_all_progress() {
        let mut conf = JobConf::new("downpour", digit_mlp(8, 64, 5));
        conf.iters = 60;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(3, 1, 2);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        // all three groups logged
        for g in 0..3 {
            let grecs: Vec<_> = recs.iter().filter(|r| r.group == g).collect();
            assert_eq!(grecs.len(), 60);
        }
        // shared-model training converged
        let finals: Vec<f32> = (0..3)
            .map(|g| recs.iter().filter(|r| r.group == g).last().unwrap().metric)
            .collect();
        assert!(
            finals.iter().any(|&m| m > 0.8),
            "at least one group accurate: {finals:?}"
        );
    }

    /// L2 distance between two server replicas, summed over shared params.
    fn replica_distance(a: &HashMap<String, Blob>, b: &HashMap<String, Blob>) -> f64 {
        assert_eq!(a.len(), b.len());
        let mut dist = 0.0f64;
        for (name, va) in a {
            let vb = b.get(name).unwrap_or_else(|| panic!("replica missing {name}"));
            assert_eq!(va.shape(), vb.shape(), "{name}");
            dist += va
                .data()
                .iter()
                .zip(vb.data())
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>();
        }
        dist.sqrt()
    }

    #[test]
    fn hogwild_groups_sync_their_replicas() {
        let run = |sync_interval: u64| {
            let mut conf = JobConf::new("hogwild", digit_mlp(8, 64, 5));
            conf.iters = 50;
            conf.updater = UpdaterConf::sgd(0.1);
            conf.topology = ClusterTopology::hogwild(2, 1, sync_interval);
            run_job(&conf, digits())
        };
        let synced = run(10);
        // Both groups trained.
        let recs = synced.log.snapshot();
        assert!(recs.iter().filter(|r| r.group == 1).count() > 0);
        let last0 = recs.iter().filter(|r| r.group == 0).last().unwrap();
        assert!(last0.metric > 0.6, "hogwild group0 metric {}", last0.metric);
        // Every server group's replica is exposed; the periodically
        // averaged replicas must end closer to each other than replicas
        // that trained on the same disjoint shards WITHOUT neighbour syncs.
        assert_eq!(synced.group_params.len(), 2);
        let unsynced = run(0);
        let d_synced = replica_distance(&synced.group_params[0], &synced.group_params[1]);
        let d_unsynced =
            replica_distance(&unsynced.group_params[0], &unsynced.group_params[1]);
        assert!(
            d_synced < d_unsynced,
            "neighbour syncs must pull replicas together: synced {d_synced} vs unsynced {d_unsynced}"
        );
    }

    /// Regression: `log_every == 0` used to panic with a mod-by-zero in
    /// the logging check. It now means "log only the final step".
    #[test]
    fn log_every_zero_logs_only_final_step() {
        let mut conf = JobConf::new("quiet", digit_mlp(8, 64, 5));
        conf.iters = 7;
        conf.log_every = 0;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(2, 1, 1);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        for g in 0..2 {
            let grecs: Vec<_> = recs.iter().filter(|r| r.group == g).collect();
            assert_eq!(grecs.len(), 1, "group {g} must log exactly the final step");
            assert_eq!(grecs[0].step, 6);
        }
    }

    /// Regression: `warmup_iters >= iters` used to deadlock — group 0
    /// finished all its steps, the gate never reached `warmup_iters`, and
    /// groups 1+ spun forever. The clamped target plus the release-on-exit
    /// guard must let every group run to completion.
    #[test]
    fn warmup_exceeding_iters_terminates() {
        let mut conf = JobConf::new("over-warm", digit_mlp(8, 64, 5));
        conf.iters = 3;
        conf.warmup_iters = 10; // > iters
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(3, 1, 1);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        for g in 0..3 {
            assert_eq!(
                recs.iter().filter(|r| r.group == g).count(),
                3,
                "group {g} must complete all steps"
            );
        }
    }

    /// The distributed zero-alloc pin at the unit level: a sandblaster job
    /// with the probe armed reports zero post-warm-up Blob allocations
    /// (the full matrix of topologies lives in `bench::distributed_alloc_probe`).
    #[test]
    fn steady_state_distributed_step_is_allocation_free() {
        let mut conf = JobConf::new("alloc", digit_mlp(16, 64, 5));
        conf.iters = 8;
        conf.updater = UpdaterConf::sgd(0.2);
        conf.alloc_probe_from = Some(3);
        let report = run_job(&conf, digits());
        assert_eq!(
            report.steady_allocs,
            vec![0],
            "post-warm-up run_job steps must not allocate Blobs"
        );
    }

    /// `DataSource` serving the same batch regardless of index (so worker
    /// groups and a single-group baseline see identical data), recording
    /// the largest intra-op budget any worker thread observed while the
    /// job's group registration was active.
    struct ConstantBatch {
        inner: SyntheticDigits,
        observed_threads: std::sync::atomic::AtomicUsize,
    }

    impl ConstantBatch {
        fn new() -> ConstantBatch {
            ConstantBatch {
                inner: SyntheticDigits::new(64, 5, 77),
                observed_threads: std::sync::atomic::AtomicUsize::new(0),
            }
        }
    }

    impl crate::data::DataSource for ConstantBatch {
        fn input_names(&self) -> Vec<String> {
            self.inner.input_names()
        }

        fn batch(&self, _index: u64, batch: usize) -> HashMap<String, Blob> {
            let t = crate::runtime::threads();
            self.observed_threads.fetch_max(t, std::sync::atomic::Ordering::Relaxed);
            self.inner.batch(0, batch)
        }
    }

    /// The oversubscription pin: a 2-worker-group job must (a) observe a
    /// divided intra-op budget inside its worker threads — at most
    /// cores/groups when `PALLAS_NUM_THREADS` is unset, exactly the
    /// explicit value when it is set — and (b) train bit-identically to
    /// the 1-group baseline: with per-group server groups, no group sync,
    /// and index-independent data, each group's trajectory is the
    /// baseline's, and no thread-budget change may perturb a single bit.
    #[test]
    fn two_worker_groups_divide_budget_and_match_single_group_bitwise() {
        let run_with = |topology: ClusterTopology| {
            let src = Arc::new(ConstantBatch::new());
            let mut conf = JobConf::new("budget", digit_mlp(16, 64, 5));
            conf.iters = 12;
            conf.updater = UpdaterConf::sgd(0.2);
            conf.topology = topology;
            let data: Arc<dyn DataSource> = src.clone();
            let report = run_job(&conf, data);
            let observed =
                src.observed_threads.load(std::sync::atomic::Ordering::Relaxed);
            (report, observed)
        };
        let (base, _) = run_with(ClusterTopology::sandblaster(1, 1));
        // hogwild(2, 1, 0): two async groups, each with its OWN server
        // group and no neighbour sync → fully independent replicas.
        let (multi, observed) = run_with(ClusterTopology::hogwild(2, 1, 0));

        // (a) Budget: explicit env wins untouched; unset divides by >= 2
        // groups (other tests may register more concurrently, which only
        // shrinks the budget further — the bound stays valid).
        assert!(observed >= 1, "worker threads must observe a budget");
        match std::env::var("PALLAS_NUM_THREADS") {
            Ok(v) => assert_eq!(
                observed,
                crate::runtime::threads_from(Some(&v)),
                "explicit PALLAS_NUM_THREADS must not be divided by groups"
            ),
            Err(_) => assert!(
                observed <= (crate::runtime::cores() / 2).max(1),
                "2 groups must observe <= cores/2 threads, saw {observed}"
            ),
        }

        // (b) Bit-identical trajectories: every group's logged loss/metric
        // sequence equals the single-group baseline's, bit for bit.
        let brecs = base.log.snapshot();
        let mrecs = multi.log.snapshot();
        for g in 0..2usize {
            let grecs: Vec<_> = mrecs.iter().filter(|r| r.group == g).collect();
            assert_eq!(grecs.len(), brecs.len(), "group {g} record count");
            for (b, m) in brecs.iter().zip(&grecs) {
                assert_eq!(b.step, m.step);
                assert_eq!(
                    b.loss.to_bits(),
                    m.loss.to_bits(),
                    "group {g} step {}: loss {} vs {}",
                    b.step,
                    b.loss,
                    m.loss
                );
                assert_eq!(
                    b.metric.to_bits(),
                    m.metric.to_bits(),
                    "group {g} step {}: metric diverged",
                    b.step
                );
            }
        }
        // Final parameters (from server group 0) match bitwise too.
        assert_eq!(base.params.len(), multi.params.len());
        for (name, bp) in &base.params {
            let mp = multi.params.get(name).unwrap_or_else(|| panic!("missing param {name}"));
            assert_eq!(bp.shape(), mp.shape(), "{name}");
            for (x, y) in bp.data().iter().zip(mp.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged");
            }
        }
    }

    #[test]
    fn virtual_clock_monotone_and_positive() {
        let mut conf = JobConf::new("clock", digit_mlp(8, 64, 5));
        conf.iters = 5;
        let report = run_job(&conf, digits());
        assert_eq!(report.group_virt_ms.len(), 1);
        assert!(report.group_virt_ms[0] > 0.0);
        let recs = report.log.snapshot();
        for w in recs.windows(2) {
            assert!(w[1].virt_ms >= w[0].virt_ms);
        }
    }
}
