//! The job coordinator (paper §3, §5): given a job configuration —
//! NeuralNet + TrainOneBatch + Updater + ClusterTopology — it materializes
//! server groups, spawns one thread per worker group, shards the data
//! stream, moves parameters between workers and servers, and collects
//! metrics on both wall and virtual clocks.
//!
//! Worker groups run asynchronously (real threads, real interleaving);
//! workers *within* a group run synchronously over a partitioned net. On
//! this single-core testbed the intra-group parallel speedup is modeled on
//! the virtual clock (ideal compute split + measured comm charges via the
//! [`CostModel`]) while training semantics are exact — see DESIGN.md
//! §Hardware-Adaptation.

pub mod copyqueue;

use crate::cluster::ClusterTopology;
use crate::comm::{ByteLedger, CostModel, VirtualClock};
use crate::data::DataSource;
use crate::metrics::{Record, TrainingLog};
use crate::model::partition::{logical_param_name, partition_net};
use crate::model::{NetBuilder, NeuralNet};
use crate::server::ServerGroup;
use crate::train::{bp::Bp, cd::Cd, TrainOneBatch};
use crate::tensor::Blob;
use crate::updater::UpdaterConf;
use crate::utils::rng::Rng;
use crate::utils::timer::Stopwatch;
use std::collections::HashMap;
use std::sync::Arc;

/// Which `TrainOneBatch` algorithm the job uses (paper §4.1.3).
#[derive(Debug, Clone, PartialEq)]
pub enum Algorithm {
    Bp,
    Cd { k: usize, stage: Option<String> },
}

impl Algorithm {
    fn instantiate(&self) -> Box<dyn TrainOneBatch> {
        match self {
            Algorithm::Bp => Box::new(Bp::new()),
            Algorithm::Cd { k, stage } => Box::new(match stage {
                Some(s) => Cd::stage(*k, s),
                None => Cd::new(*k),
            }),
        }
    }
}

/// Full job configuration (the four components of paper §3).
#[derive(Clone)]
pub struct JobConf {
    pub name: String,
    pub net: NetBuilder,
    pub algorithm: Algorithm,
    pub updater: UpdaterConf,
    pub topology: ClusterTopology,
    /// Mini-batch per worker group.
    pub batch_size: usize,
    pub iters: u64,
    pub seed: u64,
    /// Partition the net across the group's workers (dim hints must be set
    /// on the layer confs). When false, group workers only model throughput.
    pub partition_within_group: bool,
    /// Cost model for the simulated deployment's virtual clock.
    pub cost: CostModel,
    /// Log every n-th iteration.
    pub log_every: u64,
    /// Warm-up: group 0 trains alone for this many iterations before the
    /// other groups start (paper §6.2.3: "a warm-up stage, which trains the
    /// model using a single worker group at the beginning, may help to
    /// stabilize the training as reported in Google's DistBelief").
    pub warmup_iters: u64,
}

impl JobConf {
    pub fn new(name: &str, net: NetBuilder) -> JobConf {
        JobConf {
            name: name.to_string(),
            net,
            algorithm: Algorithm::Bp,
            updater: UpdaterConf::sgd(0.1),
            topology: ClusterTopology::sandblaster(1, 1),
            batch_size: 16,
            iters: 100,
            seed: 0x51464a,
            partition_within_group: false,
            cost: CostModel::numa_server(),
            log_every: 1,
            warmup_iters: 0,
        }
    }
}

/// Result of a job run.
pub struct JobReport {
    pub log: Arc<TrainingLog>,
    pub ledger: Arc<ByteLedger>,
    pub wall_ms: f64,
    /// Final virtual clock per worker group (ms).
    pub group_virt_ms: Vec<f64>,
    /// Trained parameters by logical name (from server group 0).
    pub params: HashMap<String, Blob>,
}

/// Run a training job to completion.
pub fn run_job(conf: &JobConf, data: Arc<dyn DataSource>) -> JobReport {
    let topo = &conf.topology;
    let ledger = Arc::new(ByteLedger::new());

    // Register this job's worker groups for intra-op thread budgeting
    // BEFORE any group thread starts computing: while these guards live,
    // the default (env-unset) `runtime::threads()` budget is divided by the
    // active group count, so W groups × intra-op tasks never oversubscribe
    // the machine. Budget changes never change results (the parallel
    // kernels are bit-identical at every thread count).
    let _intra_op_budget: Vec<crate::runtime::WorkerGroupGuard> =
        (0..topo.nworker_groups).map(|_| crate::runtime::register_worker_group()).collect();

    // Build the (possibly partitioned) group-level net once to register
    // parameters, then per-group replicas in their threads.
    let (group_builder, _plan) = if conf.partition_within_group && topo.nworkers_per_group > 1 {
        partition_net(&conf.net, topo.nworkers_per_group)
    } else {
        (conf.net.clone(), Default::default())
    };

    // Server groups.
    let servers: Arc<Vec<ServerGroup>> = Arc::new(
        (0..topo.nserver_groups)
            .map(|_| ServerGroup::new(topo.nservers_per_group, conf.updater.clone(), ledger.clone()))
            .collect(),
    );

    // Register logical params (one probe net; same seed as the replicas so
    // initial values match everywhere).
    {
        let probe = group_builder.clone().build(&mut Rng::new(conf.seed));
        let mut seen = std::collections::HashSet::new();
        for p in probe.params() {
            let logical = logical_param_name(&p.name);
            if seen.insert(logical.clone()) {
                for sg in servers.iter() {
                    sg.put(&logical, p.data.clone(), p.lr_mult, p.wd_mult);
                }
            }
        }
    }

    let log = Arc::new(TrainingLog::new());
    let job_sw = Stopwatch::new();
    // Warm-up gate: group 0 stores its step count here; others wait for it
    // to pass `warmup_iters` before starting.
    let warmup_gate = Arc::new(std::sync::atomic::AtomicU64::new(0));

    let mut handles = Vec::new();
    for g in 0..topo.nworker_groups {
        let conf = conf.clone();
        let group_builder = group_builder.clone();
        let servers = servers.clone();
        let data = data.clone();
        let log = log.clone();
        let topo = topo.clone();
        let job_sw = job_sw.clone();
        let warmup_gate = warmup_gate.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("wg{g}"))
                .spawn(move || {
                    if g > 0 && conf.warmup_iters > 0 {
                        while warmup_gate.load(std::sync::atomic::Ordering::Acquire)
                            < conf.warmup_iters
                        {
                            std::thread::yield_now();
                        }
                    }
                    worker_group_loop(
                        g, &conf, group_builder, &topo, &servers, &*data, &log, &job_sw,
                        &warmup_gate,
                    )
                })
                .expect("spawn worker group"),
        );
    }
    let group_virt_ms: Vec<f64> = handles.into_iter().map(|h| h.join().expect("worker group panicked")).collect();

    // Collect final params from server group 0.
    let mut params = HashMap::new();
    for name in servers[0].param_names() {
        let (v, _) = servers[0].get(&name);
        params.insert(name, v);
    }

    JobReport { log, ledger, wall_ms: job_sw.elapsed_ms(), group_virt_ms, params }
}

/// Body of one worker-group thread. Returns the group's final virtual
/// clock in ms.
#[allow(clippy::too_many_arguments)]
fn worker_group_loop(
    g: usize,
    conf: &JobConf,
    group_builder: NetBuilder,
    topo: &ClusterTopology,
    servers: &[ServerGroup],
    data: &dyn DataSource,
    log: &TrainingLog,
    job_sw: &Stopwatch,
    warmup_gate: &std::sync::atomic::AtomicU64,
) -> f64 {
    let mut net = group_builder.build(&mut Rng::new(conf.seed));
    let mut alg = conf.algorithm.instantiate();
    let sg = &servers[topo.server_group_of(g)];
    let mut clock = VirtualClock::new();
    let k = topo.nworkers_per_group.max(1);

    // Initial fetch: all replicas start from the server values.
    fetch_params(&mut net, sg, &mut clock, conf, topo);

    for step in 0..conf.iters {
        let batch_index = crate::data::shard_index(step, g, topo.nworker_groups);
        let inputs = data.batch(batch_index, conf.batch_size);

        net.zero_grads();
        let sw = Stopwatch::new();
        let stats = alg.train_one_batch(&mut net, &inputs);
        let compute_us = sw.elapsed_us();
        // Within-group workers split the compute ideally on the virtual
        // clock; bridge traffic is charged on the feature plane.
        clock.advance(compute_us / k as f64);
        let bridge_bytes = net.bridge_bytes();
        if bridge_bytes > 0 {
            sg.ledger.add_feature(bridge_bytes);
            clock.transfer(&conf.cost.intra_node, bridge_bytes);
        }

        // Aggregate gradients by logical name (the group stub's aggregation)
        // and push to the server group.
        let mut agg: HashMap<String, (Blob, usize, f32, f32)> = HashMap::new();
        for p in net.params_mut() {
            let logical = logical_param_name(&p.name);
            match agg.get_mut(&logical) {
                Some((sum, count, _, _)) => {
                    sum.add_assign(&p.grad);
                    *count += 1;
                }
                None => {
                    agg.insert(logical, (p.grad.clone(), 1, p.lr_mult, p.wd_mult));
                }
            }
        }
        let mut fresh: HashMap<String, Blob> = HashMap::new();
        let mut param_bytes = 0usize;
        for (logical, (mut sum, count, _, _)) in agg {
            sum.scale(1.0 / count as f32);
            param_bytes += 2 * sum.byte_size() + 128;
            let (value, _version) = sg.update(&logical, &sum, step);
            fresh.insert(logical, value);
        }
        // Parameter traffic crosses the network when servers are remote
        // (multi-server-group / cluster topologies), else shared memory.
        let link = if topo.nserver_groups > 1 || topo.nservers_per_group > 1 {
            conf.cost.network
        } else {
            conf.cost.intra_node
        };
        clock.transfer(&link, param_bytes);

        // Write fresh values back into all local replicas.
        for p in net.params_mut() {
            let logical = logical_param_name(&p.name);
            if let Some(v) = fresh.get(&logical) {
                p.data = v.clone();
                p.version += 1;
            }
        }

        // Distributed Hogwild: neighbour server-group sync.
        if topo.group_sync_interval > 0
            && step > 0
            && step % topo.group_sync_interval == 0
            && topo.nserver_groups > 1
        {
            let neighbour = (topo.server_group_of(g) + 1) % servers.len();
            if neighbour != topo.server_group_of(g) {
                let bytes = sg.sync_with(&servers[neighbour]);
                clock.transfer(&conf.cost.network, bytes);
            }
        }

        if g == 0 {
            warmup_gate.store(step + 1, std::sync::atomic::Ordering::Release);
        }
        if step % conf.log_every == 0 || step + 1 == conf.iters {
            log.push(Record {
                group: g,
                step,
                wall_ms: job_sw.elapsed_ms(),
                virt_ms: clock.ms(),
                loss: stats.total_loss(),
                metric: stats.metric(),
            });
        }
    }
    clock.ms()
}

/// Pull every logical parameter from the server group into the local net.
fn fetch_params(
    net: &mut NeuralNet,
    sg: &ServerGroup,
    clock: &mut VirtualClock,
    conf: &JobConf,
    topo: &ClusterTopology,
) {
    let mut bytes = 0usize;
    let mut cache: HashMap<String, Blob> = HashMap::new();
    for p in net.params_mut() {
        let logical = logical_param_name(&p.name);
        let v = cache.entry(logical.clone()).or_insert_with(|| {
            let (v, _) = sg.get(&logical);
            v
        });
        assert_eq!(
            v.shape(),
            p.data.shape(),
            "server/local shape mismatch for {} (logical {})",
            p.name,
            logical
        );
        bytes += v.byte_size();
        p.data = v.clone();
    }
    let link = if topo.nserver_groups > 1 || topo.nservers_per_group > 1 {
        conf.cost.network
    } else {
        conf.cost.intra_node
    };
    clock.transfer(&link, bytes);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDigits;
    use crate::model::layer::{Activation, LayerConf, LayerKind};

    fn digit_mlp(batch: usize, dim: usize, classes: usize) -> NetBuilder {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, dim] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 32, act: Activation::Relu, init_std: 0.1 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.1 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
    }

    fn digits() -> Arc<dyn DataSource> {
        Arc::new(SyntheticDigits::new(64, 5, 77))
    }

    #[test]
    fn sandblaster_sync_training_converges() {
        let mut conf = JobConf::new("sync", digit_mlp(16, 64, 5));
        conf.iters = 120;
        conf.updater = UpdaterConf::sgd(0.2);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        assert_eq!(recs.len(), 120);
        let last = &recs[recs.len() - 1];
        assert!(last.metric > 0.9, "sync training accuracy {}", last.metric);
        assert!(report.ledger.param_bytes() > 0);
        assert!(!report.params.is_empty());
    }

    /// Synchronous training with K in-group workers must match the K=1
    /// trajectory exactly (paper §5.2.1: "the training convergence rate is
    /// the same as that on a single node").
    #[test]
    fn sync_partitioned_matches_single_worker_semantics() {
        let make = |workers: usize, partition: bool| {
            let mut b = digit_mlp(16, 64, 5);
            if partition {
                for c in b.confs_mut().iter_mut() {
                    if ["h1", "logits", "loss"].contains(&c.name.as_str()) {
                        c.partition_dim = Some(0);
                    }
                }
            }
            let mut conf = JobConf::new("p", b);
            conf.iters = 30;
            conf.updater = UpdaterConf::sgd(0.2);
            conf.topology = ClusterTopology::sandblaster(workers, 1);
            conf.partition_within_group = partition;
            run_job(&conf, digits())
        };
        let single = make(1, false);
        let multi = make(2, true);
        let s = single.log.snapshot();
        let m = multi.log.snapshot();
        assert_eq!(s.len(), m.len());
        for (a, b) in s.iter().zip(&m) {
            // losses: multi logs the SUM over 2 half-batch loss layers; the
            // mean of the shards equals the full-batch loss.
            let multi_mean = b.loss / 2.0;
            assert!(
                (a.loss - multi_mean).abs() < 2e-3,
                "step {}: single {} vs multi-mean {}",
                a.step,
                a.loss,
                multi_mean
            );
        }
    }

    #[test]
    fn downpour_async_groups_all_progress() {
        let mut conf = JobConf::new("downpour", digit_mlp(8, 64, 5));
        conf.iters = 60;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::downpour(3, 1, 2);
        let report = run_job(&conf, digits());
        let recs = report.log.snapshot();
        // all three groups logged
        for g in 0..3 {
            let grecs: Vec<_> = recs.iter().filter(|r| r.group == g).collect();
            assert_eq!(grecs.len(), 60);
        }
        // shared-model training converged
        let finals: Vec<f32> = (0..3)
            .map(|g| recs.iter().filter(|r| r.group == g).last().unwrap().metric)
            .collect();
        assert!(
            finals.iter().any(|&m| m > 0.8),
            "at least one group accurate: {finals:?}"
        );
    }

    #[test]
    fn hogwild_groups_sync_their_replicas() {
        let mut conf = JobConf::new("hogwild", digit_mlp(8, 64, 5));
        conf.iters = 50;
        conf.updater = UpdaterConf::sgd(0.1);
        conf.topology = ClusterTopology::hogwild(2, 1, 10);
        let report = run_job(&conf, digits());
        // Both server groups ended near each other after periodic syncs:
        // compare weights from group 0's report against... (group 1 values
        // live in servers[1], not exposed; instead assert both groups
        // trained and the sync path was exercised via feature of progress).
        let recs = report.log.snapshot();
        assert!(recs.iter().filter(|r| r.group == 1).count() > 0);
        let last0 = recs.iter().filter(|r| r.group == 0).last().unwrap();
        assert!(last0.metric > 0.6, "hogwild group0 metric {}", last0.metric);
    }

    /// `DataSource` serving the same batch regardless of index (so worker
    /// groups and a single-group baseline see identical data), recording
    /// the largest intra-op budget any worker thread observed while the
    /// job's group registration was active.
    struct ConstantBatch {
        inner: SyntheticDigits,
        observed_threads: std::sync::atomic::AtomicUsize,
    }

    impl ConstantBatch {
        fn new() -> ConstantBatch {
            ConstantBatch {
                inner: SyntheticDigits::new(64, 5, 77),
                observed_threads: std::sync::atomic::AtomicUsize::new(0),
            }
        }
    }

    impl crate::data::DataSource for ConstantBatch {
        fn input_names(&self) -> Vec<String> {
            self.inner.input_names()
        }

        fn batch(&self, _index: u64, batch: usize) -> HashMap<String, Blob> {
            let t = crate::runtime::threads();
            self.observed_threads.fetch_max(t, std::sync::atomic::Ordering::Relaxed);
            self.inner.batch(0, batch)
        }
    }

    /// The oversubscription pin: a 2-worker-group job must (a) observe a
    /// divided intra-op budget inside its worker threads — at most
    /// cores/groups when `PALLAS_NUM_THREADS` is unset, exactly the
    /// explicit value when it is set — and (b) train bit-identically to
    /// the 1-group baseline: with per-group server groups, no group sync,
    /// and index-independent data, each group's trajectory is the
    /// baseline's, and no thread-budget change may perturb a single bit.
    #[test]
    fn two_worker_groups_divide_budget_and_match_single_group_bitwise() {
        let run_with = |topology: ClusterTopology| {
            let src = Arc::new(ConstantBatch::new());
            let mut conf = JobConf::new("budget", digit_mlp(16, 64, 5));
            conf.iters = 12;
            conf.updater = UpdaterConf::sgd(0.2);
            conf.topology = topology;
            let data: Arc<dyn DataSource> = src.clone();
            let report = run_job(&conf, data);
            let observed =
                src.observed_threads.load(std::sync::atomic::Ordering::Relaxed);
            (report, observed)
        };
        let (base, _) = run_with(ClusterTopology::sandblaster(1, 1));
        // hogwild(2, 1, 0): two async groups, each with its OWN server
        // group and no neighbour sync → fully independent replicas.
        let (multi, observed) = run_with(ClusterTopology::hogwild(2, 1, 0));

        // (a) Budget: explicit env wins untouched; unset divides by >= 2
        // groups (other tests may register more concurrently, which only
        // shrinks the budget further — the bound stays valid).
        assert!(observed >= 1, "worker threads must observe a budget");
        match std::env::var("PALLAS_NUM_THREADS") {
            Ok(v) => assert_eq!(
                observed,
                crate::runtime::threads_from(Some(&v)),
                "explicit PALLAS_NUM_THREADS must not be divided by groups"
            ),
            Err(_) => assert!(
                observed <= (crate::runtime::cores() / 2).max(1),
                "2 groups must observe <= cores/2 threads, saw {observed}"
            ),
        }

        // (b) Bit-identical trajectories: every group's logged loss/metric
        // sequence equals the single-group baseline's, bit for bit.
        let brecs = base.log.snapshot();
        let mrecs = multi.log.snapshot();
        for g in 0..2usize {
            let grecs: Vec<_> = mrecs.iter().filter(|r| r.group == g).collect();
            assert_eq!(grecs.len(), brecs.len(), "group {g} record count");
            for (b, m) in brecs.iter().zip(&grecs) {
                assert_eq!(b.step, m.step);
                assert_eq!(
                    b.loss.to_bits(),
                    m.loss.to_bits(),
                    "group {g} step {}: loss {} vs {}",
                    b.step,
                    b.loss,
                    m.loss
                );
                assert_eq!(
                    b.metric.to_bits(),
                    m.metric.to_bits(),
                    "group {g} step {}: metric diverged",
                    b.step
                );
            }
        }
        // Final parameters (from server group 0) match bitwise too.
        assert_eq!(base.params.len(), multi.params.len());
        for (name, bp) in &base.params {
            let mp = multi.params.get(name).unwrap_or_else(|| panic!("missing param {name}"));
            assert_eq!(bp.shape(), mp.shape(), "{name}");
            for (x, y) in bp.data().iter().zip(mp.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged");
            }
        }
    }

    #[test]
    fn virtual_clock_monotone_and_positive() {
        let mut conf = JobConf::new("clock", digit_mlp(8, 64, 5));
        conf.iters = 5;
        let report = run_job(&conf, digits());
        assert_eq!(report.group_virt_ms.len(), 1);
        assert!(report.group_virt_ms[0] > 0.0);
        let recs = report.log.snapshot();
        for w in recs.windows(2) {
            assert!(w[1].virt_ms >= w[0].virt_ms);
        }
    }
}
