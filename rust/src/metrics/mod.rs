//! Training metrics: per-iteration records collected from all worker
//! groups, with both wall-clock and virtual-clock timestamps (the latter
//! models the simulated deployment — see [`crate::comm::simnet`]).

use crate::runtime::sync::{OrderedMutex, RANK_METRICS_LOG};

/// One logged training step.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub group: usize,
    pub step: u64,
    /// Wall-clock milliseconds since job start.
    pub wall_ms: f64,
    /// Virtual milliseconds on the group's simulated clock.
    pub virt_ms: f64,
    pub loss: f32,
    pub metric: f32,
}

/// Thread-safe append-only training log.
#[derive(Debug)]
pub struct TrainingLog {
    records: OrderedMutex<Vec<Record>>,
}

impl Default for TrainingLog {
    fn default() -> TrainingLog {
        TrainingLog::new()
    }
}

impl TrainingLog {
    pub fn new() -> TrainingLog {
        TrainingLog { records: OrderedMutex::new(RANK_METRICS_LOG, "metrics.log", Vec::new()) }
    }

    pub fn push(&self, r: Record) {
        self.records.lock().unwrap().push(r);
    }

    pub fn snapshot(&self) -> Vec<Record> {
        self.records.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Final loss averaged across groups (mean of each group's last record).
    pub fn final_loss(&self) -> f32 {
        let recs = self.snapshot();
        let mut last: std::collections::HashMap<usize, &Record> = Default::default();
        for r in &recs {
            let e = last.entry(r.group).or_insert(r);
            if r.step >= e.step {
                *e = r;
            }
        }
        if last.is_empty() {
            return 0.0;
        }
        last.values().map(|r| r.loss).sum::<f32>() / last.len() as f32
    }

    /// Earliest virtual time (ms) at which any group's running-average
    /// metric reached `target` (the paper's "time to accuracy" measure,
    /// Fig 19); `None` if never reached.
    pub fn time_to_metric(&self, target: f32, window: usize) -> Option<f64> {
        let mut recs = self.snapshot();
        recs.sort_by(|a, b| a.virt_ms.partial_cmp(&b.virt_ms).unwrap());
        let mut hist: Vec<f32> = Vec::new();
        for r in &recs {
            hist.push(r.metric);
            let n = hist.len().min(window);
            let avg: f32 = hist[hist.len() - n..].iter().sum::<f32>() / n as f32;
            if avg >= target {
                return Some(r.virt_ms);
            }
        }
        None
    }

    /// Dump as TSV (step, group, wall_ms, virt_ms, loss, metric).
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("step\tgroup\twall_ms\tvirt_ms\tloss\tmetric\n");
        for r in self.snapshot() {
            out.push_str(&format!(
                "{}\t{}\t{:.3}\t{:.3}\t{:.5}\t{:.4}\n",
                r.step, r.group, r.wall_ms, r.virt_ms, r.loss, r.metric
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(group: usize, step: u64, virt_ms: f64, loss: f32, metric: f32) -> Record {
        Record { group, step, wall_ms: virt_ms, virt_ms, loss, metric }
    }

    #[test]
    fn final_loss_per_group() {
        let log = TrainingLog::new();
        log.push(rec(0, 0, 1.0, 2.0, 0.1));
        log.push(rec(0, 1, 2.0, 1.0, 0.2));
        log.push(rec(1, 0, 1.5, 3.0, 0.1));
        assert_eq!(log.final_loss(), 2.0); // mean of 1.0 and 3.0
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn time_to_metric_finds_first_crossing() {
        let log = TrainingLog::new();
        log.push(rec(0, 0, 10.0, 1.0, 0.2));
        log.push(rec(0, 1, 20.0, 0.8, 0.6));
        log.push(rec(0, 2, 30.0, 0.5, 0.9));
        assert_eq!(log.time_to_metric(0.55, 1), Some(20.0));
        assert_eq!(log.time_to_metric(0.95, 1), None);
    }

    #[test]
    fn tsv_roundtrip_lines() {
        let log = TrainingLog::new();
        log.push(rec(0, 0, 1.0, 0.5, 0.25));
        let tsv = log.to_tsv();
        assert!(tsv.starts_with("step\t"));
        assert_eq!(tsv.lines().count(), 2);
    }
}
