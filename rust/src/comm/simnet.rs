//! Simulated-network cost model and byte accounting.
//!
//! The paper evaluates on (a) a 24-core NUMA node, (b) a 32-node cluster on
//! 1 Gbps ethernet, and (c) a 3-GPU workstation on PCIe. None of those are
//! available here, so cluster/GPU experiments charge communication to a
//! latency+bandwidth link model and advance a per-entity virtual clock;
//! compute time is measured for real and fed into the same clock. Figure
//! *shapes* then follow from the compute/communication ratio exactly as in
//! the paper's analysis (§5.4.1).

use crate::comm::faults::WireFault;
use std::sync::atomic::{AtomicU64, Ordering};

/// A point-to-point link: `time(bytes) = latency + bytes / bandwidth`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    pub latency_us: f64,
    /// Bandwidth in gigabits per second.
    pub gbps: f64,
}

impl LinkModel {
    /// A validated link: `latency_us` finite and >= 0, `gbps` finite and
    /// > 0. A NaN latency or a zero bandwidth would poison every virtual
    /// -clock figure downstream (`transfer_us` would return NaN/inf and
    /// `merge_us`/`barrier` would propagate it), so reject loudly here.
    pub fn new(latency_us: f64, gbps: f64) -> LinkModel {
        assert!(
            latency_us.is_finite() && latency_us >= 0.0,
            "link latency must be finite and >= 0 µs; got {latency_us}"
        );
        assert!(
            gbps.is_finite() && gbps > 0.0,
            "link bandwidth must be finite and > 0 Gbps (zero would make every \
             transfer take infinite virtual time); got {gbps}"
        );
        LinkModel { latency_us, gbps }
    }

    /// 1 Gbps datacenter ethernet (paper's cluster switch), ~50 µs RTT/2.
    pub fn ethernet_1g() -> LinkModel {
        LinkModel::new(50.0, 1.0)
    }

    /// PCIe 3.0 x16 host↔device (paper's GPU workstation): ~8 µs, ~12 GB/s
    /// effective ≈ 96 Gbps.
    pub fn pcie3() -> LinkModel {
        LinkModel::new(8.0, 96.0)
    }

    /// Same-socket shared memory: near-zero latency, memcpy-bound.
    pub fn shared_memory() -> LinkModel {
        LinkModel::new(0.5, 400.0)
    }

    /// Cross-NUMA-socket memory path (the >8-thread degradation in the
    /// paper's Fig 18a is attributed to cross-CPU memory access).
    pub fn cross_numa() -> LinkModel {
        LinkModel::new(1.5, 80.0)
    }

    /// 10 Gbps rack LAN (a modern top-of-rack switch), ~20 µs one-way.
    pub fn ethernet_10g() -> LinkModel {
        LinkModel::new(20.0, 10.0)
    }

    /// Transfer time in microseconds.
    pub fn transfer_us(&self, bytes: usize) -> f64 {
        self.latency_us + (bytes as f64 * 8.0) / (self.gbps * 1e3)
    }
}

/// Which links connect the tiers of the simulated deployment.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Worker ↔ worker / worker ↔ server on the same node.
    pub intra_node: LinkModel,
    /// Host ↔ accelerator device.
    pub host_device: LinkModel,
    /// Node ↔ node across the cluster network.
    pub network: LinkModel,
}

impl CostModel {
    /// The paper's cluster testbed (quad-core nodes, 1 Gbps switch).
    pub fn cluster() -> CostModel {
        CostModel {
            intra_node: LinkModel::shared_memory(),
            host_device: LinkModel::pcie3(),
            network: LinkModel::ethernet_1g(),
        }
    }

    /// The paper's single-node GPU workstation (3× GTX 970 on PCIe).
    pub fn gpu_workstation() -> CostModel {
        CostModel {
            intra_node: LinkModel::shared_memory(),
            host_device: LinkModel::pcie3(),
            network: LinkModel::pcie3(), // device↔device via host
        }
    }

    /// A rack-local deployment on a 10 Gbps LAN — between `cluster` (1 Gbps
    /// ethernet) and `numa_server` (cross-NUMA memory) in link quality.
    pub fn lan() -> CostModel {
        CostModel {
            intra_node: LinkModel::shared_memory(),
            host_device: LinkModel::pcie3(),
            network: LinkModel::ethernet_10g(),
        }
    }

    /// The paper's 24-core NUMA server.
    pub fn numa_server() -> CostModel {
        CostModel {
            intra_node: LinkModel::shared_memory(),
            host_device: LinkModel::shared_memory(),
            network: LinkModel::cross_numa(),
        }
    }
}

/// Thread-safe byte counters, split by plane (parameter traffic vs layer
/// feature/gradient traffic — the two overheads of §5.4.1).
#[derive(Debug, Default)]
pub struct ByteLedger {
    param_bytes: AtomicU64,
    feature_bytes: AtomicU64,
    messages: AtomicU64,
}

impl ByteLedger {
    pub fn new() -> ByteLedger {
        ByteLedger::default()
    }

    pub fn add_param(&self, bytes: usize) {
        self.param_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_feature(&self, bytes: usize) {
        self.feature_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_bytes.load(Ordering::Relaxed)
    }

    pub fn feature_bytes(&self) -> u64 {
        self.feature_bytes.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        self.param_bytes() + self.feature_bytes()
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.param_bytes.store(0, Ordering::Relaxed);
        self.feature_bytes.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

/// Per-entity virtual clock (microseconds). Workers/servers advance their
/// own clocks; synchronization points merge them with `max`.
#[derive(Debug, Clone, Copy, Default, PartialEq, PartialOrd)]
pub struct VirtualClock {
    pub us: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { us: 0.0 }
    }

    pub fn advance(&mut self, us: f64) {
        debug_assert!(us >= 0.0);
        self.us += us;
    }

    /// Charge a transfer on `link`.
    pub fn transfer(&mut self, link: &LinkModel, bytes: usize) {
        self.us += link.transfer_us(bytes);
    }

    /// Synchronization barrier: everyone waits for the slowest.
    pub fn barrier(clocks: &mut [VirtualClock]) {
        let max = clocks.iter().map(|c| c.us).fold(0.0, f64::max);
        for c in clocks {
            c.us = max;
        }
    }

    /// Merge an event that completed at absolute virtual time `us` — an
    /// overlapped transfer the owner must wait for. The clock only moves
    /// forward: events finishing in the past cost nothing, which is how
    /// overlapped step time becomes `max(compute, comm)` instead of
    /// `compute + comm`.
    pub fn merge_us(&mut self, us: f64) {
        if us > self.us {
            self.us = us;
        }
    }

    pub fn ms(&self) -> f64 {
        self.us / 1e3
    }
}

/// Serialized transfer timeline of one point-to-point link — the overlapped
/// exchange's comm channel. Each transfer is charged at the absolute
/// virtual time it was *flushed* (handed to the channel); transfers queue
/// behind one another on the single link and report their finish time. The
/// owning worker's clock then [`VirtualClock::merge_us`]es the finish times
/// it has to wait for, so communication hidden behind remaining compute is
/// free and only the exposed tail extends the step (paper §5's overlap of
/// parameter exchange with the backward pass).
#[derive(Debug, Clone, Copy, Default)]
pub struct LinkTimeline {
    free_us: f64,
}

impl LinkTimeline {
    pub fn new() -> LinkTimeline {
        LinkTimeline { free_us: 0.0 }
    }

    /// Charge a `bytes` transfer flushed at absolute virtual `flush_us`;
    /// returns the absolute finish time. Transfers serialize: one starts at
    /// `max(flush time, link free)`.
    pub fn flush(&mut self, link: &LinkModel, flush_us: f64, bytes: usize) -> f64 {
        let start = if self.free_us > flush_us { self.free_us } else { flush_us };
        self.free_us = start + link.transfer_us(bytes);
        self.free_us
    }

    /// Absolute virtual time at which the link next becomes idle.
    pub fn free_us(&self) -> f64 {
        self.free_us
    }

    /// The delivery model: charge one flush *attempt* and report its fate.
    /// The wire time is burned whether or not the payload survives — a lost
    /// or corrupt transfer occupied the link exactly as long as a clean one
    /// (honest accounting of wasted bytes); a `Duplicate` serializes a
    /// second back-to-back copy and finishes when the extra copy lands. The
    /// outcome is decided upstream by `FaultPlan::wire_fault`, so chaos
    /// scenarios replay bit-for-bit.
    pub fn deliver(
        &mut self,
        link: &LinkModel,
        flush_us: f64,
        bytes: usize,
        fault: Option<WireFault>,
    ) -> (Delivery, f64) {
        let finish = self.flush(link, flush_us, bytes);
        match fault {
            Some(WireFault::Drop) => (Delivery::Lost, finish),
            Some(WireFault::Corrupt) => (Delivery::Corrupted, finish),
            Some(WireFault::Duplicate) => (Delivery::Ok, self.flush(link, finish, bytes)),
            Some(WireFault::Reorder) | None => (Delivery::Ok, finish),
        }
    }
}

/// Fate of one transfer attempt through [`LinkTimeline::deliver`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// The frame arrived intact (possibly alongside discarded extra or
    /// stale copies — those are counted by the protocol layer).
    Ok,
    /// The frame vanished in flight; the sender's deadline will fire.
    Lost,
    /// The frame arrived bit-damaged; the receiver's CRC32 check rejects
    /// it, which the sender observes as a deadline miss.
    Corrupted,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_costs() {
        let eth = LinkModel::ethernet_1g();
        // 1 MB over 1 Gbps = 8e6 bits / 1e9 bps = 8 ms + 50us
        let t = eth.transfer_us(1_000_000);
        assert!((t - 8050.0).abs() < 1.0, "{t}");
        // zero bytes = latency only
        assert_eq!(eth.transfer_us(0), 50.0);
        // pcie much faster than ethernet
        assert!(LinkModel::pcie3().transfer_us(1_000_000) < t / 50.0);
    }

    #[test]
    fn ledger_accounting() {
        let l = ByteLedger::new();
        l.add_param(100);
        l.add_feature(50);
        l.add_param(1);
        assert_eq!(l.param_bytes(), 101);
        assert_eq!(l.feature_bytes(), 50);
        assert_eq!(l.total_bytes(), 151);
        assert_eq!(l.messages(), 3);
        l.reset();
        assert_eq!(l.total_bytes(), 0);
    }

    #[test]
    fn clock_barrier() {
        let mut clocks = vec![VirtualClock { us: 10.0 }, VirtualClock { us: 30.0 }, VirtualClock { us: 20.0 }];
        VirtualClock::barrier(&mut clocks);
        assert!(clocks.iter().all(|c| c.us == 30.0));
    }

    #[test]
    fn clock_transfer() {
        let mut c = VirtualClock::new();
        c.transfer(&LinkModel::ethernet_1g(), 0);
        assert_eq!(c.us, 50.0);
        c.advance(25.0);
        assert_eq!(c.us, 75.0);
        assert_eq!(c.ms(), 0.075);
    }

    #[test]
    fn clock_merge_only_moves_forward() {
        let mut c = VirtualClock { us: 100.0 };
        c.merge_us(40.0); // past event: free
        assert_eq!(c.us, 100.0);
        c.merge_us(130.0); // exposed comm tail
        assert_eq!(c.us, 130.0);
    }

    #[test]
    #[should_panic(expected = "bandwidth")]
    fn zero_bandwidth_link_rejected() {
        let _ = LinkModel::new(10.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "bandwidth")]
    fn nan_bandwidth_link_rejected() {
        let _ = LinkModel::new(10.0, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "latency")]
    fn negative_latency_link_rejected() {
        let _ = LinkModel::new(-1.0, 1.0);
    }

    /// The delivery model burns wire time on every fate: lost and corrupt
    /// attempts occupy the link exactly like clean ones, and a duplicate
    /// serializes a second copy behind the first.
    #[test]
    fn deliver_charges_every_fate_honestly() {
        let link = LinkModel::new(10.0, 8.0); // 10 µs + 1 µs per 1000 B
        let mut tl = LinkTimeline::new();
        let (d, f) = tl.deliver(&link, 0.0, 1000, None);
        assert_eq!((d, f), (Delivery::Ok, 11.0));
        let (d, f) = tl.deliver(&link, 11.0, 1000, Some(WireFault::Drop));
        assert_eq!((d, f), (Delivery::Lost, 22.0));
        let (d, f) = tl.deliver(&link, 22.0, 1000, Some(WireFault::Corrupt));
        assert_eq!((d, f), (Delivery::Corrupted, 33.0));
        // Duplicate: two back-to-back copies, finish when the second lands.
        let (d, f) = tl.deliver(&link, 33.0, 1000, Some(WireFault::Duplicate));
        assert_eq!((d, f), (Delivery::Ok, 55.0));
        assert_eq!(tl.free_us(), 55.0);
        // Reorder: the stale-copy charge is the protocol layer's job; the
        // real frame itself is one clean transfer.
        let (d, f) = tl.deliver(&link, 55.0, 1000, Some(WireFault::Reorder));
        assert_eq!((d, f), (Delivery::Ok, 66.0));
    }

    /// The overlap timeline: transfers are charged at their flush time,
    /// serialize on the link, and the max-merged step time beats the summed
    /// (sequential) accounting whenever flushes land before compute ends.
    #[test]
    fn timeline_serializes_and_overlaps() {
        let link = LinkModel { latency_us: 10.0, gbps: 8.0 }; // 1 B/ns
        let mut tl = LinkTimeline::new();
        // Bucket A flushed at t=0: 10 + 1000 ns... (1000 B / 1 GB/s = 1 µs).
        let f1 = tl.flush(&link, 0.0, 1000);
        assert_eq!(f1, 11.0);
        // Bucket B flushed at t=5 queues behind A (link busy until 11).
        let f2 = tl.flush(&link, 5.0, 1000);
        assert_eq!(f2, 22.0);
        // Bucket C flushed after the link went idle starts immediately.
        let f3 = tl.flush(&link, 100.0, 1000);
        assert_eq!(f3, 111.0);
        assert_eq!(tl.free_us(), 111.0);

        // Step accounting: compute ends at 120; overlapped step max-merges
        // to 120 (all transfers hidden), sequential would charge 120 + 33.
        let mut overlapped = VirtualClock { us: 120.0 };
        for f in [f1, f2, f3] {
            overlapped.merge_us(f);
        }
        assert_eq!(overlapped.us, 120.0);
        let mut sequential = VirtualClock { us: 120.0 };
        for _ in 0..3 {
            sequential.transfer(&link, 1000);
        }
        assert!(sequential.us > overlapped.us);
    }
}
