//! Per-bucket wire codecs for the parameter plane (Mayer & Jacobsen's
//! survey, PAPERS.md: communication compression as a standard scalability
//! lever): each flush-bucket chunk is encoded with a quantization scale
//! riding in its header, so comm-bound configs ship ~2× (f16) or ~4×
//! (int8) fewer gradient/value bytes over the modeled link.
//!
//! Workers and servers share an address space here (the wire is simulated),
//! so "encoding" is a quantize→dequantize round trip: the values that reach
//! the server's updater — and the fresh values the worker adopts — are
//! exactly what a real receiver would decode, while the byte counts charged
//! to the [`crate::comm::LinkTimeline`] and [`crate::comm::ByteLedger`] are
//! the compressed chunk sizes.
//!
//! Quantization error on the *gradient* path is preserved, not dropped:
//! [`feedback_encode`] keeps a per-slot residual (error feedback, 1-bit-SGD
//! style) that is re-added to the next flush, so the running sum of decoded
//! gradients tracks the uncompressed sum and convergence is unchanged in
//! expectation. Value adoption (server → worker) is plain quantization —
//! the server's master copy stays full precision.
//!
//! [`Codec::Raw`] is the identity: the hot path ships blobs in the
//! historical format with the historical byte accounting, bit for bit (the
//! encode/decode functions below still exist for Raw so the test matrix can
//! pin its bitwise round trip through the chunk format).
//!
//! Decoding is hardened like [`crate::model::checkpoint::Checkpoint::read_from`]:
//! truncated headers, short payloads, bad counts, and NaN/negative scales
//! are [`anyhow::Result`] errors naming the offending field — never panics.

use anyhow::{bail, Result};

/// Encoded-chunk header: tag byte + f32 LE scale + u32 LE element count.
pub const CHUNK_HEADER: usize = 9;

/// Integrity-frame header prepended to a chunk under the retry protocol
/// (`FaultPlan` wire faults armed): u32 LE per-bucket sequence number +
/// u32 LE CRC32 of the chunk bytes. The sequence number lets a receiver
/// discard duplicates and stale retransmits; the CRC turns silent bit
/// damage into a detected, retryable loss — for `Raw` payloads too.
pub const FRAME_HEADER: usize = 8;

/// Bound on a decoded chunk's element count (mirrors the checkpoint
/// reader's `MAX_ELEMS`): a corrupt count field errors out instead of
/// driving a giant allocation or loop.
pub const MAX_ELEMS: usize = 1 << 30;

/// Wire codec for flush buckets, selected via
/// [`crate::coordinator::JobConf::wire_codec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Full f32 payloads, historical format and byte accounting — the
    /// exchange is bit-identical to the uncompressed parameter plane.
    Raw,
    /// IEEE 754 binary16 with a per-chunk scale (values are normalized by
    /// the chunk's max magnitude before conversion, so ±huge and subnormal
    /// buckets neither overflow nor flush to zero). ~2× payload shrink;
    /// per-element error ≤ `max_abs / 1024`.
    F16,
    /// 8-bit linear quantization, `scale = max_abs / 127`, round to
    /// nearest. ~4× payload shrink; per-element error ≤ `scale / 2` (≈
    /// `max_abs / 254`) — re-injected into the next flush by error
    /// feedback on the gradient path.
    Int8,
}

impl Codec {
    /// Parse a config-file spelling.
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "raw" => Ok(Codec::Raw),
            "f16" => Ok(Codec::F16),
            "int8" => Ok(Codec::Int8),
            other => bail!("unknown wire codec '{other}' (raw | f16 | int8)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::F16 => "f16",
            Codec::Int8 => "int8",
        }
    }

    /// Chunk-format tag byte.
    fn tag(self) -> u8 {
        match self {
            Codec::Raw => 0,
            Codec::F16 => 1,
            Codec::Int8 => 2,
        }
    }

    /// Encoded payload bytes per element.
    pub fn elem_bytes(self) -> usize {
        match self {
            Codec::Raw => 4,
            Codec::F16 => 2,
            Codec::Int8 => 1,
        }
    }

    /// Encoded buffer length for `n` elements (header + payload).
    pub fn encoded_len(self, n: usize) -> usize {
        CHUNK_HEADER + n * self.elem_bytes()
    }

    /// Buffer length of a CRC-framed chunk for `n` elements. Under the
    /// retry protocol even `Raw` ships the self-describing chunk format
    /// (the CRC needs a concrete byte layout to cover), so the framed wire
    /// charge is `FRAME_HEADER + encoded_len` for every codec.
    pub fn framed_len(self, n: usize) -> usize {
        FRAME_HEADER + self.encoded_len(n)
    }

    /// Modeled wire bytes of one `payload_bytes` (f32) parameter payload
    /// under this codec. Raw ships the blob as-is — the historical charge,
    /// no chunk framing — so its accounting stays bit-identical; quantized
    /// codecs pay the compressed payload plus the chunk header carrying
    /// the scale.
    pub fn wire_bytes(self, payload_bytes: usize) -> usize {
        match self {
            Codec::Raw => payload_bytes,
            coded => coded.encoded_len(payload_bytes / 4),
        }
    }

    /// Per-chunk quantization scale for `src` (the value a decoder
    /// multiplies by). 0.0 encodes an all-zero (or non-finite-max) chunk:
    /// every element decodes to exactly 0.
    fn scale_for(self, src: &[f32]) -> f32 {
        let max_abs = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if !max_abs.is_finite() || max_abs == 0.0 {
            return match self {
                Codec::Raw => 1.0,
                _ => 0.0,
            };
        }
        match self {
            Codec::Raw => 1.0,
            Codec::F16 => max_abs,
            // The division can flush to zero for deeply subnormal chunks —
            // then the whole chunk quantizes to zero, which is within the
            // error bound (every element is ≤ max_abs ≈ 0 anyway).
            Codec::Int8 => max_abs / 127.0,
        }
    }

    /// Encode `src` into `dst` (cleared and refilled; reserve
    /// [`Codec::encoded_len`] up front to keep the steady state free of
    /// buffer growth). Inputs are expected finite — gradients and values
    /// on this plane always are.
    pub fn encode_into(self, src: &[f32], dst: &mut Vec<u8>) {
        dst.clear();
        self.encode_append(src, dst);
    }

    /// [`Codec::encode_into`] without the clear: append the chunk to
    /// whatever `dst` already holds (the integrity frame writes its header
    /// first and backfills the CRC over the appended chunk).
    fn encode_append(self, src: &[f32], dst: &mut Vec<u8>) {
        dst.push(self.tag());
        let scale = self.scale_for(src);
        dst.extend_from_slice(&scale.to_le_bytes());
        dst.extend_from_slice(&(src.len() as u32).to_le_bytes());
        match self {
            Codec::Raw => {
                for &v in src {
                    dst.extend_from_slice(&v.to_le_bytes());
                }
            }
            Codec::F16 => {
                for &v in src {
                    let h = if scale == 0.0 { 0 } else { f32_to_f16_bits(v / scale) };
                    dst.extend_from_slice(&h.to_le_bytes());
                }
            }
            Codec::Int8 => {
                for &v in src {
                    let q = if scale == 0.0 {
                        0i8
                    } else {
                        (v / scale).round().clamp(-127.0, 127.0) as i8
                    };
                    dst.push(q as u8);
                }
            }
        }
    }

    /// Decode an encoded chunk into `dst` (whose length must equal the
    /// chunk's element count). Hardened: corrupt or truncated chunks are
    /// errors naming the offending field, never panics.
    pub fn decode_into(self, src: &[u8], dst: &mut [f32]) -> Result<()> {
        if src.len() < CHUNK_HEADER {
            bail!(
                "encoded chunk truncated: {} bytes, need a {CHUNK_HEADER}-byte header",
                src.len()
            );
        }
        let tag = src[0];
        if tag != self.tag() {
            bail!(
                "chunk codec tag {tag} does not match decoder '{}' (tag {})",
                self.name(),
                self.tag()
            );
        }
        // lint: panic-ok(4-byte slice of a length-checked header is infallible)
        let scale = f32::from_le_bytes(src[1..5].try_into().unwrap());
        if !scale.is_finite() {
            bail!("chunk scale is not finite ({scale})");
        }
        if scale < 0.0 {
            bail!("chunk scale is negative ({scale})");
        }
        // lint: panic-ok(4-byte slice of a length-checked header is infallible)
        let count = u32::from_le_bytes(src[5..9].try_into().unwrap()) as usize;
        if count > MAX_ELEMS {
            bail!("chunk element count {count} exceeds the {MAX_ELEMS} bound");
        }
        if count != dst.len() {
            bail!(
                "chunk element count {count} does not match the {}-element destination",
                dst.len()
            );
        }
        let payload = &src[CHUNK_HEADER..];
        let want = count * self.elem_bytes();
        if payload.len() != want {
            bail!(
                "chunk payload is {} bytes, expected {want} for {count} '{}' elements",
                payload.len(),
                self.name()
            );
        }
        match self {
            Codec::Raw => {
                for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                    // lint: panic-ok(chunks_exact(4) yields 4-byte slices)
                    *d = f32::from_le_bytes(c.try_into().unwrap());
                }
            }
            Codec::F16 => {
                for (d, c) in dst.iter_mut().zip(payload.chunks_exact(2)) {
                    // lint: panic-ok(chunks_exact(2) yields 2-byte slices)
                    let h = u16::from_le_bytes(c.try_into().unwrap());
                    *d = f16_bits_to_f32(h) * scale;
                }
            }
            Codec::Int8 => {
                for (d, &b) in dst.iter_mut().zip(payload) {
                    *d = (b as i8) as f32 * scale;
                }
            }
        }
        Ok(())
    }
}

const CRC32_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32/ISO-HDLC (the IEEE 802.3 polynomial, reflected, as in ethernet,
/// gzip, and zlib) over `data`. Std-only, table-driven; the table is built
/// at compile time.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode `src` as a CRC-framed chunk into `dst` (cleared and refilled):
/// `[seq u32 LE][crc32 u32 LE][chunk]`, with the CRC computed over the
/// chunk bytes. Reserve [`Codec::framed_len`] up front to keep the steady
/// state free of buffer growth.
pub fn frame_chunk(codec: Codec, seq: u32, src: &[f32], dst: &mut Vec<u8>) {
    dst.clear();
    dst.extend_from_slice(&seq.to_le_bytes());
    dst.extend_from_slice(&[0u8; 4]); // CRC backfilled below
    codec.encode_append(src, dst);
    let crc = crc32(&dst[FRAME_HEADER..]);
    dst[4..8].copy_from_slice(&crc.to_le_bytes());
}

/// Verify a framed chunk: returns the sequence number and the chunk bytes,
/// or a named error on a truncated frame or CRC mismatch. Hardened like
/// [`Codec::decode_into`]: arbitrary input never panics.
pub fn frame_verify(buf: &[u8]) -> Result<(u32, &[u8])> {
    if buf.len() < FRAME_HEADER {
        bail!(
            "framed chunk truncated: {} bytes, need a {FRAME_HEADER}-byte frame header",
            buf.len()
        );
    }
    // lint: panic-ok(4-byte slices of a length-checked header are infallible)
    let seq = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    // lint: panic-ok(4-byte slices of a length-checked header are infallible)
    let want = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let got = crc32(&buf[FRAME_HEADER..]);
    if got != want {
        bail!(
            "frame CRC32 mismatch: header says {want:#010x}, chunk hashes to {got:#010x} \
             (corrupt transfer; discard and await retransmit)"
        );
    }
    Ok((seq, &buf[FRAME_HEADER..]))
}

/// THE error-feedback encode recipe, shared by the comm path
/// ([`crate::coordinator::workspace::apply_flush`]) and the accumulation
/// test so the two cannot drift apart: add the residual carried from the
/// previous flush into `grad`, encode the compensated gradient, decode into
/// `dec` (the values that actually reach the server), and store the fresh
/// quantization error back into `residual` for the next flush. All slices
/// share one length; `enc` is the caller's reserved chunk scratch.
pub fn feedback_encode(
    codec: Codec,
    grad: &mut [f32],
    residual: &mut [f32],
    enc: &mut Vec<u8>,
    dec: &mut [f32],
) {
    for (g, r) in grad.iter_mut().zip(residual.iter()) {
        *g += *r;
    }
    codec.encode_into(grad, enc);
    // lint: panic-ok(round-trip of a buffer this call just encoded; a failure is a codec bug, not input)
    codec.decode_into(enc, dec).expect("self-encoded chunk must decode");
    for ((r, g), d) in residual.iter_mut().zip(grad.iter()).zip(dec.iter()) {
        *r = *g - *d;
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (the `half` crate
/// is not in the offline vendor set). Overflow saturates to ±65504 (the
/// largest finite half) instead of producing an infinity — a quantizer
/// must never widen a finite value to inf.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (NaN keeps a quiet payload bit).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127;
    if e < -24 {
        return sign; // underflows even the smallest half subnormal
    }
    let h = if e >= -14 {
        // Normal half range (round-to-nearest-even; a mantissa carry into
        // the exponent is still correct rounding).
        let mant16 = mant >> 13;
        let round = mant & 0x1fff;
        let mut h = (((e + 15) as u32) << 10) | mant16;
        if round > 0x1000 || (round == 0x1000 && (mant16 & 1) == 1) {
            h += 1;
        }
        h
    } else {
        // Subnormal half: shift the (implicit-bit) mantissa into place.
        let m = mant | 0x0080_0000;
        let shift = (13 - 14 - e) as u32;
        let mant16 = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = mant16;
        if rem > half || (rem == half && (mant16 & 1) == 1) {
            h += 1;
        }
        h
    };
    if h >= 0x7c00 {
        return sign | 0x7bff; // saturate instead of rounding up to inf
    }
    sign | h as u16
}

/// IEEE 754 binary16 bits → f32 (exact: every half value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal half: renormalize into the f32 exponent range.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Half → f32 → half is the identity for every non-NaN bit pattern
    /// (the f32 conversion is exact, so converting back must land on the
    /// same bits) — pins both converters against each other exhaustively.
    #[test]
    fn f16_f32_f16_is_identity_for_all_non_nan_patterns() {
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let mant = h & 0x3ff;
            if exp == 31 && mant != 0 {
                continue; // NaN payloads are canonicalized, not preserved
            }
            if exp == 31 {
                // ±inf saturates to ±max-finite by design; skip identity.
                continue;
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "pattern {h:#06x} did not round-trip");
        }
    }

    /// Spot values against the IEEE tables: 1.0, -2.5, the largest finite
    /// half, the smallest subnormal, and overflow saturation.
    #[test]
    fn f16_conversion_spot_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.5), 0xc100);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8);
        assert_eq!(f32_to_f16_bits(1e9), 0x7bff, "overflow saturates, not inf");
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(0.0).to_le_bytes(), [0, 0]);
        // Round-to-nearest-even: 2049/2048 is exactly between two halves.
        assert_eq!(f32_to_f16_bits(1.0 + 1.0 / 2048.0), 0x3c00);
    }

    /// Encoded sizes: header + n × per-element payload; Raw's modeled wire
    /// size is the historical bare payload (no chunk framing).
    #[test]
    fn encoded_and_wire_sizes() {
        assert_eq!(Codec::Raw.encoded_len(10), CHUNK_HEADER + 40);
        assert_eq!(Codec::F16.encoded_len(10), CHUNK_HEADER + 20);
        assert_eq!(Codec::Int8.encoded_len(10), CHUNK_HEADER + 10);
        assert_eq!(Codec::Raw.wire_bytes(40), 40);
        assert_eq!(Codec::F16.wire_bytes(40), CHUNK_HEADER + 20);
        assert_eq!(Codec::Int8.wire_bytes(40), CHUNK_HEADER + 10);
    }

    #[test]
    fn parse_and_names() {
        for c in [Codec::Raw, Codec::F16, Codec::Int8] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("zstd").is_err());
    }

    /// The actual encoded buffer length always matches `encoded_len` — the
    /// scratch reservation in the workspace depends on it.
    #[test]
    fn encode_fills_exactly_encoded_len() {
        let v = [0.5f32, -3.25, 0.0, 1e-3];
        let mut enc = Vec::new();
        for c in [Codec::Raw, Codec::F16, Codec::Int8] {
            c.encode_into(&v, &mut enc);
            assert_eq!(enc.len(), c.encoded_len(v.len()), "{}", c.name());
        }
    }

    /// CRC-32/ISO-HDLC check vectors: the canonical "123456789" → 0xCBF43926,
    /// the empty string → 0, and a single zero byte.
    #[test]
    fn crc32_check_vectors() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(&[0u8]), 0xD202_EF8D);
    }

    /// A framed chunk round-trips: verify recovers the sequence number and
    /// the exact chunk bytes the codec produced, at the `framed_len` size.
    #[test]
    fn frame_roundtrip_preserves_seq_and_chunk() {
        let v = [0.5f32, -3.25, 0.0, 1e-3];
        let mut framed = Vec::new();
        let mut bare = Vec::new();
        for c in [Codec::Raw, Codec::F16, Codec::Int8] {
            frame_chunk(c, 7, &v, &mut framed);
            assert_eq!(framed.len(), c.framed_len(v.len()), "{}", c.name());
            let (seq, chunk) = frame_verify(&framed).unwrap();
            assert_eq!(seq, 7);
            c.encode_into(&v, &mut bare);
            assert_eq!(chunk, &bare[..], "{}", c.name());
            let mut dec = [0.0f32; 4];
            c.decode_into(chunk, &mut dec).unwrap();
        }
    }

    /// Truncated frames and CRC mismatches are named errors, never panics
    /// or silent acceptance.
    #[test]
    fn frame_verify_hardened() {
        assert!(frame_verify(&[]).unwrap_err().to_string().contains("truncated"));
        assert!(frame_verify(&[1, 2, 3]).unwrap_err().to_string().contains("truncated"));
        // An 8-byte frame with an empty chunk: CRC of nothing is 0.
        assert!(frame_verify(&[9, 0, 0, 0, 0, 0, 0, 0]).is_ok());
        let mut framed = Vec::new();
        frame_chunk(Codec::Raw, 1, &[1.0, 2.0], &mut framed);
        framed[FRAME_HEADER + 3] ^= 0x40;
        let e = frame_verify(&framed).unwrap_err().to_string();
        assert!(e.contains("CRC32 mismatch"), "{e}");
    }
}
