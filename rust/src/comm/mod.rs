//! Communication substrate (paper §5.1): message types exchanged between
//! workers and servers, byte accounting, and the simulated-network cost
//! model used to evaluate cluster-scale configurations on this single-node
//! testbed (see DESIGN.md §Hardware-Adaptation).
//!
//! Workers and servers in this reproduction share an address space (SINGA's
//! in-memory message passing between threads); *remote* links are modeled:
//! every transfer is charged to a [`ByteLedger`] and, in virtual-time mode,
//! advances a [`VirtualClock`] by the [`LinkModel`] cost.

pub mod codec;
pub mod faults;
pub mod msg;
pub mod simnet;

pub use codec::Codec;
pub use faults::{FaultPlan, FaultRecord, RetryConf, WireEvents, WireFault};
pub use msg::Msg;
pub use simnet::{ByteLedger, CostModel, Delivery, LinkModel, LinkTimeline, VirtualClock};
