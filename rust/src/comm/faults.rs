//! Deterministic fault injection on the simnet clock: per-worker-group
//! kill-at-step and delay/straggler schedules, configured via
//! [`crate::coordinator::JobConf::faults`].
//!
//! Production scale means workers die and stragglers happen (IBM DLaaS:
//! resilience is what turns a training framework into a service). The plan
//! is *deterministic in step space* — a kill fires at the top of a named
//! `(group, step)`, a delay scales that step's virtual compute charge —
//! so fault scenarios replay bit-for-bit: recovery tests can pin a
//! restarted run against an uninterrupted one, and `BENCH_faults.json`
//! measures recovery overhead on the virtual clock instead of on wall
//! noise. Training *values* are never perturbed; only control flow (kill →
//! restart from checkpoint) and the clock/ledger accounting change.

/// A delay rule: steps `from..to` of `group` take `factor`× their healthy
/// per-worker compute time (a straggling worker dragging the group's
/// synchronous barrier).
#[derive(Debug, Clone, PartialEq)]
struct DelayRule {
    group: usize,
    from: u64,
    to: u64,
    factor: f64,
}

/// A deterministic fault schedule for one job. Built with the chained
/// constructors; queried by the worker-group loop each step.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    kills: Vec<(usize, u64)>,
    delays: Vec<DelayRule>,
    /// Virtual time (µs) a killed worker group spends restarting —
    /// scheduler reallocation, process start, net rebuild — before the
    /// checkpoint read is charged on top.
    pub restart_latency_us: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { kills: Vec::new(), delays: Vec::new(), restart_latency_us: 2_000_000.0 }
    }
}

impl FaultPlan {
    /// The perfect cluster: nothing ever fails.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.delays.is_empty()
    }

    /// Kill worker group `group` at the top of `step` (before the step's
    /// batch is consumed). The group restarts from the latest checkpoint —
    /// see the recovery rules in `coordinator::worker_group_loop`.
    pub fn kill(mut self, group: usize, step: u64) -> FaultPlan {
        self.kills.push((group, step));
        self
    }

    /// Straggle: `group`'s step `step` takes `factor`× its healthy
    /// per-worker compute time on the virtual clock.
    pub fn delay(self, group: usize, step: u64, factor: f64) -> FaultPlan {
        self.delay_range(group, step, step + 1, factor)
    }

    /// Straggle over a half-open step range `from..to`.
    pub fn delay_range(mut self, group: usize, from: u64, to: u64, factor: f64) -> FaultPlan {
        assert!(factor >= 1.0, "a delay factor below 1 would model a speedup");
        self.delays.push(DelayRule { group, from, to, factor });
        self
    }

    pub fn with_restart_latency_us(mut self, us: f64) -> FaultPlan {
        self.restart_latency_us = us;
        self
    }

    /// Does the plan kill `group` at the top of `step`?
    pub fn kill_at(&self, group: usize, step: u64) -> bool {
        self.kills.iter().any(|&(g, s)| g == group && s == step)
    }

    /// Compute-time multiplier for `(group, step)`: the worst matching
    /// delay rule, or 1.0 when the step is healthy.
    pub fn delay_factor(&self, group: usize, step: u64) -> f64 {
        self.delays
            .iter()
            .filter(|r| r.group == group && (r.from..r.to).contains(&step))
            .map(|r| r.factor)
            .fold(1.0, f64::max)
    }
}

/// One recovered kill, as reported in `JobReport::fault_events`: where the
/// group died, where it resumed, which checkpoint (if any) it restored
/// from, and what the recovery cost on its virtual clock.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub group: usize,
    pub killed_at_step: u64,
    pub resumed_at_step: u64,
    /// `Some(step)` when the group restored a checkpoint taken after that
    /// many completed steps; `None` for a cold restart (no checkpoint yet)
    /// or a shared-server rejoin (live params survive the kill).
    pub restored_from: Option<u64>,
    /// Virtual-clock cost of the restart itself (latency + checkpoint
    /// read), excluding the replayed steps.
    pub recovery_virt_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_benign() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.kill_at(0, 0));
        assert_eq!(p.delay_factor(0, 0), 1.0);
    }

    #[test]
    fn kill_matches_only_its_group_and_step() {
        let p = FaultPlan::none().kill(1, 7);
        assert!(p.kill_at(1, 7));
        assert!(!p.kill_at(0, 7));
        assert!(!p.kill_at(1, 6));
        assert!(!p.is_empty());
    }

    #[test]
    fn delay_ranges_take_the_worst_matching_factor() {
        let p = FaultPlan::none().delay_range(0, 5, 10, 2.0).delay(0, 7, 4.0).delay(1, 7, 8.0);
        assert_eq!(p.delay_factor(0, 4), 1.0);
        assert_eq!(p.delay_factor(0, 5), 2.0);
        assert_eq!(p.delay_factor(0, 7), 4.0);
        assert_eq!(p.delay_factor(0, 9), 2.0);
        assert_eq!(p.delay_factor(0, 10), 1.0);
        assert_eq!(p.delay_factor(1, 7), 8.0);
    }

    #[test]
    #[should_panic(expected = "speedup")]
    fn sub_unit_delay_factor_rejected() {
        let _ = FaultPlan::none().delay(0, 1, 0.5);
    }
}
