//! Deterministic fault injection on the simnet clock: per-worker-group
//! kill-at-step and delay/straggler schedules, plus per-link *wire* fault
//! schedules (drop / corrupt / duplicate / reorder), configured via
//! [`crate::coordinator::JobConf::faults`].
//!
//! Production scale means workers die, stragglers happen, and the network
//! loses or mangles packets (IBM DLaaS: resilience is what turns a training
//! framework into a service; the Mayer & Jacobsen survey names transport
//! reliability a core open challenge). The plan is *deterministic in step
//! space* — a kill fires at the top of a named `(group, step)`, a delay
//! scales that step's virtual compute charge, and a wire rule decides the
//! fate of a named flush attempt, with probabilistic rules resolved by a
//! seeded splitmix64 stream (the same generator family as
//! `PALLAS_SANITIZE=stress`) — so chaos scenarios replay bit-for-bit:
//! tests can pin a lossy run against a lossless one, and
//! `BENCH_chaos.json` measures retry overhead on the virtual clock instead
//! of on wall noise. Training *values* are never perturbed by the plan
//! itself; the retry protocol in `coordinator::exchange` re-delivers lost
//! and corrupt flushes (value-transparent), and only an exhausted retry
//! budget degrades a bucket to its last-known value (counted as bounded
//! staleness in `JobReport::wire_events`).

use anyhow::{bail, Result};

/// One splitmix64 output step — the same finalizer family the stress-mode
/// sanitizer seeds its yield decisions with (`runtime::sync`). Used here to
/// resolve probabilistic wire rules deterministically from the plan seed.
pub(crate) fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fold a tuple of event coordinates into the seeded stream: one splitmix
/// step per component, so nearby coordinates land far apart.
fn mix(seed: u64, parts: &[u64]) -> u64 {
    parts.iter().fold(splitmix64(seed), |h, &p| splitmix64(h ^ p))
}

/// What a wire rule does to a matching flush attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFault {
    /// The transfer vanishes: bytes are charged (they crossed the wire),
    /// nothing arrives, the sender's deadline fires.
    Drop,
    /// The transfer arrives bit-damaged: the CRC32 frame check fails on the
    /// receiver and the chunk is discarded, same outcome as a drop.
    Corrupt,
    /// The transfer arrives twice: the second copy burns wire time and is
    /// discarded by its stale sequence number.
    Duplicate,
    /// A stale retransmit overtakes the fresh copy: the out-of-date frame
    /// arrives first and is discarded by its sequence number.
    Reorder,
}

impl WireFault {
    pub fn name(&self) -> &'static str {
        match self {
            WireFault::Drop => "drop",
            WireFault::Corrupt => "corrupt",
            WireFault::Duplicate => "duplicate",
            WireFault::Reorder => "reorder",
        }
    }
}

/// A wire rule: flush attempts of `group` in steps `from..to` suffer
/// `kind`, either on one named attempt (`nth = Some`) or on every attempt
/// (`nth = None`, a severed link), gated by a `rate` coin resolved from the
/// plan's seeded splitmix64 stream (`rate = 1.0` fires unconditionally).
#[derive(Debug, Clone, PartialEq)]
struct WireRule {
    group: usize,
    from: u64,
    to: u64,
    kind: WireFault,
    nth: Option<u32>,
    rate: f64,
}

/// A delay rule: steps `from..to` of `group` take `factor`× their healthy
/// per-worker compute time (a straggling worker dragging the group's
/// synchronous barrier).
#[derive(Debug, Clone, PartialEq)]
struct DelayRule {
    group: usize,
    from: u64,
    to: u64,
    factor: f64,
}

/// A deterministic fault schedule for one job. Built with the chained
/// constructors; queried by the worker-group loop each step and by the
/// exchange's delivery loop on each flush attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    kills: Vec<(usize, u64)>,
    delays: Vec<DelayRule>,
    wire: Vec<WireRule>,
    wire_seed: u64,
    /// Virtual time (µs) a killed worker group spends restarting —
    /// scheduler reallocation, process start, net rebuild — before the
    /// checkpoint read is charged on top.
    pub restart_latency_us: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            kills: Vec::new(),
            delays: Vec::new(),
            wire: Vec::new(),
            wire_seed: 0xC4A0_5EED,
            restart_latency_us: 2_000_000.0,
        }
    }
}

impl FaultPlan {
    /// The perfect cluster: nothing ever fails.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.delays.is_empty() && self.wire.is_empty()
    }

    /// Kill worker group `group` at the top of `step` (before the step's
    /// batch is consumed). The group restarts from the latest checkpoint —
    /// see the recovery rules in `coordinator::worker_group_loop`.
    pub fn kill(mut self, group: usize, step: u64) -> FaultPlan {
        self.kills.push((group, step));
        self
    }

    /// Straggle: `group`'s step `step` takes `factor`× its healthy
    /// per-worker compute time on the virtual clock.
    pub fn delay(self, group: usize, step: u64, factor: f64) -> FaultPlan {
        self.delay_range(group, step, step + 1, factor)
    }

    /// Straggle over a half-open step range `from..to`.
    pub fn delay_range(mut self, group: usize, from: u64, to: u64, factor: f64) -> FaultPlan {
        assert!(
            factor.is_finite() && factor >= 1.0,
            "delay factor must be finite and >= 1 (a factor below 1 would model a speedup); \
             got {factor}"
        );
        self.delays.push(DelayRule { group, from, to, factor });
        self
    }

    pub fn with_restart_latency_us(mut self, us: f64) -> FaultPlan {
        assert!(
            us.is_finite() && us >= 0.0,
            "restart latency must be finite and >= 0 µs (it is charged to every \
             recovery on the virtual clock); got {us}"
        );
        self.restart_latency_us = us;
        self
    }

    /// Reseed the splitmix64 stream that resolves probabilistic wire rules.
    pub fn with_wire_seed(mut self, seed: u64) -> FaultPlan {
        self.wire_seed = seed;
        self
    }

    fn wire_rule(
        mut self,
        group: usize,
        from: u64,
        to: u64,
        kind: WireFault,
        nth: Option<u32>,
        rate: f64,
    ) -> FaultPlan {
        assert!(
            rate.is_finite() && (0.0..=1.0).contains(&rate),
            "wire fault rate must be a finite probability in [0, 1]; got {rate}"
        );
        self.wire.push(WireRule { group, from, to, kind, nth, rate });
        self
    }

    /// Lose attempt `nth` (0-based) of every bucket flush `group` sends in
    /// steps `from..to`. With `nth = 0` the first copy always vanishes and
    /// the retransmit goes through — the canonical eventual-delivery plan.
    pub fn drop_nth(self, group: usize, from: u64, to: u64, nth: u32) -> FaultPlan {
        self.wire_rule(group, from, to, WireFault::Drop, Some(nth), 1.0)
    }

    /// Bit-damage attempt `nth` of every matching flush: the receiver's
    /// CRC32 check rejects the frame and the sender retransmits.
    pub fn corrupt_nth(self, group: usize, from: u64, to: u64, nth: u32) -> FaultPlan {
        self.wire_rule(group, from, to, WireFault::Corrupt, Some(nth), 1.0)
    }

    /// Deliver attempt `nth` of every matching flush twice; the second copy
    /// is discarded by its duplicate sequence number.
    pub fn duplicate_nth(self, group: usize, from: u64, to: u64, nth: u32) -> FaultPlan {
        self.wire_rule(group, from, to, WireFault::Duplicate, Some(nth), 1.0)
    }

    /// Let a stale retransmit overtake attempt `nth` of every matching
    /// flush; the out-of-date frame is discarded by its sequence number.
    pub fn reorder_nth(self, group: usize, from: u64, to: u64, nth: u32) -> FaultPlan {
        self.wire_rule(group, from, to, WireFault::Reorder, Some(nth), 1.0)
    }

    /// Probabilistic chaos: every attempt of every matching flush suffers
    /// `kind` with probability `rate`, resolved from the seeded splitmix64
    /// stream (bit-for-bit reproducible for a given `wire_seed`).
    pub fn wire_rate(
        self,
        group: usize,
        from: u64,
        to: u64,
        kind: WireFault,
        rate: f64,
    ) -> FaultPlan {
        self.wire_rule(group, from, to, kind, None, rate)
    }

    /// Sever `group`'s link from step `from` onward: every attempt of every
    /// later flush is lost, so each bucket exhausts its retry budget and
    /// the group degrades to bounded staleness.
    pub fn sever(self, group: usize, from: u64) -> FaultPlan {
        self.wire_rule(group, from, u64::MAX, WireFault::Drop, None, 1.0)
    }

    /// Does the plan schedule any wire faults? When false, the exchange
    /// runs the historical (frameless, retry-free) protocol bit-for-bit.
    pub fn has_wire_faults(&self) -> bool {
        !self.wire.is_empty()
    }

    /// Does the plan kill `group` at the top of `step`?
    pub fn kill_at(&self, group: usize, step: u64) -> bool {
        self.kills.iter().any(|&(g, s)| g == group && s == step)
    }

    /// Compute-time multiplier for `(group, step)`: the worst matching
    /// delay rule, or 1.0 when the step is healthy.
    pub fn delay_factor(&self, group: usize, step: u64) -> f64 {
        self.delays
            .iter()
            .filter(|r| r.group == group && (r.from..r.to).contains(&step))
            .map(|r| r.factor)
            .fold(1.0, f64::max)
    }

    /// Fate of one flush attempt: the first rule (in insertion order)
    /// matching `(group, step, attempt)` whose rate coin lands decides;
    /// `None` means clean delivery. `seq` is the frame's sequence number —
    /// part of the coin so distinct buckets of one step fault
    /// independently under probabilistic rules.
    pub fn wire_fault(&self, group: usize, step: u64, seq: u32, attempt: u32) -> Option<WireFault> {
        for (i, r) in self.wire.iter().enumerate() {
            if r.group != group || !(r.from..r.to).contains(&step) {
                continue;
            }
            if let Some(n) = r.nth {
                if n != attempt {
                    continue;
                }
            }
            if r.rate < 1.0 {
                let h = mix(
                    self.wire_seed,
                    &[group as u64, step, seq as u64, attempt as u64, i as u64],
                );
                // 53 high bits → a uniform f64 in [0, 1).
                if (h >> 11) as f64 / (1u64 << 53) as f64 >= r.rate {
                    continue;
                }
            }
            return Some(r.kind);
        }
        None
    }

    /// Which bit a `Corrupt` fault flips in the framed chunk, resolved from
    /// the same stream (salted so it never correlates with the rate coin).
    pub fn corrupt_bit(
        &self,
        group: usize,
        step: u64,
        seq: u32,
        attempt: u32,
        frame_bits: u64,
    ) -> u64 {
        debug_assert!(frame_bits > 0);
        let salted = self.wire_seed ^ 0xB17F_11B5;
        let h = mix(salted, &[group as u64, step, seq as u64, attempt as u64]);
        h % frame_bits
    }

    /// Reject rules naming worker groups the job does not have — a kill,
    /// delay, or wire rule aimed at an out-of-range group would otherwise
    /// never fire and the scenario would silently test nothing.
    pub fn validate(&self, n_groups: usize) -> Result<()> {
        for &(g, step) in &self.kills {
            if g >= n_groups {
                bail!(
                    "fault plan: kill at step {step} names worker group {g}, but the job \
                     has only {n_groups} worker group(s) (groups are 0-based)"
                );
            }
        }
        for r in &self.delays {
            if r.group >= n_groups {
                bail!(
                    "fault plan: delay rule over steps {}..{} names worker group {}, but \
                     the job has only {n_groups} worker group(s) (groups are 0-based)",
                    r.from,
                    r.to,
                    r.group
                );
            }
        }
        for r in &self.wire {
            if r.group >= n_groups {
                bail!(
                    "fault plan: wire {} rule over steps {}..{} names worker group {}, but \
                     the job has only {n_groups} worker group(s) (groups are 0-based)",
                    r.kind.name(),
                    r.from,
                    r.to,
                    r.group
                );
            }
        }
        Ok(())
    }
}

/// Retry/timeout knobs for the wire protocol (`JobConf::retry`): attempt
/// `a` of a flush arms a virtual-clock deadline `timeout_us * backoff^a`
/// after its send instant; a lost or corrupt delivery retransmits at the
/// deadline, and after `max_attempts` failed copies the bucket degrades to
/// its last-known value (bounded staleness) instead of hanging.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryConf {
    pub timeout_us: f64,
    pub backoff: f64,
    pub max_attempts: u32,
}

impl Default for RetryConf {
    fn default() -> RetryConf {
        RetryConf { timeout_us: 5_000.0, backoff: 2.0, max_attempts: 4 }
    }
}

impl RetryConf {
    pub fn new(timeout_us: f64, backoff: f64, max_attempts: u32) -> RetryConf {
        let conf = RetryConf { timeout_us, backoff, max_attempts };
        conf.validate();
        conf
    }

    /// Panic (with the offending field) on values that would poison the
    /// virtual clock or retry forever.
    pub fn validate(&self) {
        assert!(
            self.timeout_us.is_finite() && self.timeout_us > 0.0,
            "retry timeout must be finite and > 0 µs; got {}",
            self.timeout_us
        );
        assert!(
            self.backoff.is_finite() && self.backoff >= 1.0,
            "retry backoff factor must be finite and >= 1; got {}",
            self.backoff
        );
        assert!(self.max_attempts >= 1, "retry needs at least one attempt");
    }

    /// Deadline armed for attempt `attempt` (0-based), in µs after its send
    /// instant: exponential backoff on the base timeout.
    pub fn timeout_after(&self, attempt: u32) -> f64 {
        self.timeout_us * self.backoff.powi(attempt as i32)
    }
}

/// One recovered kill, as reported in `JobReport::fault_events`: where the
/// group died, where it resumed, which checkpoint (if any) it restored
/// from, and what the recovery cost on its virtual clock.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub group: usize,
    pub killed_at_step: u64,
    pub resumed_at_step: u64,
    /// `Some(step)` when the group restored a checkpoint taken after that
    /// many completed steps; `None` for a cold restart (no checkpoint yet)
    /// or a shared-server rejoin (live params survive the kill).
    pub restored_from: Option<u64>,
    /// Virtual-clock cost of the restart itself (latency + checkpoint
    /// read), excluding the replayed steps.
    pub recovery_virt_ms: f64,
}

/// Wire-plane outcome of a job, reported in `JobReport::wire_events`
/// (mirroring `fault_events` for the process plane). All counts are summed
/// over the job; `degraded_steps` is per worker group.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WireEvents {
    /// Transfers lost in flight (charged to the wire, never delivered).
    pub drops: u64,
    /// Frames whose CRC32 check failed on the receiver.
    pub corruptions_detected: u64,
    /// Extra copies discarded by their duplicate sequence number.
    pub duplicates_discarded: u64,
    /// Stale frames that overtook fresh ones, discarded by sequence number.
    pub reorders_discarded: u64,
    /// Retransmissions the deadline protocol issued.
    pub retransmits: u64,
    /// Buckets that exhausted `max_attempts` and adopted their last-known
    /// value instead (bounded staleness).
    pub staleness_adoptions: u64,
    /// Bytes burned on transfers that were lost, corrupt, or discarded.
    pub wasted_bytes: u64,
    /// Per worker group: steps in which at least one bucket degraded.
    pub degraded_steps: Vec<u64>,
}

impl WireEvents {
    /// Fold one worker group's tallies into the job total: scalar counters
    /// add, and the group's `degraded_steps` entries append (one entry per
    /// group, in join order — see `run_job`).
    pub fn absorb(&mut self, other: WireEvents) {
        self.drops += other.drops;
        self.corruptions_detected += other.corruptions_detected;
        self.duplicates_discarded += other.duplicates_discarded;
        self.reorders_discarded += other.reorders_discarded;
        self.retransmits += other.retransmits;
        self.staleness_adoptions += other.staleness_adoptions;
        self.wasted_bytes += other.wasted_bytes;
        self.degraded_steps.extend(other.degraded_steps);
    }

    pub fn is_clean(&self) -> bool {
        self.drops == 0
            && self.corruptions_detected == 0
            && self.duplicates_discarded == 0
            && self.reorders_discarded == 0
            && self.retransmits == 0
            && self.staleness_adoptions == 0
            && self.wasted_bytes == 0
            && self.degraded_steps.iter().all(|&d| d == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_benign() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.kill_at(0, 0));
        assert_eq!(p.delay_factor(0, 0), 1.0);
        assert!(!p.has_wire_faults());
        assert_eq!(p.wire_fault(0, 0, 0, 0), None);
    }

    #[test]
    fn kill_matches_only_its_group_and_step() {
        let p = FaultPlan::none().kill(1, 7);
        assert!(p.kill_at(1, 7));
        assert!(!p.kill_at(0, 7));
        assert!(!p.kill_at(1, 6));
        assert!(!p.is_empty());
    }

    #[test]
    fn delay_ranges_take_the_worst_matching_factor() {
        let p = FaultPlan::none().delay_range(0, 5, 10, 2.0).delay(0, 7, 4.0).delay(1, 7, 8.0);
        assert_eq!(p.delay_factor(0, 4), 1.0);
        assert_eq!(p.delay_factor(0, 5), 2.0);
        assert_eq!(p.delay_factor(0, 7), 4.0);
        assert_eq!(p.delay_factor(0, 9), 2.0);
        assert_eq!(p.delay_factor(0, 10), 1.0);
        assert_eq!(p.delay_factor(1, 7), 8.0);
    }

    #[test]
    #[should_panic(expected = "speedup")]
    fn sub_unit_delay_factor_rejected() {
        let _ = FaultPlan::none().delay(0, 1, 0.5);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn nan_delay_factor_rejected() {
        let _ = FaultPlan::none().delay(0, 1, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "restart latency")]
    fn nan_restart_latency_rejected() {
        let _ = FaultPlan::none().with_restart_latency_us(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "restart latency")]
    fn negative_restart_latency_rejected() {
        let _ = FaultPlan::none().with_restart_latency_us(-1.0);
    }

    #[test]
    fn wire_rules_match_group_step_and_attempt() {
        let p = FaultPlan::none().drop_nth(1, 5, 10, 0);
        assert!(p.has_wire_faults());
        assert!(!p.is_empty());
        assert_eq!(p.wire_fault(1, 5, 3, 0), Some(WireFault::Drop));
        assert_eq!(p.wire_fault(1, 9, 0, 0), Some(WireFault::Drop));
        // Wrong group, step outside the range, or a later attempt: clean.
        assert_eq!(p.wire_fault(0, 5, 3, 0), None);
        assert_eq!(p.wire_fault(1, 10, 3, 0), None);
        assert_eq!(p.wire_fault(1, 4, 3, 0), None);
        assert_eq!(p.wire_fault(1, 5, 3, 1), None);
    }

    #[test]
    fn first_matching_rule_wins() {
        let p = FaultPlan::none().corrupt_nth(0, 0, 10, 0).drop_nth(0, 0, 10, 0);
        assert_eq!(p.wire_fault(0, 3, 0, 0), Some(WireFault::Corrupt));
    }

    #[test]
    fn sever_drops_every_attempt_from_its_step() {
        let p = FaultPlan::none().sever(0, 7);
        assert_eq!(p.wire_fault(0, 6, 0, 0), None);
        for attempt in 0..16 {
            assert_eq!(p.wire_fault(0, 7, 0, attempt), Some(WireFault::Drop));
            assert_eq!(p.wire_fault(0, u64::MAX - 1, 9, attempt), Some(WireFault::Drop));
        }
    }

    #[test]
    fn rate_coin_is_seeded_and_deterministic() {
        let p = FaultPlan::none().wire_rate(0, 0, 1000, WireFault::Drop, 0.5);
        let outcomes: Vec<bool> = (0..1000).map(|s| p.wire_fault(0, s, 0, 0).is_some()).collect();
        // Bit-for-bit replay under the same seed.
        let again: Vec<bool> = (0..1000).map(|s| p.wire_fault(0, s, 0, 0).is_some()).collect();
        assert_eq!(outcomes, again);
        // Roughly half fire; both outcomes occur.
        let fired = outcomes.iter().filter(|&&b| b).count();
        assert!((300..=700).contains(&fired), "rate 0.5 fired {fired}/1000");
        // A different seed resolves differently somewhere.
        let q = FaultPlan::none()
            .with_wire_seed(0xDEAD_BEEF)
            .wire_rate(0, 0, 1000, WireFault::Drop, 0.5);
        let other: Vec<bool> = (0..1000).map(|s| q.wire_fault(0, s, 0, 0).is_some()).collect();
        assert_ne!(outcomes, other);
    }

    #[test]
    fn rate_extremes() {
        let never = FaultPlan::none().wire_rate(0, 0, 100, WireFault::Corrupt, 0.0);
        assert!((0..100).all(|s| never.wire_fault(0, s, 0, 0).is_none()));
        let always = FaultPlan::none().wire_rate(0, 0, 100, WireFault::Corrupt, 1.0);
        assert!((0..100).all(|s| always.wire_fault(0, s, 0, 0) == Some(WireFault::Corrupt)));
    }

    #[test]
    #[should_panic(expected = "probability")]
    fn out_of_range_rate_rejected() {
        let _ = FaultPlan::none().wire_rate(0, 0, 1, WireFault::Drop, 1.5);
    }

    #[test]
    fn corrupt_bit_is_deterministic_and_in_range() {
        let p = FaultPlan::none();
        for len in [1u64, 8, 800, 4096] {
            let b = p.corrupt_bit(0, 3, 1, 0, len);
            assert!(b < len);
            assert_eq!(b, p.corrupt_bit(0, 3, 1, 0, len));
        }
    }

    #[test]
    fn validate_names_the_offending_rule() {
        assert!(FaultPlan::none().validate(1).is_ok());
        let full = FaultPlan::none().kill(0, 3).delay(0, 1, 2.0).drop_nth(0, 0, 9, 0);
        assert!(full.validate(1).is_ok());

        let e = FaultPlan::none().kill(2, 3).validate(2).unwrap_err().to_string();
        assert!(e.contains("kill") && e.contains("group 2") && e.contains("2 worker group"), "{e}");

        let e = FaultPlan::none().delay(5, 1, 2.0).validate(2).unwrap_err().to_string();
        assert!(e.contains("delay") && e.contains("group 5"), "{e}");

        let e = FaultPlan::none().corrupt_nth(3, 0, 9, 0).validate(3).unwrap_err().to_string();
        assert!(e.contains("corrupt") && e.contains("group 3"), "{e}");

        let e = FaultPlan::none().sever(9, 0).validate(1).unwrap_err().to_string();
        assert!(e.contains("drop") && e.contains("group 9"), "{e}");
    }

    #[test]
    fn retry_conf_deadlines_back_off_exponentially() {
        let r = RetryConf::new(1_000.0, 2.0, 4);
        assert_eq!(r.timeout_after(0), 1_000.0);
        assert_eq!(r.timeout_after(1), 2_000.0);
        assert_eq!(r.timeout_after(3), 8_000.0);
    }

    #[test]
    #[should_panic(expected = "timeout")]
    fn retry_conf_rejects_nan_timeout() {
        let _ = RetryConf::new(f64::NAN, 2.0, 3);
    }

    #[test]
    #[should_panic(expected = "backoff")]
    fn retry_conf_rejects_sub_unit_backoff() {
        let _ = RetryConf::new(1_000.0, 0.5, 3);
    }

    #[test]
    #[should_panic(expected = "at least one attempt")]
    fn retry_conf_rejects_zero_attempts() {
        let _ = RetryConf::new(1_000.0, 2.0, 0);
    }

    #[test]
    fn wire_events_clean_check() {
        let mut w = WireEvents { degraded_steps: vec![0, 0], ..WireEvents::default() };
        assert!(w.is_clean());
        w.retransmits = 1;
        assert!(!w.is_clean());
    }
}
