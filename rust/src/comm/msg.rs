//! Worker ↔ server message types (paper §5.1: "workers and servers
//! communicate through message passing"; the stub thread aggregates local
//! messages and forwards them to remote receivers).

use crate::tensor::Blob;

/// A parameter-plane message.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Register a parameter at the server (initial value + metadata).
    Put { param: String, value: Blob, lr_mult: f32, wd_mult: f32 },
    /// Gradient contribution from a worker group.
    Update { param: String, grad: Blob, step: u64 },
    /// Fetch the current value.
    Get { param: String },
    /// Server response to `Get` (or pushed fresh value after `Update`).
    Response { param: String, value: Blob, version: u64 },
}

impl Msg {
    /// Fixed per-message header bytes (metadata, routing ids).
    pub const HEADER: usize = 64;

    /// Wire size in bytes: payload + a fixed 64-byte header (metadata,
    /// routing ids). Drives the communication cost model.
    pub fn byte_size(&self) -> usize {
        match self {
            Msg::Put { param, value, .. } => Msg::put_wire_size(param, value),
            Msg::Update { param, grad, .. } => Msg::update_wire_size(param, grad),
            Msg::Get { param } => Msg::get_wire_size(param),
            Msg::Response { param, value, .. } => Msg::HEADER + param.len() + value.byte_size(),
        }
    }

    // Wire sizes computable WITHOUT materializing a message: the server's
    // `_into` fast path charges the ledger with these instead of cloning
    // payload blobs into `Msg`-owned fields just to measure them.

    /// Wire size of a `Put` registering `value` under `param`.
    pub fn put_wire_size(param: &str, value: &Blob) -> usize {
        Msg::HEADER + param.len() + value.byte_size()
    }

    /// Wire size of an `Update` carrying `grad` for `param`.
    pub fn update_wire_size(param: &str, grad: &Blob) -> usize {
        Msg::HEADER + param.len() + grad.byte_size()
    }

    /// Wire size of a `Get` for `param`.
    pub fn get_wire_size(param: &str) -> usize {
        Msg::HEADER + param.len()
    }

    /// Ledger accounting for the value flowing back to the worker: payload
    /// plus header (the name rides in the request echo, matching the
    /// historical `value.byte_size() + 64` server arithmetic).
    pub fn response_wire_size(value: &Blob) -> usize {
        Msg::HEADER + value.byte_size()
    }

    /// Wire bytes of one steady-state exchange round trip for a parameter
    /// of `payload_bytes`: gradient up + fresh value down, one header each
    /// (the historical `2 * bytes + 128` virtual-clock charge). Bucketed
    /// flushes sum this over their slots, so sequential and overlapped
    /// exchanges move identical byte totals and differ only in timing.
    pub fn exchange_wire_size(payload_bytes: usize) -> usize {
        2 * payload_bytes + 2 * Msg::HEADER
    }

    /// [`Msg::exchange_wire_size`] under a wire codec: the gradient goes up
    /// and the fresh value comes down as *encoded* chunks, one header each.
    /// `Codec::Raw` reproduces the historical charge exactly.
    pub fn exchange_wire_size_coded(codec: crate::comm::Codec, payload_bytes: usize) -> usize {
        2 * codec.wire_bytes(payload_bytes) + 2 * Msg::HEADER
    }

    /// [`Msg::exchange_wire_size_coded`] under the retry protocol: both
    /// directions ship CRC-framed self-describing chunks (every codec,
    /// `Raw` included — integrity needs the frame), so each direction pays
    /// the chunk plus the 8-byte integrity frame on top of its `Msg`
    /// header.
    pub fn exchange_wire_size_framed(codec: crate::comm::Codec, payload_bytes: usize) -> usize {
        2 * codec.framed_len(payload_bytes / 4) + 2 * Msg::HEADER
    }

    pub fn param(&self) -> &str {
        match self {
            Msg::Put { param, .. }
            | Msg::Update { param, .. }
            | Msg::Get { param }
            | Msg::Response { param, .. } => param,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_sizes() {
        let g = Msg::Get { param: "w".into() };
        assert_eq!(g.byte_size(), 65);
        let u = Msg::Update { param: "w".into(), grad: Blob::zeros(&[10]), step: 0 };
        assert_eq!(u.byte_size(), 64 + 1 + 40);
        assert_eq!(u.param(), "w");
    }

    /// The clone-free size helpers must agree with the sizes of the
    /// materialized messages they stand in for.
    #[test]
    fn wire_size_helpers_match_materialized_messages() {
        let v = Blob::zeros(&[7]);
        assert_eq!(
            Msg::put_wire_size("conv/w", &v),
            Msg::Put { param: "conv/w".into(), value: v.clone(), lr_mult: 1.0, wd_mult: 1.0 }
                .byte_size()
        );
        assert_eq!(
            Msg::update_wire_size("conv/w", &v),
            Msg::Update { param: "conv/w".into(), grad: v.clone(), step: 3 }.byte_size()
        );
        assert_eq!(
            Msg::get_wire_size("conv/w"),
            Msg::Get { param: "conv/w".into() }.byte_size()
        );
        assert_eq!(Msg::response_wire_size(&v), 64 + 28);
    }

    /// One exchange round trip = grad payload up + value payload down with
    /// a header each — the historical per-slot virtual-clock charge.
    #[test]
    fn exchange_wire_size_is_roundtrip_payload_plus_headers() {
        let v = Blob::zeros(&[10]); // 40 payload bytes
        assert_eq!(Msg::exchange_wire_size(v.byte_size()), 2 * 40 + 128);
        assert_eq!(Msg::exchange_wire_size(0), 128);
    }

    /// Coded exchange sizes: Raw matches the historical formula bit for
    /// bit; f16/int8 pay the compressed payload plus one chunk header per
    /// direction.
    #[test]
    fn coded_exchange_wire_sizes() {
        use crate::comm::codec::{Codec, CHUNK_HEADER};
        let payload = 40; // 10 f32 elements
        assert_eq!(
            Msg::exchange_wire_size_coded(Codec::Raw, payload),
            Msg::exchange_wire_size(payload)
        );
        assert_eq!(
            Msg::exchange_wire_size_coded(Codec::F16, payload),
            2 * (CHUNK_HEADER + 20) + 128
        );
        assert_eq!(
            Msg::exchange_wire_size_coded(Codec::Int8, payload),
            2 * (CHUNK_HEADER + 10) + 128
        );
    }

    /// Framed exchange sizes: every codec — Raw included — pays the chunk
    /// header plus the 8-byte integrity frame per direction once the retry
    /// protocol is armed.
    #[test]
    fn framed_exchange_wire_sizes() {
        use crate::comm::codec::{Codec, CHUNK_HEADER, FRAME_HEADER};
        let payload = 40; // 10 f32 elements
        assert_eq!(
            Msg::exchange_wire_size_framed(Codec::Raw, payload),
            2 * (FRAME_HEADER + CHUNK_HEADER + 40) + 128
        );
        assert_eq!(
            Msg::exchange_wire_size_framed(Codec::F16, payload),
            2 * (FRAME_HEADER + CHUNK_HEADER + 20) + 128
        );
        assert_eq!(
            Msg::exchange_wire_size_framed(Codec::Int8, payload),
            2 * (FRAME_HEADER + CHUNK_HEADER + 10) + 128
        );
    }
}
