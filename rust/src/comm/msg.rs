//! Worker ↔ server message types (paper §5.1: "workers and servers
//! communicate through message passing"; the stub thread aggregates local
//! messages and forwards them to remote receivers).

use crate::tensor::Blob;

/// A parameter-plane message.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Register a parameter at the server (initial value + metadata).
    Put { param: String, value: Blob, lr_mult: f32, wd_mult: f32 },
    /// Gradient contribution from a worker group.
    Update { param: String, grad: Blob, step: u64 },
    /// Fetch the current value.
    Get { param: String },
    /// Server response to `Get` (or pushed fresh value after `Update`).
    Response { param: String, value: Blob, version: u64 },
}

impl Msg {
    /// Wire size in bytes: payload + a fixed 64-byte header (metadata,
    /// routing ids). Drives the communication cost model.
    pub fn byte_size(&self) -> usize {
        const HEADER: usize = 64;
        match self {
            Msg::Put { param, value, .. } => HEADER + param.len() + value.byte_size(),
            Msg::Update { param, grad, .. } => HEADER + param.len() + grad.byte_size(),
            Msg::Get { param } => HEADER + param.len(),
            Msg::Response { param, value, .. } => HEADER + param.len() + value.byte_size(),
        }
    }

    pub fn param(&self) -> &str {
        match self {
            Msg::Put { param, .. }
            | Msg::Update { param, .. }
            | Msg::Get { param }
            | Msg::Response { param, .. } => param,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_sizes() {
        let g = Msg::Get { param: "w".into() };
        assert_eq!(g.byte_size(), 65);
        let u = Msg::Update { param: "w".into(), grad: Blob::zeros(&[10]), step: 0 };
        assert_eq!(u.byte_size(), 64 + 1 + 40);
        assert_eq!(u.param(), "w");
    }
}
