//! Wall-clock stopwatch plus simple statistics over repeated measurements.

use std::time::{Duration, Instant};

/// A restartable stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Streaming summary statistics (Welford) over f64 samples — used by the
/// bench harness to report mean/std/min/max per configuration.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Time a closure `iters` times, returning per-iteration stats in
/// milliseconds. `warmup` iterations are discarded first (paper §6.2.2
/// averages iterations 30–80 of 100 to skip the start/end phases; this is
/// the same idea).
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut st = Stats::new();
    for _ in 0..iters {
        let sw = Stopwatch::new();
        f();
        st.add(sw.elapsed_ms());
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn stats_single_sample() {
        let mut s = Stats::new();
        s.add(5.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.mean(), 5.0);
    }

    #[test]
    fn time_iters_counts() {
        let mut calls = 0;
        let st = time_iters(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.count(), 5);
        assert!(st.mean() >= 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_us();
        let b = sw.elapsed_us();
        assert!(b >= a);
    }
}
