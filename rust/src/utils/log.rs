//! Leveled stderr logging with a global verbosity switch.
//!
//! The coordinator runs many threads; messages are prefixed with the thread
//! name so worker/server interleavings stay readable.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global verbosity (e.g. from `-v` flags on the CLI).
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match VERBOSITY.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
        };
        let t = std::thread::current();
        eprintln!("[{tag} {}] {args}", t.name().unwrap_or("main"));
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::utils::log::log($crate::utils::log::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::utils::log::log($crate::utils::log::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::utils::log::log($crate::utils::log::Level::Debug, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::utils::log::log($crate::utils::log::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_check() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(prev);
    }
}
