//! Deterministic pseudo-random number generation (PCG-XSH-RR 64/32).
//!
//! All stochastic pieces of the system — parameter initialization, synthetic
//! dataset generation, dropout masks, SGD shuffling — draw from this PRNG so
//! every experiment is bit-reproducible from its seed. (The `rand` crate is
//! not available in the offline vendor set.)

/// PCG-XSH-RR 64/32 generator (O'Neill 2014). Small state, good statistical
/// quality, trivially seedable per worker/stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create a generator from a seed; `stream` lets workers share a seed
    /// while drawing independent sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Rng {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Rng {
        Rng::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// Uniform integer in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of iid `N(0, std)` samples (weight init).
    pub fn gaussian_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() * std).collect()
    }

    /// Vector of iid uniform samples in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_range(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(7, 1);
        let mut b = Rng::with_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_and_spread() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
