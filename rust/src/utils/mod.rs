//! Support substrates: JSON, PRNG, timing, logging, and a mini
//! property-testing harness.
//!
//! The build environment vendors only `xla` and `anyhow`, so everything a
//! production framework would normally pull from crates.io (serde, rand,
//! proptest, env_logger) is implemented here from scratch.

pub mod json;
pub mod rng;
pub mod timer;
pub mod log;
pub mod quickcheck;

pub use json::Json;
pub use rng::Rng;
pub use timer::Stopwatch;
