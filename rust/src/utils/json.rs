//! Minimal JSON value model, parser and writer.
//!
//! Used for the artifact manifest produced by `python/compile/aot.py` and
//! for job/cluster configuration files. Supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, booleans, null); numbers
//! are stored as `f64` which is sufficient for shapes, sizes and hyper-
//! parameters.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document. Trailing whitespace is allowed,
    /// trailing garbage is an error.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `["a","b"]` → `vec!["a","b"]` (non-strings skipped).
    pub fn str_vec(&self) -> Vec<String> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default()
    }

    /// Convenience: `[1,2,3]` → `vec![1,2,3]` (non-numbers skipped).
    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }

    /// Serialize compactly (no insignificant whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, e) in v.iter().enumerate() {
                    indent(out, depth + 1);
                    e.write_pretty(out, depth + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    indent(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers so call-sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i + 1..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let combined =
                                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        char::from_u32(combined)
                                    } else {
                                        // High surrogate followed by a
                                        // non-low-surrogate escape; without
                                        // the range check `lo - 0xDC00`
                                        // underflows.
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8 in string"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        // self.i points at 'u'; consume 4 hex digits after it.
        let s = self
            .b
            .get(self.i + 1..self.i + 5)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let st = std::str::from_utf8(s).map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(st, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"m":{"k":[1,2.5,true,null,"s\"t"]}}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("name", s("fig18a")),
            ("threads", arr(vec![num(1.0), num(2.0), num(4.0)])),
            ("nested", obj(vec![("empty", Json::Arr(vec![]))])),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn helpers() {
        let v = Json::parse(r#"{"dims":[2,3,4],"names":["a","b"]}"#).unwrap();
        assert_eq!(v.get("dims").unwrap().usize_vec(), vec![2, 3, 4]);
        assert_eq!(v.get("names").unwrap().str_vec(), vec!["a", "b"]);
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn error_offsets() {
        let e = Json::parse("{\"a\": @}").unwrap_err();
        assert_eq!(e.offset, 6);
    }

    #[test]
    fn malformed_inputs_error_instead_of_panicking() {
        for doc in [
            "",
            "-",
            "+1",
            "1e",
            "1e+",
            "-.",
            "1.2.3",
            "nul",
            "tru",
            "falsy",
            "[1",
            "{\"a\"",
            "{\"a\" 1}",
            r#""\q""#,
            r#""\u12"#,
            r#""\u12G4""#,
            r#""\ud800""#,
            r#""\ud800A""#,
            // High surrogate + non-surrogate escape: used to underflow in
            // the pair-combining arithmetic instead of erroring.
            r#""\ud800\u0041""#,
            r#""\udc00""#,
        ] {
            assert!(Json::parse(doc).is_err(), "accepted malformed input {doc:?}");
        }
    }
}
