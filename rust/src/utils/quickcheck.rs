//! Mini property-testing harness (proptest is not in the offline vendor
//! set). Generates random cases from a seeded [`Rng`], runs the property,
//! and on failure re-runs with a bisected "size" to report a smaller
//! counterexample where possible.
//!
//! Usage (`no_run`: doctest binaries bypass the crate's rpath config):
//! ```no_run
//! use singa::utils::quickcheck::{forall, prop_assert, Gen};
//! forall(100, |g| {
//!     let n = g.usize(1, 64);
//!     let v = g.f32_vec(n, -10.0, 10.0);
//!     let s: f32 = v.iter().sum();
//!     prop_assert(s.is_finite(), &format!("sum finite for n={n}"))
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Size hint in `[0,1]`: properties can scale their inputs by it so the
    /// harness can retry failures with smaller cases.
    pub size: f32,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        // Scale the upper bound by the current size hint (min lo+1 span).
        let span = ((hi - lo) as f32 * self.size).ceil() as usize + 1;
        lo + self.rng.below(span.min(hi - lo + 1))
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.rng.uniform_vec(n, lo, hi)
    }

    pub fn gaussian_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.gaussian_vec(n, std)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Result of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two f32 slices are elementwise close.
pub fn prop_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("{what}: idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Run `prop` against `cases` random cases. Panics with the seed and case
/// index on failure so the case is replayable; retries the failing seed at
/// smaller sizes first to report the smallest size that still fails.
pub fn forall<F: FnMut(&mut Gen) -> PropResult>(cases: u32, mut prop: F) {
    forall_seeded(0x5eed_cafe, cases, &mut prop);
}

pub fn forall_seeded<F: FnMut(&mut Gen) -> PropResult>(seed: u64, cases: u32, prop: &mut F) {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::with_stream(case_seed, 77), size: 1.0 };
        if let Err(msg) = prop(&mut g) {
            // Try smaller sizes with the same stream to shrink.
            let mut smallest: Option<(f32, String)> = None;
            for &size in &[0.1f32, 0.25, 0.5, 0.75] {
                let mut g = Gen { rng: Rng::with_stream(case_seed, 77), size };
                if let Err(m) = prop(&mut g) {
                    smallest = Some((size, m));
                    break;
                }
            }
            match smallest {
                Some((size, m)) => panic!(
                    "property failed (case {case}, seed {case_seed:#x}, shrunk to size {size}): {m}"
                ),
                None => panic!("property failed (case {case}, seed {case_seed:#x}): {msg}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(50, |g| {
            count += 1;
            let n = g.usize(0, 32);
            prop_assert(n <= 32, "bounded")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(20, |g| {
            let n = g.usize(0, 100);
            prop_assert(n < 5, "always small")
        });
    }

    #[test]
    fn prop_close_tolerances() {
        assert!(prop_close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0, "t").is_ok());
        assert!(prop_close(&[1.0], &[1.1], 1e-6, 1e-6, "t").is_err());
        assert!(prop_close(&[1.0, 2.0], &[1.0], 0.1, 0.0, "t").is_err());
        assert!(prop_close(&[100.0], &[100.5], 0.0, 0.01, "t").is_ok());
    }

    #[test]
    fn gen_usize_respects_bounds() {
        forall(200, |g| {
            let v = g.usize(3, 9);
            prop_assert((3..=9).contains(&v), &format!("v={v}"))
        });
    }
}
