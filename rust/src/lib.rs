//! # singa-rs — "Deep Learning At Scale and At Ease" (SINGA, 2016) in Rust + JAX + Pallas
//!
//! A reproduction of the SINGA distributed deep-learning platform as a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordinator: layer-abstraction programming
//!   model, worker/server groups, cluster topologies (Sandblaster, AllReduce,
//!   Downpour, Hogwild), neural-net partitioning (data / model / hybrid
//!   parallelism) with auto-inserted connection layers, and the paper's
//!   communication optimizations (reduced transfer + computation/
//!   communication overlap via async copy queues).
//! * **L2 (python/compile/model.py)** — JAX model step functions, AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`].
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, lowered inside the L2 functions.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

// Every unsafe operation must sit in an explicit `unsafe {}` block with its
// own `// SAFETY:` justification, even inside `unsafe fn` — enforced here by
// the compiler and cross-checked by `pallas_lint` (rule `unsafe-safety`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod utils;
pub mod tensor;
pub mod model;
pub mod train;
pub mod updater;
pub mod comm;
pub mod server;
pub mod cluster;
pub mod coordinator;
pub mod runtime;
pub mod data;
pub mod baselines;
pub mod metrics;
pub mod config;
pub mod bench;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
