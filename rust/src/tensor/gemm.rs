//! Blocked single-precision GEMM — the OpenBLAS stand-in for the native
//! backend. `C = alpha * op(A) @ op(B) + beta * C` with row-major storage.
//!
//! The kernel packs the operands into cache-friendly tiles and hands the
//! inner loop to the dispatching microkernel in [`super::kernel`]: the
//! scalar oracle (2-row register blocking the compiler auto-vectorizes —
//! the historical bit pattern) or, under `PALLAS_KERNEL=simd`, the
//! explicit AVX2/FMA register-tile kernel. The perf pass (EXPERIMENTS.md
//! §Perf) records the blocking iterations.
//!
//! # Intra-op parallelism
//!
//! [`gemm`] splits the MC-block (row-stripe) loop across the persistent
//! worker pool ([`crate::runtime::pool`]), each task owning a disjoint row
//! stripe of `C` (so writes need no synchronization) while sharing the
//! packed B panel read-only per `(kk, jj)` tile. The stripe partition
//! reuses [`Blob::split_range`] over whole MC blocks, so every row of `C`
//! is produced by exactly the same sequence of float operations as the
//! serial path — the output is **bit-for-bit identical for every thread
//! count** (pinned by property tests in `tests/properties.rs`). The task
//! count comes from [`crate::runtime::threads()`] (`PALLAS_NUM_THREADS`,
//! divided across active worker groups when unset); 1 runs the historical
//! serial loop on the caller thread, touching no pool machinery. Stripes
//! are fixed per *task index*, never per OS thread, so which pool worker
//! executes a stripe cannot affect the result.
//!
//! # Pack scratch
//!
//! The per-call `a_pack`/`b_pack` tile buffers live in a thread-local pool
//! owned by the *calling* thread (workers borrow caller-owned buffers), so
//! steady-state gemm calls perform zero pack allocations after the first
//! call warms the pool — the counter behind [`pack_alloc_count`] mirrors
//! the Blob allocation counter one level below the Blob layer.

use super::blob::Blob;
use super::kernel::{microkernel, scale8, KernelKind};
use crate::runtime::sync::{OrderedMutex, RANK_COMPUTE_STRIPE};
use std::cell::{Cell, RefCell};

/// Whether an operand is logically transposed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transpose {
    No,
    Yes,
}

// Pack op(A) row-major (m x k) and op(B) row-major (k x n) tile by tile.
// Tiles sized to keep the working set (~MC*KC + KC*NC floats) in L2.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 256;

/// Every pool buffer is sized for the largest tile (the KC x NC B panel) so
/// the pool can hand any buffer to any role without reallocating.
const PACK_LEN: usize = KC * NC;
const _: () = assert!(MC * KC <= PACK_LEN, "A tile must fit in a pool buffer");

thread_local! {
    /// Reusable pack buffers owned by this thread; buffer 0 serves the B
    /// panel, the rest serve per-worker A tiles.
    static PACK_POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) }; // lint: alloc-ok(empty pool, grown once per thread)
    /// Pack-buffer allocations made on behalf of this thread's gemm calls
    /// (pool growth only). The alloc probe diffs this across steady-state
    /// training steps, exactly like `Blob::alloc_count`.
    static PACK_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Pack-scratch allocations charged to the current thread so far. Workers
/// borrow caller-owned buffers, so a parallel gemm's allocations are all
/// visible on the calling thread's counter.
pub fn pack_alloc_count() -> u64 {
    PACK_ALLOCS.with(|c| c.get())
}

/// Move the thread-local pool out, grown to at least `min_bufs` buffers
/// (growth is the only pack allocation and is counted).
fn take_pool(min_bufs: usize) -> Vec<Vec<f32>> {
    let mut pool = PACK_POOL.with(|p| std::mem::take(&mut *p.borrow_mut()));
    while pool.len() < min_bufs {
        PACK_ALLOCS.with(|c| c.set(c.get() + 1));
        pool.push(vec![0.0f32; PACK_LEN]); // lint: alloc-ok(counted pool growth, warm-up only)
    }
    pool
}

/// Return the pool for the next call on this thread.
fn give_pool(pool: Vec<Vec<f32>>) {
    PACK_POOL.with(|p| *p.borrow_mut() = pool);
}

/// `C[m,n] = alpha * op(A)[m,k] @ op(B)[k,n] + beta * C[m,n]`.
///
/// `a` is `m x k` when `ta == No`, else `k x m` (and similarly for `b`).
/// All matrices are dense row-major slices. Runs on
/// [`crate::runtime::threads()`] intra-op workers; see
/// [`gemm_with_threads`] for the determinism contract.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    gemm_with_threads(ta, tb, m, n, k, alpha, a, b, beta, c, crate::runtime::threads());
}

/// [`gemm`] with an explicit worker count.
///
/// `threads == 1` is exactly the historical serial code path (no spawns).
/// Any other count splits whole MC row blocks across scoped workers with
/// [`Blob::split_range`]; because every `C` row still sees the identical
/// per-element operation sequence (same blocks, same `kk` panel order, same
/// kernel), the result is bit-for-bit identical to the serial path for
/// every thread count. The microkernel kind is resolved once on the
/// calling thread ([`crate::runtime::kernel`]) and shared by all workers,
/// so a single call never mixes kernel families.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_threads(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
    threads: usize,
) {
    let kind = crate::runtime::kernel();
    gemm_with_kernel(ta, tb, m, n, k, alpha, a, b, beta, c, threads, kind);
}

/// [`gemm_with_threads`] with an explicit microkernel kind — used by the
/// scalar-vs-simd probes and property tests to pin both families against
/// each other regardless of the process-wide `PALLAS_KERNEL` resolution.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_kernel(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
    threads: usize,
    kind: KernelKind,
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");

    if beta == 0.0 {
        c.iter_mut().for_each(|x| *x = 0.0);
    } else if beta != 1.0 {
        scale8(beta, c);
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }

    let mc_blocks = (m + MC - 1) / MC;
    let t = threads.max(1).min(mc_blocks);

    // Buffer 0 is the shared B panel; buffers 1..=t are per-task A tiles.
    let mut bufs = take_pool(t + 1);
    let (b_slot, a_slots) = bufs.split_at_mut(1);
    let b_pack = &mut b_slot[0];

    if t == 1 {
        // Serial path: identical iteration order to the historical
        // single-threaded kernel, run entirely on the caller thread.
        let a_pack = &mut a_slots[0];
        let mut kk = 0;
        while kk < k {
            let kb = KC.min(k - kk);
            let mut jj = 0;
            while jj < n {
                let nb = NC.min(n - jj);
                pack_b(tb, b, k, n, kk, jj, kb, nb, &mut b_pack[..]);
                let mut ii = 0;
                while ii < m {
                    let mb = MC.min(m - ii);
                    pack_a(ta, a, m, k, ii, kk, mb, kb, &mut a_pack[..]);
                    let c_tile = &mut c[ii * n + jj..];
                    microkernel(kind, mb, nb, kb, alpha, &a_pack[..], &b_pack[..], nb, c_tile, n);
                    ii += mb;
                }
                jj += nb;
            }
            kk += kb;
        }
    } else {
        // Parallel path: C is pre-split ONCE into contiguous runs of whole
        // MC blocks (one stripe + one A slot per task, each behind an
        // uncontended per-task mutex), then every (kk, jj) panel fans the
        // stripes out over the persistent pool. Stripe-local blocks
        // coincide with the serial blocks, so each row of C sees the
        // serial operation sequence exactly.
        // lint: alloc-ok(per-call stripe table of borrows, not Blob payloads)
        let mut stripes: Vec<OrderedMutex<(usize, usize, &mut [f32], &mut Vec<f32>)>> =
            Vec::with_capacity(t);
        {
            let mut rest: &mut [f32] = &mut c[..];
            let mut next_row = 0usize;
            let mut slots = a_slots.iter_mut();
            for tid in 0..t {
                let (bs, bc) = Blob::split_range(mc_blocks, t, tid);
                let rstart = bs * MC;
                let rcount = ((bs + bc) * MC).min(m) - rstart;
                debug_assert_eq!(rstart, next_row, "stripes must be contiguous");
                next_row += rcount;
                let (stripe, tail) = rest.split_at_mut(rcount * n);
                rest = tail;
                let a_pack = slots.next().expect("one A slot per task");
                stripes.push(OrderedMutex::new(
                    RANK_COMPUTE_STRIPE,
                    "gemm.stripe",
                    (rstart, rcount, stripe, a_pack),
                ));
            }
        }
        let mut kk = 0;
        while kk < k {
            let kb = KC.min(k - kk);
            let mut jj = 0;
            while jj < n {
                let nb = NC.min(n - jj);
                pack_b(tb, b, k, n, kk, jj, kb, nb, &mut b_pack[..]);
                let b_panel: &[f32] = &b_pack[..];
                crate::runtime::pool::run(t, |tid| {
                    let mut guard =
                        stripes[tid].try_lock().expect("each task owns its stripe");
                    let (rstart, rcount, stripe, a_pack) = &mut *guard;
                    let mut ii = 0;
                    while ii < *rcount {
                        let mb = MC.min(*rcount - ii);
                        pack_a(ta, a, m, k, *rstart + ii, kk, mb, kb, &mut a_pack[..]);
                        microkernel(
                            kind,
                            mb,
                            nb,
                            kb,
                            alpha,
                            &a_pack[..],
                            b_panel,
                            nb,
                            &mut stripe[ii * n + jj..],
                            n,
                        );
                        ii += mb;
                    }
                });
                jj += nb;
            }
            kk += kb;
        }
    }
    give_pool(bufs);
}

/// Pack a `mb x kb` tile of op(A) starting at (ii, kk) into row-major.
#[inline]
fn pack_a(
    ta: Transpose,
    a: &[f32],
    _m: usize,
    k: usize,
    ii: usize,
    kk: usize,
    mb: usize,
    kb: usize,
    out: &mut [f32],
) {
    match ta {
        Transpose::No => {
            for r in 0..mb {
                let src = (ii + r) * k + kk;
                out[r * kb..r * kb + kb].copy_from_slice(&a[src..src + kb]);
            }
        }
        Transpose::Yes => {
            // A is stored k x m; op(A)[r, c] = A[c, r].
            let m_stride = _m;
            for r in 0..mb {
                for c in 0..kb {
                    out[r * kb + c] = a[(kk + c) * m_stride + (ii + r)];
                }
            }
        }
    }
}

/// Pack a `kb x nb` tile of op(B) starting at (kk, jj) into row-major.
#[inline]
fn pack_b(
    tb: Transpose,
    b: &[f32],
    k: usize,
    n: usize,
    kk: usize,
    jj: usize,
    kb: usize,
    nb: usize,
    out: &mut [f32],
) {
    match tb {
        Transpose::No => {
            for r in 0..kb {
                let src = (kk + r) * n + jj;
                out[r * nb..r * nb + nb].copy_from_slice(&b[src..src + nb]);
            }
        }
        Transpose::Yes => {
            // B is stored n x k; op(B)[r, c] = B[c, r].
            let _ = n;
            for r in 0..kb {
                for c in 0..nb {
                    out[r * nb + c] = b[(jj + c) * k + (kk + r)];
                }
            }
        }
    }
}

/// Naive reference used by tests.
pub fn gemm_ref(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                let av = match ta {
                    Transpose::No => a[i * k + p],
                    Transpose::Yes => a[p * m + i],
                };
                let bv = match tb {
                    Transpose::No => b[p * n + j],
                    Transpose::Yes => b[j * k + p],
                };
                acc += av * bv;
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_close};

    fn check(ta: Transpose, tb: Transpose, m: usize, n: usize, k: usize, alpha: f32, beta: f32) {
        let mut rng = crate::utils::rng::Rng::new((m * 31 + n * 7 + k) as u64);
        let a = rng.uniform_vec(m * k, -1.0, 1.0);
        let b = rng.uniform_vec(k * n, -1.0, 1.0);
        let c0 = rng.uniform_vec(m * n, -1.0, 1.0);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c1);
        gemm_ref(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c2);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3 + 1e-4 * y.abs(), "{x} vs {y} (m={m} n={n} k={k})");
        }
    }

    #[test]
    fn small_exact() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1., 2., 3., 4.];
        let b = [1., 1., 1., 1.];
        let mut c = [0.0; 4];
        gemm(Transpose::No, Transpose::No, 2, 2, 2, 1.0, &a, &b, 0.0, &mut c);
        assert_eq!(c, [3., 3., 7., 7.]);
    }

    #[test]
    fn all_transpose_combos() {
        for &(ta, tb) in &[
            (Transpose::No, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::No),
            (Transpose::Yes, Transpose::Yes),
        ] {
            check(ta, tb, 5, 7, 3, 1.0, 0.0);
            check(ta, tb, 64, 64, 64, 1.0, 0.0);
        }
    }

    #[test]
    fn alpha_beta() {
        check(Transpose::No, Transpose::No, 8, 8, 8, 2.5, 0.5);
        check(Transpose::No, Transpose::No, 8, 8, 8, 0.0, 1.0);
        check(Transpose::Yes, Transpose::No, 13, 9, 17, -1.0, 2.0);
    }

    #[test]
    fn crosses_block_boundaries() {
        // Sizes straddling MC/KC/NC.
        check(Transpose::No, Transpose::No, 65, 257, 300, 1.0, 0.0);
        check(Transpose::No, Transpose::Yes, 70, 130, 260, 1.0, 1.0);
    }

    #[test]
    fn property_matches_reference() {
        forall(25, |g| {
            let m = g.usize(1, 40);
            let n = g.usize(1, 40);
            let k = g.usize(1, 40);
            let a = g.f32_vec(m * k, -1.0, 1.0);
            let b = g.f32_vec(k * n, -1.0, 1.0);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            gemm(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.0, &mut c1);
            gemm_ref(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.0, &mut c2);
            prop_close(&c1, &c2, 1e-3, 1e-4, "gemm vs ref")
        });
    }

    #[test]
    fn degenerate_dims() {
        let mut c = vec![5.0; 0];
        gemm(Transpose::No, Transpose::No, 0, 0, 0, 1.0, &[], &[], 0.0, &mut c);
        // k = 0 → C = beta*C
        let mut c = vec![2.0; 4];
        gemm(Transpose::No, Transpose::No, 2, 2, 0, 1.0, &[], &[], 0.5, &mut c);
        assert_eq!(c, [1.0; 4]);
    }

    /// alpha == 0 must reduce to C = beta*C without touching A/B (even for
    /// non-finite operands), for every beta class (0, 1, other).
    #[test]
    fn alpha_zero_is_pure_beta_scaling() {
        let a = [f32::NAN; 4];
        let b = [f32::INFINITY; 4];
        let mut c = vec![3.0; 4];
        gemm(Transpose::No, Transpose::No, 2, 2, 2, 0.0, &a, &b, 1.0, &mut c);
        assert_eq!(c, [3.0; 4], "beta=1 keeps C");
        gemm(Transpose::No, Transpose::No, 2, 2, 2, 0.0, &a, &b, 2.0, &mut c);
        assert_eq!(c, [6.0; 4], "beta=2 doubles C");
        gemm(Transpose::No, Transpose::No, 2, 2, 2, 0.0, &a, &b, 0.0, &mut c);
        assert_eq!(c, [0.0; 4], "beta=0 zeroes C");
    }

    /// Empty-dimension cases for every (m, n, k) zero pattern: output must
    /// still be exactly beta*C and never read out of bounds.
    #[test]
    fn empty_dims_apply_beta_only() {
        for &(m, n, k) in &[(0usize, 3usize, 2usize), (3, 0, 2), (3, 3, 0), (0, 0, 5)] {
            let a = vec![1.0f32; m * k];
            let b = vec![1.0f32; k * n];
            let mut c = vec![4.0f32; m * n];
            gemm(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.25, &mut c);
            assert!(c.iter().all(|&v| v == 1.0), "(m,n,k)=({m},{n},{k}): {c:?}");
        }
    }

    /// Thread counts {2, 4, 7} must produce output `==`-identical to the
    /// serial path on sizes that straddle every block boundary (the full
    /// random-matrix determinism sweep lives in `tests/properties.rs`).
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = crate::utils::rng::Rng::new(0xdead);
        for &(m, n, k) in &[
            (65usize, 257usize, 300usize),
            (129, 64, 257),
            (191, 31, 511),
            (256, 40, 70),
            (64, 5, 5),
            (1, 1, 1),
        ] {
            let a = rng.uniform_vec(m * k, -1.0, 1.0);
            let b = rng.uniform_vec(k * n, -1.0, 1.0);
            let c0 = rng.uniform_vec(m * n, -1.0, 1.0);
            for &(alpha, beta) in &[(1.0f32, 0.0f32), (2.5, -0.5), (-1.0, 1.0)] {
                let mut serial = c0.clone();
                gemm_with_threads(
                    Transpose::No, Transpose::No, m, n, k, alpha, &a, &b, beta, &mut serial, 1,
                );
                for &t in &[2usize, 4, 7] {
                    let mut par = c0.clone();
                    gemm_with_threads(
                        Transpose::No, Transpose::No, m, n, k, alpha, &a, &b, beta, &mut par, t,
                    );
                    assert!(
                        par == serial,
                        "threads={t} differs from serial (m={m} n={n} k={k} alpha={alpha} beta={beta})"
                    );
                }
            }
        }
    }

    /// Degenerate dims and alpha == 0 short-circuit identically under any
    /// thread count (the early-outs run before any worker is spawned).
    #[test]
    fn parallel_degenerate_dims_apply_beta_only() {
        for &t in &[1usize, 2, 7] {
            for &(m, n, k) in &[(0usize, 3usize, 2usize), (3, 0, 2), (3, 3, 0), (0, 0, 5)] {
                let a = vec![1.0f32; m * k];
                let b = vec![1.0f32; k * n];
                let mut c = vec![4.0f32; m * n];
                gemm_with_threads(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.25, &mut c, t);
                assert!(c.iter().all(|&v| v == 1.0), "t={t} (m,n,k)=({m},{n},{k}): {c:?}");
            }
            let a = [f32::NAN; 4];
            let b = [f32::INFINITY; 4];
            let mut c = vec![3.0f32; 4];
            gemm_with_threads(Transpose::No, Transpose::No, 2, 2, 2, 0.0, &a, &b, 1.0, &mut c, t);
            assert_eq!(c, [3.0; 4], "t={t}: alpha=0 must not touch A/B");
        }
    }

    /// The pack pool settles after warm-up: steady-state gemm calls (serial
    /// and parallel, mixed sizes) perform zero pack allocations on this
    /// thread, and shrinking the thread count never re-allocates.
    #[test]
    fn pack_scratch_settles_after_warmup() {
        let mut rng = crate::utils::rng::Rng::new(0xf00d);
        let n = 100;
        let a = rng.uniform_vec(n * n, -1.0, 1.0);
        let b = rng.uniform_vec(n * n, -1.0, 1.0);
        let mut c = vec![0.0f32; n * n];
        // Warm up at the largest thread count used below.
        gemm_with_threads(Transpose::No, Transpose::No, n, n, n, 1.0, &a, &b, 0.0, &mut c, 4);
        let before = pack_alloc_count();
        for &t in &[1usize, 2, 4, 1, 4] {
            for &sz in &[16usize, 100] {
                gemm_with_threads(
                    Transpose::No,
                    Transpose::No,
                    sz,
                    sz,
                    sz,
                    1.0,
                    &a[..sz * sz],
                    &b[..sz * sz],
                    0.0,
                    &mut c[..sz * sz],
                    t,
                );
            }
        }
        assert_eq!(
            pack_alloc_count(),
            before,
            "steady-state gemm must not allocate pack scratch"
        );
    }

    /// The simd kernel family must approximate the scalar oracle across
    /// block-straddling sizes, transposes, and alpha/beta classes. Skips
    /// (with a notice) when the host lacks AVX2+FMA — the knob degrades to
    /// scalar there and equality is trivial.
    #[test]
    fn simd_matches_scalar_oracle() {
        if !crate::tensor::kernel::simd_supported() {
            eprintln!("NOTICE: AVX2+FMA not detected; skipping simd-vs-scalar gemm test");
            return;
        }
        let mut rng = crate::utils::rng::Rng::new(0x51d);
        for &(ta, tb) in &[
            (Transpose::No, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::No),
            (Transpose::Yes, Transpose::Yes),
        ] {
            for &(m, n, k) in &[(5usize, 7usize, 3usize), (64, 64, 64), (65, 257, 300), (33, 9, 70)]
            {
                let a = rng.uniform_vec(m * k, -1.0, 1.0);
                let b = rng.uniform_vec(k * n, -1.0, 1.0);
                let c0 = rng.uniform_vec(m * n, -1.0, 1.0);
                for &(alpha, beta) in &[(1.0f32, 0.0f32), (2.5, -0.5), (-1.0, 1.0)] {
                    let mut cs = c0.clone();
                    gemm_with_kernel(
                        ta, tb, m, n, k, alpha, &a, &b, beta, &mut cs, 1, KernelKind::Scalar,
                    );
                    let mut cv = c0.clone();
                    gemm_with_kernel(
                        ta, tb, m, n, k, alpha, &a, &b, beta, &mut cv, 1, KernelKind::Simd,
                    );
                    for (i, (x, y)) in cv.iter().zip(&cs).enumerate() {
                        assert!(
                            (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                            "idx={i}: {x} vs {y} (m={m} n={n} k={k} ta={ta:?} tb={tb:?})"
                        );
                    }
                }
            }
        }
    }

    /// Within the simd family the thread-count determinism contract holds
    /// just like for scalar: stripes see the same per-element op sequence,
    /// so every count reproduces the serial simd output bit-for-bit.
    #[test]
    fn simd_parallel_is_bit_identical_to_simd_serial() {
        if !crate::tensor::kernel::simd_supported() {
            eprintln!("NOTICE: AVX2+FMA not detected; skipping simd determinism test");
            return;
        }
        let mut rng = crate::utils::rng::Rng::new(0x51d2);
        for &(m, n, k) in &[(65usize, 257usize, 300usize), (129, 64, 257), (256, 40, 70)] {
            let a = rng.uniform_vec(m * k, -1.0, 1.0);
            let b = rng.uniform_vec(k * n, -1.0, 1.0);
            let c0 = rng.uniform_vec(m * n, -1.0, 1.0);
            let mut serial = c0.clone();
            gemm_with_kernel(
                Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.5, &mut serial, 1,
                KernelKind::Simd,
            );
            for &t in &[2usize, 4, 7] {
                let mut par = c0.clone();
                gemm_with_kernel(
                    Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 0.5, &mut par, t,
                    KernelKind::Simd,
                );
                assert!(par == serial, "simd threads={t} differs (m={m} n={n} k={k})");
            }
        }
    }

    /// Random alpha/beta (including 0, 1, negatives) and all transpose
    /// combos must match the reference kernel.
    #[test]
    fn property_alpha_beta_transpose_matches_reference() {
        forall(40, |g| {
            let m = g.usize(1, 24);
            let n = g.usize(1, 24);
            let k = g.usize(1, 24);
            let alpha = *g.choose(&[0.0f32, 1.0, -1.0, 2.5, 0.3]);
            let beta = *g.choose(&[0.0f32, 1.0, -0.5, 2.0]);
            let ta = if g.bool() { Transpose::Yes } else { Transpose::No };
            let tb = if g.bool() { Transpose::Yes } else { Transpose::No };
            let a = g.f32_vec(m * k, -1.0, 1.0);
            let b = g.f32_vec(k * n, -1.0, 1.0);
            let c0 = g.f32_vec(m * n, -1.0, 1.0);
            let mut c1 = c0.clone();
            let mut c2 = c0;
            gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c1);
            gemm_ref(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c2);
            prop_close(&c1, &c2, 1e-3, 1e-3, "gemm alpha/beta vs ref")
        });
    }
}
