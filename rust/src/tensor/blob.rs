//! `Blob`: the dense n-d f32 tensor flowing between layers.
//!
//! Mirrors the paper's Fig 6: every layer owns feature/gradient blobs and
//! `Param` objects wrap a pair of blobs. The first dimension is by
//! convention the batch dimension (paper §5.3 "every layer's feature blob is
//! considered a matrix whose rows are feature vectors"), so partitioning
//! support is expressed as row/column slice + concat (dim 0 / dim 1).

use crate::utils::rng::Rng;
use std::cell::Cell;
use std::fmt;

thread_local! {
    /// Count of non-empty blob buffer allocations made by this thread
    /// (constructors, clones, and growing `resize`s). The bench harness
    /// diffs this across training steps to prove the planned executor's
    /// steady state is allocation-free.
    static BLOB_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn note_alloc(len: usize) {
    if len > 0 {
        BLOB_ALLOCS.with(|c| c.set(c.get() + 1));
    }
}

/// Dense row-major f32 tensor.
#[derive(PartialEq)]
pub struct Blob {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Clone for Blob {
    fn clone(&self) -> Blob {
        note_alloc(self.data.len());
        Blob { shape: self.shape.clone(), data: self.data.clone() }
    }
}

/// An empty placeholder blob (used by `std::mem::take` when the executor
/// temporarily moves workspace slots out for disjoint mutable access).
impl Default for Blob {
    fn default() -> Blob {
        Blob { shape: Vec::new(), data: Vec::new() }
    }
}

impl fmt::Debug for Blob {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Blob{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, "{:?}", self.data)?;
        }
        Ok(())
    }
}

impl Blob {
    /// Blob buffer allocations made by the current thread so far (see the
    /// steady-state allocation probe in [`crate::bench`]).
    pub fn alloc_count() -> u64 {
        BLOB_ALLOCS.with(|c| c.get())
    }

    /// Zero-filled blob.
    pub fn zeros(shape: &[usize]) -> Blob {
        let n: usize = shape.iter().product();
        note_alloc(n);
        Blob { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Constant-filled blob.
    pub fn full(shape: &[usize], v: f32) -> Blob {
        let n: usize = shape.iter().product();
        note_alloc(n);
        Blob { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Blob from existing data (length must match shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Blob {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        note_alloc(data.len());
        Blob { shape: shape.to_vec(), data }
    }

    /// Gaussian-initialized blob (weight init).
    pub fn gaussian(shape: &[usize], std: f32, rng: &mut Rng) -> Blob {
        let n: usize = shape.iter().product();
        note_alloc(n);
        Blob { shape: shape.to_vec(), data: rng.gaussian_vec(n, std) }
    }

    /// Uniform-initialized blob.
    pub fn uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Blob {
        let n: usize = shape.iter().product();
        note_alloc(n);
        Blob { shape: shape.to_vec(), data: rng.uniform_vec(n, lo, hi) }
    }

    /// Reshape in place, reallocating only when the element count outgrows
    /// the existing capacity (shrinks and re-grows within capacity are
    /// allocation-free, so alternating train/eval batch sizes settle after
    /// one cycle). Elements appended beyond the previous length are zero;
    /// contents up to the previous length are preserved — every caller
    /// overwrites (or zero-fills) the buffer before reading it. A no-op at
    /// steady state.
    pub fn resize(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        if self.data.len() != n {
            if n > self.data.capacity() {
                note_alloc(n);
            }
            self.data.resize(n, 0.0);
        }
        if self.shape != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
    }

    /// Copy `other`'s contents into this blob (shapes must already agree in
    /// element count; this blob adopts `other`'s shape). No allocation when
    /// the length matches.
    pub fn copy_from(&mut self, other: &Blob) {
        self.resize(other.shape());
        self.data.copy_from_slice(&other.data);
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as a matrix (dim 0; batch dimension).
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[0]
        }
    }

    /// Number of columns when viewed as a matrix (product of dims 1..).
    pub fn cols(&self) -> usize {
        if self.shape.len() <= 1 {
            if self.shape.is_empty() { 1 } else { self.data.len() / self.shape[0].max(1) }
        } else {
            self.shape[1..].iter().product()
        }
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret the shape without touching data.
    pub fn reshape(&self, shape: &[usize]) -> Blob {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        Blob { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// `self += other` (shape-checked).
    pub fn add_assign(&mut self, other: &Blob) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Blob) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    /// Sum of elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Mean of elements (0 for empty).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f32
        }
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Size in bytes when serialized over the (simulated) wire — used by the
    /// communication cost model (§5.4.1).
    pub fn byte_size(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    // ---- Partitioning primitives (paper §5.3, Fig 12) ----

    /// Slice rows `[start, start+count)` (batch-dimension partitioning;
    /// partition_dim = 0).
    pub fn slice_rows(&self, start: usize, count: usize) -> Blob {
        let cols = self.cols();
        let rows = self.rows();
        assert!(start + count <= rows, "slice_rows {start}+{count} > {rows}");
        let mut shape = self.shape.clone();
        shape[0] = count;
        Blob {
            shape,
            data: self.data[start * cols..(start + count) * cols].to_vec(),
        }
    }

    /// Slice columns `[start, start+count)` of the matrix view (feature-
    /// dimension partitioning; partition_dim = 1). Result is 2-d.
    pub fn slice_cols(&self, start: usize, count: usize) -> Blob {
        let rows = self.rows();
        let cols = self.cols();
        assert!(start + count <= cols, "slice_cols {start}+{count} > {cols}");
        let mut data = Vec::with_capacity(rows * count);
        for r in 0..rows {
            let base = r * cols + start;
            data.extend_from_slice(&self.data[base..base + count]);
        }
        Blob { shape: vec![rows, count], data }
    }

    /// Concatenate along dim 0 (rows). Inverse of `slice_rows` over even
    /// splits; used by ConcatLayer.
    pub fn concat_rows(parts: &[&Blob]) -> Blob {
        assert!(!parts.is_empty());
        let cols = parts[0].cols();
        let mut shape = parts[0].shape.clone();
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            assert_eq!(p.cols(), cols, "concat_rows column mismatch");
            rows += p.rows();
            data.extend_from_slice(&p.data);
        }
        shape[0] = rows;
        Blob { shape, data }
    }

    /// Concatenate along dim 1 (columns of the matrix view). Result is 2-d.
    pub fn concat_cols(parts: &[&Blob]) -> Blob {
        assert!(!parts.is_empty());
        let rows = parts[0].rows();
        let total_cols: usize = parts.iter().map(|p| p.cols()).sum();
        let mut data = Vec::with_capacity(rows * total_cols);
        for r in 0..rows {
            for p in parts {
                assert_eq!(p.rows(), rows, "concat_cols row mismatch");
                let c = p.cols();
                data.extend_from_slice(&p.data[r * c..(r + 1) * c]);
            }
        }
        Blob { shape: vec![rows, total_cols], data }
    }

    /// `(start, count)` of part `i` of `total` split into `k` even parts —
    /// the allocation-free point query behind [`Blob::split_points`].
    pub fn split_range(total: usize, k: usize, i: usize) -> (usize, usize) {
        assert!(k > 0 && i < k);
        let base = total / k;
        let extra = total % k;
        let start = i * base + i.min(extra);
        (start, base + usize::from(i < extra))
    }

    /// Even split points for partitioning `total` into `k` parts: the first
    /// `total % k` parts get one extra element (paper: mini-batch 256 into 2
    /// sub-layers of 128 each).
    pub fn split_points(total: usize, k: usize) -> Vec<(usize, usize)> {
        assert!(k > 0);
        let base = total / k;
        let extra = total % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let count = base + usize::from(i < extra);
            out.push((start, count));
            start += count;
        }
        out
    }
}

/// A learnable parameter: value + gradient blobs plus versioning metadata
/// used by the parameter server (paper Fig 6 `Param`).
#[derive(Debug, Clone)]
pub struct Param {
    /// Global name, e.g. `"conv1/weight"`. Sub-layer params share a prefix
    /// with a slice suffix (e.g. `"fc1/weight@1of2"`).
    pub name: String,
    pub data: Blob,
    pub grad: Blob,
    /// Version incremented by the server on every update; workers use it to
    /// detect staleness in asynchronous frameworks.
    pub version: u64,
    /// Multiplier on the learning rate (paper convention: bias terms often
    /// train at 2x the weight LR).
    pub lr_mult: f32,
    /// L2 regularization multiplier.
    pub wd_mult: f32,
}

impl Param {
    pub fn new(name: &str, data: Blob) -> Param {
        let grad = Blob::zeros(data.shape());
        Param { name: name.to_string(), data, grad, version: 0, lr_mult: 1.0, wd_mult: 1.0 }
    }

    pub fn with_lr_mult(mut self, m: f32) -> Param {
        self.lr_mult = m;
        self
    }

    pub fn with_wd_mult(mut self, m: f32) -> Param {
        self.wd_mult = m;
        self
    }

    /// Number of scalar parameters.
    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// Plain SGD step `data -= lr * lr_mult * grad`, fused and in place.
    /// Replaces the old aliasing workaround (`p.grad.clone()` + `axpy`) that
    /// update loops needed because `data` and `grad` live in one struct.
    pub fn sgd_step(&mut self, lr: f32) {
        let step = lr * self.lr_mult;
        for (w, g) in self.data.data_mut().iter_mut().zip(self.grad.data()) {
            *w -= step * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_assert, prop_close};

    #[test]
    fn construction_and_views() {
        let b = Blob::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.cols(), 3);
        assert_eq!(b.len(), 6);
        assert_eq!(b.byte_size(), 24);
        let r = b.reshape(&[3, 2]);
        assert_eq!(r.rows(), 3);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn bad_shape_panics() {
        Blob::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn arithmetic() {
        let mut a = Blob::full(&[2, 2], 1.0);
        let b = Blob::full(&[2, 2], 2.0);
        a.add_assign(&b);
        assert_eq!(a.data(), &[3.0; 4]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[4.0; 4]);
        a.scale(0.25);
        assert_eq!(a.data(), &[1.0; 4]);
        assert_eq!(a.sum(), 4.0);
        assert_eq!(a.mean(), 1.0);
        assert!((a.norm() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn slicing_rows() {
        let b = Blob::from_vec(&[4, 2], (0..8).map(|x| x as f32).collect());
        let s = b.slice_rows(1, 2);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2., 3., 4., 5.]);
    }

    #[test]
    fn slicing_cols() {
        let b = Blob::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let s = b.slice_cols(1, 2);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2., 3., 5., 6.]);
    }

    #[test]
    fn concat_inverts_slice_rows() {
        forall(50, |g| {
            let rows = g.usize(1, 12);
            let cols = g.usize(1, 8);
            let k = g.usize(1, rows);
            let b = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -1.0, 1.0));
            let parts: Vec<Blob> = Blob::split_points(rows, k)
                .into_iter()
                .map(|(s, c)| b.slice_rows(s, c))
                .collect();
            let refs: Vec<&Blob> = parts.iter().collect();
            let back = Blob::concat_rows(&refs);
            prop_close(back.data(), b.data(), 0.0, 0.0, "roundtrip rows")
        });
    }

    #[test]
    fn concat_inverts_slice_cols() {
        forall(50, |g| {
            let rows = g.usize(1, 8);
            let cols = g.usize(1, 12);
            let k = g.usize(1, cols);
            let b = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -1.0, 1.0));
            let parts: Vec<Blob> = Blob::split_points(cols, k)
                .into_iter()
                .map(|(s, c)| b.slice_cols(s, c))
                .collect();
            let refs: Vec<&Blob> = parts.iter().collect();
            let back = Blob::concat_cols(&refs);
            prop_close(back.data(), b.data(), 0.0, 0.0, "roundtrip cols")
        });
    }

    #[test]
    fn split_points_cover_exactly() {
        forall(100, |g| {
            let total = g.usize(1, 100);
            let k = g.usize(1, 16);
            let pts = Blob::split_points(total, k);
            let covered: usize = pts.iter().map(|&(_, c)| c).sum();
            prop_assert(pts.len() == k && covered == total, "coverage")?;
            // contiguity
            let mut pos = 0;
            for &(s, c) in &pts {
                prop_assert(s == pos, "contiguous")?;
                pos = s + c;
            }
            Ok(())
        });
    }

    #[test]
    fn split_points_balanced() {
        let pts = Blob::split_points(256, 2);
        assert_eq!(pts, vec![(0, 128), (128, 128)]);
        let pts = Blob::split_points(10, 3);
        assert_eq!(pts, vec![(0, 4), (4, 3), (7, 3)]);
    }

    #[test]
    fn split_range_matches_split_points() {
        forall(100, |g| {
            let total = g.usize(1, 100);
            let k = g.usize(1, 16);
            let pts = Blob::split_points(total, k);
            for (i, &pt) in pts.iter().enumerate() {
                prop_assert(Blob::split_range(total, k, i) == pt, "range == points")?;
            }
            Ok(())
        });
    }

    #[test]
    fn resize_reallocates_only_on_growth_beyond_capacity() {
        let mut b = Blob::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let before = Blob::alloc_count();
        b.resize(&[3, 2]); // same length: pure metadata change
        assert_eq!(Blob::alloc_count(), before);
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.data()[0], 1.0, "same-length resize preserves data");
        b.resize(&[4, 2]); // grows past capacity: one allocation
        assert_eq!(Blob::alloc_count(), before + 1);
        assert_eq!(b.len(), 8);
        assert_eq!(&b.data()[6..], &[0.0, 0.0], "appended tail is zero");
        // Shrink and re-grow within the retained capacity: no allocation.
        b.resize(&[2, 2]);
        b.resize(&[4, 2]);
        assert_eq!(Blob::alloc_count(), before + 1, "capacity reuse is free");
    }

    #[test]
    fn copy_from_reuses_buffer() {
        let src = Blob::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let mut dst = Blob::zeros(&[4]);
        let before = Blob::alloc_count();
        dst.copy_from(&src);
        assert_eq!(Blob::alloc_count(), before);
        assert_eq!(dst.shape(), &[2, 2]);
        assert_eq!(dst.data(), src.data());
    }

    #[test]
    fn sgd_step_matches_axpy_workaround() {
        let mut p = Param::new("w", Blob::full(&[3], 1.0)).with_lr_mult(2.0);
        p.grad = Blob::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let mut expect = p.data.clone();
        let g = p.grad.clone();
        expect.axpy(-0.1 * p.lr_mult, &g);
        let before = Blob::alloc_count();
        p.sgd_step(0.1);
        assert_eq!(Blob::alloc_count(), before, "sgd_step must not allocate");
        assert_eq!(p.data.data(), expect.data());
    }

    #[test]
    fn param_metadata() {
        let p = Param::new("fc/w", Blob::zeros(&[3, 4])).with_lr_mult(2.0).with_wd_mult(0.0);
        assert_eq!(p.size(), 12);
        assert_eq!(p.lr_mult, 2.0);
        assert_eq!(p.wd_mult, 0.0);
        assert_eq!(p.grad.shape(), &[3, 4]);
        assert_eq!(p.version, 0);
    }
}
