//! Native tensor math library — the role OpenBLAS + Mshadow play in the
//! paper (§6.2.1): dense f32 blobs plus the linear-algebra and neural-net
//! primitives the built-in layers need.
//!
//! This is the `NativeBackend` compute substrate. The production hot loop
//! runs AOT-compiled XLA executables instead (see [`crate::runtime`]); the
//! native path is the reference implementation, the engine for partitioning
//! experiments with configuration-dependent shapes, and the baseline for
//! the op-level-parallelism comparisons in Fig 18(a) — which it now backs
//! with real intra-op parallelism: [`gemm`] and the conv transforms
//! ([`conv::im2col`] / [`conv::col2im_acc`]) fan out over the persistent
//! worker pool ([`crate::runtime::pool`]) on
//! [`crate::runtime::threads()`] tasks, with bit-identical output at every
//! thread count. Their inner loops dispatch through [`kernel`] — scalar
//! oracle by default, explicit AVX2/FMA microkernels under
//! `PALLAS_KERNEL=simd` (see [`crate::runtime::kernel`]).

pub mod blob;
pub mod gemm;
pub mod kernel;
pub mod ops;
pub mod conv;

pub use blob::Blob;
pub use gemm::{gemm, gemm_with_threads, Transpose};
pub use kernel::KernelKind;
