//! Convolution and pooling primitives via im2col — the approach the paper
//! adopts from Caffe (§6.2.1 "Caffe's im2col and pooling code is adopted").
//!
//! Layout: images are `[batch, channels, height, width]` row-major.
//!
//! # Intra-op parallelism
//!
//! With the GEMM threaded, the im2col/col2im transforms are the remaining
//! single-threaded hot spots, so they stripe over the same persistent pool
//! ([`crate::runtime::pool`]) under the same contract as
//! [`super::gemm::gemm`]: work is partitioned by *task index* into regions
//! that are disjoint on both the read-accumulate and write side —
//! [`im2col`] by whole rows of the column matrix (pure scattered reads,
//! disjoint output rows), [`col2im_acc`] by whole channels (each channel
//! accumulates only into its own image plane, in the serial loop order) —
//! so the output is **bit-for-bit identical to serial at every thread
//! count** (pinned by property tests in `tests/properties.rs`). The task
//! count comes from [`crate::runtime::threads()`]; the `*_with_threads`
//! variants take it explicitly, and `1` runs the historical serial loops
//! on the caller thread with no pool machinery touched.
//!
//! # Kernel dispatch
//!
//! Under `PALLAS_KERNEL=simd` ([`crate::runtime::kernel`]) the transforms
//! take a span-structured fast path: per output row the valid `ox` range
//! is one contiguous source span (stride 1 copies/accumulates it through
//! the AVX2 span kernels in [`super::kernel`]; larger strides use a
//! branch-free gather), zeros elsewhere. Copies and lane-independent adds
//! reorder no floating-point arithmetic, so the simd transforms stay
//! **bitwise identical** to the scalar oracle — pinned by exact-equality
//! tests here and in `tests/properties.rs`.

use super::blob::Blob;
use super::gemm::{gemm_with_threads, Transpose};
use super::kernel::{add_span, copy_span, KernelKind};
use crate::runtime::sync::{OrderedMutex, RANK_COMPUTE_STRIPE};

/// Static geometry of a conv/pool operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Conv2dGeom {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kernel) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kernel) / self.stride + 1
    }

    /// Rows of the im2col matrix = kernel*kernel*in_c.
    pub fn col_rows(&self) -> usize {
        self.in_c * self.kernel * self.kernel
    }

    /// Cols of the im2col matrix = out_h*out_w.
    pub fn col_cols(&self) -> usize {
        self.out_h() * self.out_w()
    }
}

/// One-shot striped dispatch shared by the parallel conv transforms: split
/// `out` into `tasks` contiguous chunks — chunk `i` spanning
/// `Blob::split_range(units, tasks, i)` units of `unit_len` elements each —
/// and run `f(unit_start, unit_count, chunk)` once per task on the
/// persistent pool. Each chunk sits behind its own mutex locked by exactly
/// one task, so the locks are uncontended and the writes disjoint.
fn run_striped(
    out: &mut [f32],
    units: usize,
    unit_len: usize,
    tasks: usize,
    f: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    let mut stripes: Vec<OrderedMutex<(usize, usize, &mut [f32])>> = Vec::with_capacity(tasks);
    let mut rest: &mut [f32] = out;
    let mut next = 0usize;
    for tid in 0..tasks {
        let (u0, un) = Blob::split_range(units, tasks, tid);
        debug_assert_eq!(u0, next, "stripes must be contiguous");
        next = u0 + un;
        let (chunk, tail) = rest.split_at_mut(un * unit_len);
        rest = tail;
        stripes.push(OrderedMutex::new(RANK_COMPUTE_STRIPE, "conv.stripe", (u0, un, chunk)));
    }
    crate::runtime::pool::run(tasks, |tid| {
        let mut guard = stripes[tid].try_lock().expect("each task owns its stripe");
        let (u0, un, chunk) = &mut *guard;
        f(*u0, *un, chunk);
    });
}

/// Unfold one image `[C,H,W]` into the im2col matrix
/// `[C*k*k, out_h*out_w]` (zero padding outside the image). Runs on
/// [`crate::runtime::threads()`] intra-op tasks; see the module docs for
/// the determinism contract.
pub fn im2col(img: &[f32], g: &Conv2dGeom, out: &mut [f32]) {
    im2col_with_threads(img, g, out, crate::runtime::threads());
}

/// [`im2col`] with an explicit task count. Tasks own disjoint stripes of
/// whole column-matrix rows; every row is a pure gather written by exactly
/// one task in the serial order, so the result is `==`-identical to
/// `threads == 1` for every count.
pub fn im2col_with_threads(img: &[f32], g: &Conv2dGeom, out: &mut [f32], threads: usize) {
    im2col_with_kernel(img, g, out, threads, crate::runtime::kernel());
}

/// [`im2col_with_threads`] with an explicit microkernel kind (probes and
/// scalar-vs-simd equality tests). Both kinds produce bitwise-identical
/// output; the kind only selects the execution strategy.
pub fn im2col_with_kernel(
    img: &[f32],
    g: &Conv2dGeom,
    out: &mut [f32],
    threads: usize,
    kind: KernelKind,
) {
    assert_eq!(img.len(), g.in_c * g.in_h * g.in_w, "im2col input size");
    assert_eq!(out.len(), g.col_rows() * g.col_cols(), "im2col output size");
    let rows = g.col_rows();
    let cc = g.col_cols();
    let t = threads.max(1).min(rows.max(1));
    if t == 1 {
        im2col_rows(img, g, 0, rows, out, kind);
        return;
    }
    run_striped(out, rows, cc, t, |r0, rc, chunk| im2col_rows(img, g, r0, rc, chunk, kind));
}

/// Write rows `[row0, row0 + rows)` of the im2col matrix into `out`, whose
/// first element corresponds to row `row0`. Row `(c*k + ky)*k + kx` gathers
/// kernel offset `(ky, kx)` of channel `c` — the exact loop order of the
/// historical serial transform.
fn im2col_rows(
    img: &[f32],
    g: &Conv2dGeom,
    row0: usize,
    rows: usize,
    out: &mut [f32],
    kind: KernelKind,
) {
    if kind == KernelKind::Simd {
        im2col_rows_spans(img, g, row0, rows, out);
        return;
    }
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.kernel;
    for r in 0..rows {
        let row = row0 + r;
        let c = row / (k * k);
        let rem = row % (k * k);
        let (ky, kx) = (rem / k, rem % k);
        let base = r * oh * ow;
        for oy in 0..oh {
            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
            for ox in 0..ow {
                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                out[base + oy * ow + ox] = if iy >= 0
                    && (iy as usize) < g.in_h
                    && ix >= 0
                    && (ix as usize) < g.in_w
                {
                    img[c * g.in_h * g.in_w + iy as usize * g.in_w + ix as usize]
                } else {
                    0.0
                };
            }
        }
    }
}

/// Valid output-x range `[lo, hi)` for kernel offset `kx`: the `ox` whose
/// source column `ix = ox*stride + kx - pad` lands inside `[0, in_w)`,
/// clamped to `[0, ow)`. Returns `(lo, hi, shift)` with `shift = kx - pad`
/// so `ix = ox*stride + shift`.
fn ox_span(g: &Conv2dGeom, kx: usize, ow: usize) -> (usize, usize, isize) {
    let s = g.stride as isize;
    let shift = kx as isize - g.pad as isize;
    let lo = if shift >= 0 { 0 } else { ((-shift + s - 1) / s) as usize };
    let last = g.in_w as isize - 1 - shift;
    let hi = if last < 0 { 0 } else { (last / s + 1) as usize };
    let lo = lo.min(ow);
    (lo, hi.clamp(lo, ow), shift)
}

/// Span-structured [`im2col_rows`] for the simd path: zeros outside the
/// valid span, one contiguous copy (stride 1) or branch-free gather
/// inside it. Values are exactly the scalar gather's, written in the same
/// left-to-right order per row.
fn im2col_rows_spans(img: &[f32], g: &Conv2dGeom, row0: usize, rows: usize, out: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.kernel;
    let s = g.stride as isize;
    for r in 0..rows {
        let row = row0 + r;
        let c = row / (k * k);
        let rem = row % (k * k);
        let (ky, kx) = (rem / k, rem % k);
        let (lo, hi, shift) = ox_span(g, kx, ow);
        let plane = &img[c * g.in_h * g.in_w..(c + 1) * g.in_h * g.in_w];
        let base = r * oh * ow;
        for oy in 0..oh {
            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
            let dst = &mut out[base + oy * ow..base + oy * ow + ow];
            if iy < 0 || iy as usize >= g.in_h || hi <= lo {
                dst.fill(0.0);
                continue;
            }
            let src = &plane[iy as usize * g.in_w..(iy as usize + 1) * g.in_w];
            dst[..lo].fill(0.0);
            dst[hi..].fill(0.0);
            if g.stride == 1 {
                let i0 = (lo as isize + shift) as usize;
                copy_span(KernelKind::Simd, &src[i0..i0 + (hi - lo)], &mut dst[lo..hi]);
            } else {
                for (d, ox) in dst[lo..hi].iter_mut().zip(lo..hi) {
                    *d = src[(ox as isize * s + shift) as usize];
                }
            }
        }
    }
}

/// Fold an im2col matrix back into image gradients (transpose of `im2col`,
/// accumulating where patches overlap).
pub fn col2im(col: &[f32], g: &Conv2dGeom, img: &mut [f32]) {
    col2im_with_threads(col, g, img, crate::runtime::threads());
}

/// [`col2im`] with an explicit task count.
pub fn col2im_with_threads(col: &[f32], g: &Conv2dGeom, img: &mut [f32], threads: usize) {
    img.iter_mut().for_each(|v| *v = 0.0);
    col2im_acc_with_threads(col, g, img, threads);
}

/// `col2im` without the zero prologue: accumulates into `img`, which the
/// planned executor hands over already zeroed (and possibly already holding
/// sibling consumers' gradient contributions). Runs on
/// [`crate::runtime::threads()`] intra-op tasks.
pub fn col2im_acc(col: &[f32], g: &Conv2dGeom, img: &mut [f32]) {
    col2im_acc_with_threads(col, g, img, crate::runtime::threads());
}

/// [`col2im_acc`] with an explicit task count. Tasks own disjoint stripes
/// of whole *channels*: channel `c` reads only column rows
/// `[c*k*k, (c+1)*k*k)` and accumulates only into its own image plane, in
/// the serial `(ky, kx, oy, ox)` order, so every image pixel receives the
/// identical addition sequence for every count — `==`-identical to
/// `threads == 1`.
pub fn col2im_acc_with_threads(col: &[f32], g: &Conv2dGeom, img: &mut [f32], threads: usize) {
    col2im_acc_with_kernel(col, g, img, threads, crate::runtime::kernel());
}

/// [`col2im_acc_with_threads`] with an explicit microkernel kind. Both
/// kinds accumulate in the identical `(ky, kx, oy, ox)` order, so the
/// output is bitwise the same.
pub fn col2im_acc_with_kernel(
    col: &[f32],
    g: &Conv2dGeom,
    img: &mut [f32],
    threads: usize,
    kind: KernelKind,
) {
    let t = threads.max(1).min(g.in_c.max(1));
    if t == 1 {
        col2im_channels(col, g, 0, g.in_c, img, kind);
        return;
    }
    let plane = g.in_h * g.in_w;
    run_striped(img, g.in_c, plane, t, |c0, cn, chunk| {
        col2im_channels(col, g, c0, cn, chunk, kind)
    });
}

/// Accumulate channels `[c0, c0 + channels)` of the column matrix into
/// `img`, whose first element is the first pixel of channel `c0`'s plane —
/// the historical serial loop restricted to a channel range.
fn col2im_channels(
    col: &[f32],
    g: &Conv2dGeom,
    c0: usize,
    channels: usize,
    img: &mut [f32],
    kind: KernelKind,
) {
    if kind == KernelKind::Simd {
        col2im_channels_spans(col, g, c0, channels, img);
        return;
    }
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.kernel;
    let plane = g.in_h * g.in_w;
    for ci in 0..channels {
        let c = c0 + ci;
        for ky in 0..k {
            for kx in 0..k {
                let base = ((c * k + ky) * k + kx) * oh * ow;
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for ox in 0..ow {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if iy >= 0 && (iy as usize) < g.in_h && ix >= 0 && (ix as usize) < g.in_w
                        {
                            img[ci * plane + iy as usize * g.in_w + ix as usize] +=
                                col[base + oy * ow + ox];
                        }
                    }
                }
            }
        }
    }
}

/// Span-structured [`col2im_channels`] for the simd path. Every image
/// pixel receives the same additions in the same `(ky, kx, oy, ox)` order
/// as the scalar loop (within one row each destination is touched at most
/// once, so the 8-wide lane adds reorder nothing) — bitwise identical.
fn col2im_channels_spans(col: &[f32], g: &Conv2dGeom, c0: usize, channels: usize, img: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.kernel;
    let s = g.stride as isize;
    let plane = g.in_h * g.in_w;
    for ci in 0..channels {
        let c = c0 + ci;
        let dst = &mut img[ci * plane..(ci + 1) * plane];
        for ky in 0..k {
            for kx in 0..k {
                let base = ((c * k + ky) * k + kx) * oh * ow;
                let (lo, hi, shift) = ox_span(g, kx, ow);
                if hi <= lo {
                    continue;
                }
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    if iy < 0 || iy as usize >= g.in_h {
                        continue;
                    }
                    let srow = &col[base + oy * ow + lo..base + oy * ow + hi];
                    let drow = &mut dst[iy as usize * g.in_w..(iy as usize + 1) * g.in_w];
                    if g.stride == 1 {
                        let i0 = (lo as isize + shift) as usize;
                        add_span(KernelKind::Simd, srow, &mut drow[i0..i0 + (hi - lo)]);
                    } else {
                        for (v, ox) in srow.iter().zip(lo..hi) {
                            drow[(ox as isize * s + shift) as usize] += v;
                        }
                    }
                }
            }
        }
    }
}

/// Reusable scratch for the batched-GEMM convolution path. Owned by the
/// `ConvolutionLayer` so the big packed operands are allocated once and
/// reused every step.
#[derive(Default)]
pub struct ConvScratch {
    bigcol: Vec<f32>,
    bigout: Vec<f32>,
    dcol: Vec<f32>,
}

impl ConvScratch {
    pub fn new() -> ConvScratch {
        ConvScratch::default()
    }
}

fn ensure_len(v: &mut Vec<f32>, n: usize) {
    if v.len() != n {
        v.clear();
        v.resize(n, 0.0);
    }
}

/// Forward convolution into a caller-provided output: input `[B,C,H,W]`,
/// weight `[out_c, C*k*k]`, bias `[out_c]` → output `[B, out_c, oh, ow]`
/// (resized). The per-image im2col buffers are written into `cols` for
/// reuse in the backward pass; all buffers are reused across calls. The
/// im2col transforms and the batched GEMM run on
/// [`crate::runtime::threads()`] intra-op tasks.
pub fn conv2d_forward_into(
    input: &Blob,
    weight: &Blob,
    bias: &Blob,
    g: &Conv2dGeom,
    out: &mut Blob,
    cols: &mut Vec<Vec<f32>>,
    scratch: &mut ConvScratch,
) {
    conv2d_forward_into_with_threads(
        input,
        weight,
        bias,
        g,
        out,
        cols,
        scratch,
        crate::runtime::threads(),
    );
}

/// [`conv2d_forward_into`] with an explicit task count (used by the conv
/// scaling probe to pin serial-vs-parallel bit-identity and throughput).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_into_with_threads(
    input: &Blob,
    weight: &Blob,
    bias: &Blob,
    g: &Conv2dGeom,
    out: &mut Blob,
    cols: &mut Vec<Vec<f32>>,
    scratch: &mut ConvScratch,
    threads: usize,
) {
    let b = input.shape()[0];
    let out_c = weight.shape()[0];
    let (oh, ow) = (g.out_h(), g.out_w());
    let img_len = g.in_c * g.in_h * g.in_w;
    let (cr, cc) = (g.col_rows(), g.col_cols());
    out.resize(&[b, out_c, oh, ow]);
    if cols.len() != b {
        cols.resize_with(b, Vec::new);
    }
    // Batch all images into ONE wide GEMM: W [out_c, cr] @ bigcol
    // [cr, b*cc]. The weight pack is amortized across the whole batch
    // (perf pass, EXPERIMENTS.md §Perf L3 iteration 5).
    ensure_len(&mut scratch.bigcol, cr * b * cc);
    for (i, col) in cols.iter_mut().enumerate() {
        ensure_len(col, cr * cc);
        im2col_with_threads(&input.data()[i * img_len..(i + 1) * img_len], g, col, threads);
        for r in 0..cr {
            scratch.bigcol[r * b * cc + i * cc..r * b * cc + (i + 1) * cc]
                .copy_from_slice(&col[r * cc..(r + 1) * cc]);
        }
    }
    ensure_len(&mut scratch.bigout, out_c * b * cc);
    gemm_with_threads(
        Transpose::No,
        Transpose::No,
        out_c,
        b * cc,
        cr,
        1.0,
        weight.data(),
        &scratch.bigcol,
        0.0,
        &mut scratch.bigout,
        threads,
    );
    for i in 0..b {
        let dst = &mut out.data_mut()[i * out_c * cc..(i + 1) * out_c * cc];
        for oc in 0..out_c {
            let bv = bias.data()[oc];
            let src = &scratch.bigout[oc * b * cc + i * cc..oc * b * cc + (i + 1) * cc];
            for (d, s) in dst[oc * cc..(oc + 1) * cc].iter_mut().zip(src) {
                *d = s + bv;
            }
        }
    }
}

/// Forward convolution (allocating wrapper over [`conv2d_forward_into`]).
pub fn conv2d_forward(
    input: &Blob,
    weight: &Blob,
    bias: &Blob,
    g: &Conv2dGeom,
) -> (Blob, Vec<Vec<f32>>) {
    let mut out = Blob::default();
    let mut cols = Vec::new(); // lint: alloc-ok(allocating wrapper, not the steady-state _into path)
    let mut scratch = ConvScratch::new();
    conv2d_forward_into(input, weight, bias, g, &mut out, &mut cols, &mut scratch);
    (out, cols)
}

/// Backward convolution, ACCUMULATING (`+=`) into the provided gradient
/// buffers: `d_weight [out_c, cr]`, `d_bias [out_c]` and (when wanted) the
/// input-gradient slot `d_input` (same element count as `input`).
pub fn conv2d_backward_acc(
    input: &Blob,
    weight: &Blob,
    grad_out: &Blob,
    cols: &[Vec<f32>],
    g: &Conv2dGeom,
    mut d_input: Option<&mut Blob>,
    d_weight: &mut Blob,
    d_bias: &mut Blob,
    scratch: &mut ConvScratch,
) {
    let b = input.shape()[0];
    let out_c = weight.shape()[0];
    let (cr, cc) = (g.col_rows(), g.col_cols());
    let img_len = g.in_c * g.in_h * g.in_w;
    let threads = crate::runtime::threads();
    ensure_len(&mut scratch.dcol, cr * cc);

    for i in 0..b {
        let go = &grad_out.data()[i * out_c * cc..(i + 1) * out_c * cc];
        // dW += dOut [out_c, cc] @ col^T [cc, cr]
        gemm_with_threads(
            Transpose::No,
            Transpose::Yes,
            out_c,
            cr,
            cc,
            1.0,
            go,
            &cols[i],
            1.0,
            d_weight.data_mut(),
            threads,
        );
        if let Some(dx) = d_input.as_deref_mut() {
            // d_col = W^T [cr, out_c] @ dOut [out_c, cc]
            gemm_with_threads(
                Transpose::Yes,
                Transpose::No,
                cr,
                cc,
                out_c,
                1.0,
                weight.data(),
                go,
                0.0,
                &mut scratch.dcol,
                threads,
            );
            col2im_acc_with_threads(
                &scratch.dcol,
                g,
                &mut dx.data_mut()[i * img_len..(i + 1) * img_len],
                threads,
            );
        }
        for oc in 0..out_c {
            d_bias.data_mut()[oc] += go[oc * cc..(oc + 1) * cc].iter().sum::<f32>();
        }
    }
}

/// Backward convolution: returns (d_input, d_weight, d_bias) — allocating
/// wrapper over [`conv2d_backward_acc`].
pub fn conv2d_backward(
    input: &Blob,
    weight: &Blob,
    grad_out: &Blob,
    cols: &[Vec<f32>],
    g: &Conv2dGeom,
) -> (Blob, Blob, Blob) {
    let out_c = weight.shape()[0];
    let mut d_input = Blob::zeros(input.shape());
    let mut d_weight = Blob::zeros(weight.shape());
    let mut d_bias = Blob::zeros(&[out_c]);
    let mut scratch = ConvScratch::new();
    conv2d_backward_acc(
        input,
        weight,
        grad_out,
        cols,
        g,
        Some(&mut d_input),
        &mut d_weight,
        &mut d_bias,
        &mut scratch,
    );
    (d_input, d_weight, d_bias)
}

/// Max-pool forward into caller-provided output and argmax buffers (both
/// resized; no allocation at steady state).
pub fn maxpool_forward_into(input: &Blob, g: &Conv2dGeom, out: &mut Blob, arg: &mut Vec<usize>) {
    let b = input.shape()[0];
    let (oh, ow) = (g.out_h(), g.out_w());
    let img_len = g.in_c * g.in_h * g.in_w;
    out.resize(&[b, g.in_c, oh, ow]);
    if arg.len() != b * g.in_c * oh * ow {
        arg.clear();
        arg.resize(b * g.in_c * oh * ow, 0);
    }
    for i in 0..b {
        for c in 0..g.in_c {
            let plane = &input.data()[i * img_len + c * g.in_h * g.in_w..];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0;
                    for ky in 0..g.kernel {
                        let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                        if iy < 0 || iy as usize >= g.in_h {
                            continue;
                        }
                        for kx in 0..g.kernel {
                            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                            if ix < 0 || ix as usize >= g.in_w {
                                continue;
                            }
                            let idx = iy as usize * g.in_w + ix as usize;
                            if plane[idx] > best {
                                best = plane[idx];
                                best_idx = idx;
                            }
                        }
                    }
                    let o = ((i * g.in_c + c) * oh + oy) * ow + ox;
                    out.data_mut()[o] = best;
                    arg[o] = i * img_len + c * g.in_h * g.in_w + best_idx;
                }
            }
        }
    }
}

/// Max-pool forward: input `[B,C,H,W]` → (output, argmax indices).
pub fn maxpool_forward(input: &Blob, g: &Conv2dGeom) -> (Blob, Vec<usize>) {
    let mut out = Blob::default();
    let mut arg = Vec::new(); // lint: alloc-ok(allocating wrapper, not the steady-state _into path)
    maxpool_forward_into(input, g, &mut out, &mut arg);
    (out, arg)
}

/// Max-pool backward, ACCUMULATING output grads onto the argmax positions
/// of an already-initialized input-gradient slot.
pub fn maxpool_backward_acc(grad_out: &Blob, arg: &[usize], d_input: &mut Blob) {
    for (o, &src) in arg.iter().enumerate() {
        d_input.data_mut()[src] += grad_out.data()[o];
    }
}

/// Max-pool backward: scatter output grads to the argmax positions.
pub fn maxpool_backward(input_shape: &[usize], grad_out: &Blob, arg: &[usize]) -> Blob {
    let mut d_input = Blob::zeros(input_shape);
    maxpool_backward_acc(grad_out, arg, &mut d_input);
    d_input
}

/// Average-pool forward into a caller-provided output (resized).
pub fn avgpool_forward_into(input: &Blob, g: &Conv2dGeom, out: &mut Blob) {
    let b = input.shape()[0];
    let (oh, ow) = (g.out_h(), g.out_w());
    let img_len = g.in_c * g.in_h * g.in_w;
    out.resize(&[b, g.in_c, oh, ow]);
    let k2 = (g.kernel * g.kernel) as f32;
    for i in 0..b {
        for c in 0..g.in_c {
            let plane = &input.data()[i * img_len + c * g.in_h * g.in_w..];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0;
                    for ky in 0..g.kernel {
                        let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                        if iy < 0 || iy as usize >= g.in_h {
                            continue;
                        }
                        for kx in 0..g.kernel {
                            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                            if ix < 0 || ix as usize >= g.in_w {
                                continue;
                            }
                            acc += plane[iy as usize * g.in_w + ix as usize];
                        }
                    }
                    out.data_mut()[((i * g.in_c + c) * oh + oy) * ow + ox] = acc / k2;
                }
            }
        }
    }
}

/// Average-pool forward.
pub fn avgpool_forward(input: &Blob, g: &Conv2dGeom) -> Blob {
    let mut out = Blob::default();
    avgpool_forward_into(input, g, &mut out);
    out
}

/// Local response normalization into a caller-provided output (resized).
pub fn lrn_forward_into(input: &Blob, size: usize, alpha: f32, beta: f32, k: f32, out: &mut Blob) {
    let (b, c, h, w) = nchw(input);
    out.copy_from(input);
    let plane = h * w;
    for i in 0..b {
        for y in 0..plane {
            for ch in 0..c {
                let lo = ch.saturating_sub(size / 2);
                let hi = (ch + size / 2 + 1).min(c);
                let mut acc = 0.0;
                for cc in lo..hi {
                    let v = input.data()[(i * c + cc) * plane + y];
                    acc += v * v;
                }
                let denom = (k + alpha / size as f32 * acc).powf(beta);
                out.data_mut()[(i * c + ch) * plane + y] /= denom;
            }
        }
    }
}

/// Local response normalization across channels (AlexNet §3.3):
/// `b[c] = a[c] / (k + alpha/n * sum_{c'} a[c']^2)^beta`.
pub fn lrn_forward(input: &Blob, size: usize, alpha: f32, beta: f32, k: f32) -> Blob {
    let mut out = Blob::default();
    lrn_forward_into(input, size, alpha, beta, k, &mut out);
    out
}

fn nchw(x: &Blob) -> (usize, usize, usize, usize) {
    let s = x.shape();
    assert_eq!(s.len(), 4, "expected NCHW blob, got {s:?}");
    (s[0], s[1], s[2], s[3])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_close};
    use crate::utils::rng::Rng;

    fn geom(c: usize, h: usize, w: usize, k: usize, s: usize, p: usize) -> Conv2dGeom {
        Conv2dGeom { in_c: c, in_h: h, in_w: w, kernel: k, stride: s, pad: p }
    }

    #[test]
    fn geometry() {
        let g = geom(3, 32, 32, 5, 1, 2);
        assert_eq!(g.out_h(), 32);
        assert_eq!(g.out_w(), 32);
        let g = geom(3, 32, 32, 3, 2, 0);
        assert_eq!(g.out_h(), 15);
    }

    #[test]
    fn im2col_identity_kernel() {
        // k=1, s=1, p=0 → im2col is the identity on each channel plane.
        let g = geom(2, 3, 3, 1, 1, 0);
        let img: Vec<f32> = (0..18).map(|x| x as f32).collect();
        let mut col = vec![0.0; g.col_rows() * g.col_cols()];
        im2col(&img, &g, &mut col);
        assert_eq!(col, img);
    }

    #[test]
    fn im2col_known_patch() {
        let g = geom(1, 3, 3, 2, 1, 0);
        let img = [1., 2., 3., 4., 5., 6., 7., 8., 9.];
        let mut col = vec![0.0; g.col_rows() * g.col_cols()];
        im2col(&img, &g, &mut col);
        // rows are kernel positions, cols are the 4 output locations
        assert_eq!(col[0..4], [1., 2., 4., 5.]); // ky=0,kx=0
        assert_eq!(col[4..8], [2., 3., 5., 6.]); // ky=0,kx=1
        assert_eq!(col[8..12], [4., 5., 7., 8.]); // ky=1,kx=0
        assert_eq!(col[12..16], [5., 6., 8., 9.]); // ky=1,kx=1
    }

    #[test]
    fn conv_forward_known_value() {
        // 1x1 input channel, 3x3 image of ones, 2x2 kernel of ones → each
        // output = 4 + bias.
        let g = geom(1, 3, 3, 2, 1, 0);
        let input = Blob::full(&[1, 1, 3, 3], 1.0);
        let weight = Blob::full(&[1, 4], 1.0);
        let bias = Blob::from_vec(&[1], vec![0.5]);
        let (out, _) = conv2d_forward(&input, &weight, &bias, &g);
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        assert_eq!(out.data(), &[4.5; 4]);
    }

    /// Convolution gradient check against numerical differentiation.
    #[test]
    fn conv_backward_numerical() {
        let g = geom(2, 5, 5, 3, 1, 1);
        let mut rng = Rng::new(77);
        let input = Blob::from_vec(&[2, 2, 5, 5], rng.uniform_vec(100, -1.0, 1.0));
        let out_c = 3;
        let weight = Blob::from_vec(&[out_c, g.col_rows()], rng.uniform_vec(out_c * g.col_rows(), -0.5, 0.5));
        let bias = Blob::zeros(&[out_c]);

        // Scalar objective: sum of outputs.
        let f = |input: &Blob, weight: &Blob| -> f32 {
            conv2d_forward(input, weight, &bias, &g).0.sum()
        };

        let (out, cols) = conv2d_forward(&input, &weight, &bias, &g);
        let grad_out = Blob::full(out.shape(), 1.0);
        let (d_in, d_w, d_b) = conv2d_backward(&input, &weight, &grad_out, &cols, &g);

        let eps = 1e-2;
        // spot-check 12 coordinates of d_input
        for i in (0..input.len()).step_by(input.len() / 12) {
            let mut p = input.clone();
            p.data_mut()[i] += eps;
            let mut m = input.clone();
            m.data_mut()[i] -= eps;
            let num = (f(&p, &weight) - f(&m, &weight)) / (2.0 * eps);
            assert!(
                (num - d_in.data()[i]).abs() < 2e-2,
                "d_input[{i}]: numeric {num} vs {}",
                d_in.data()[i]
            );
        }
        // spot-check d_weight
        for i in (0..weight.len()).step_by(weight.len() / 12) {
            let mut p = weight.clone();
            p.data_mut()[i] += eps;
            let mut m = weight.clone();
            m.data_mut()[i] -= eps;
            let num = (f(&input, &p) - f(&input, &m)) / (2.0 * eps);
            assert!(
                (num - d_w.data()[i]).abs() < 5e-2,
                "d_weight[{i}]: numeric {num} vs {}",
                d_w.data()[i]
            );
        }
        // bias gradient is just the count of output positions per channel
        let per_c = 2.0 * (g.out_h() * g.out_w()) as f32;
        for &v in d_b.data() {
            assert!((v - per_c).abs() < 1e-3);
        }
    }

    /// Fixed geometries straddling the stripe boundaries: every task count
    /// must reproduce the serial im2col/col2im output bit-for-bit (the
    /// random-geometry sweep lives in `tests/properties.rs`).
    #[test]
    fn parallel_im2col_and_col2im_bit_identical_to_serial() {
        let mut rng = Rng::new(0xc0de);
        for &(c, h, w, k, s, p) in &[
            (3usize, 8usize, 8usize, 3usize, 1usize, 1usize),
            (16, 7, 5, 3, 2, 0),
            (1, 12, 12, 5, 1, 2), // single channel: col2im degenerates to serial
            (2, 3, 3, 3, 1, 0),   // kernel == image
        ] {
            let g = geom(c, h, w, k, s, p);
            let img = rng.uniform_vec(c * h * w, -1.0, 1.0);
            let n = g.col_rows() * g.col_cols();
            let mut col_serial = vec![0.0; n];
            im2col_with_threads(&img, &g, &mut col_serial, 1);
            let colm = rng.uniform_vec(n, -1.0, 1.0);
            let img0 = rng.uniform_vec(c * h * w, -1.0, 1.0);
            let mut acc_serial = img0.clone();
            col2im_acc_with_threads(&colm, &g, &mut acc_serial, 1);
            for &t in &[2usize, 4, 7] {
                let mut col_t = vec![0.0; n];
                im2col_with_threads(&img, &g, &mut col_t, t);
                assert!(col_t == col_serial, "im2col t={t} differs (c={c} h={h} k={k})");
                let mut acc_t = img0.clone();
                col2im_acc_with_threads(&colm, &g, &mut acc_t, t);
                assert!(acc_t == acc_serial, "col2im_acc t={t} differs (c={c} h={h} k={k})");
            }
        }
    }

    /// The simd span transforms must reproduce the scalar oracle bitwise
    /// (copies and lane-independent adds reorder no arithmetic), across
    /// strides, pads, kernel-larger-than-pad, and task counts.
    #[test]
    fn simd_transforms_bit_identical_to_scalar() {
        if !crate::tensor::kernel::simd_supported() {
            eprintln!("NOTICE: AVX2+FMA not detected; exercising the span path via scalar spans");
        }
        // The span path runs either way: the span kernels re-check
        // detection and degrade to scalar lanes, staying bitwise equal.
        let kind = KernelKind::Simd;
        let mut rng = Rng::new(0x51dc);
        for &(c, h, w, k, s, p) in &[
            (3usize, 8usize, 8usize, 3usize, 1usize, 1usize),
            (2, 9, 13, 5, 1, 2),
            (16, 7, 5, 3, 2, 0),
            (4, 11, 6, 3, 2, 1),
            (1, 12, 12, 5, 1, 4), // pad close to kernel: wide zero borders
            (2, 3, 3, 3, 1, 0),   // kernel == image
            (3, 6, 40, 5, 1, 2),  // wide rows: full 8-lane spans
        ] {
            let g = geom(c, h, w, k, s, p);
            let img = rng.uniform_vec(c * h * w, -1.0, 1.0);
            let n = g.col_rows() * g.col_cols();
            let mut col_scalar = vec![0.0; n];
            im2col_with_kernel(&img, &g, &mut col_scalar, 1, KernelKind::Scalar);
            let colm = rng.uniform_vec(n, -1.0, 1.0);
            let img0 = rng.uniform_vec(c * h * w, -1.0, 1.0);
            let mut acc_scalar = img0.clone();
            col2im_acc_with_kernel(&colm, &g, &mut acc_scalar, 1, KernelKind::Scalar);
            for &t in &[1usize, 2, 4, 7] {
                let mut col_v = vec![0.0; n];
                im2col_with_kernel(&img, &g, &mut col_v, t, kind);
                assert!(col_v == col_scalar, "im2col simd t={t} differs (c={c} h={h} k={k} s={s})");
                let mut acc_v = img0.clone();
                col2im_acc_with_kernel(&colm, &g, &mut acc_v, t, kind);
                assert!(acc_v == acc_scalar, "col2im simd t={t} differs (c={c} h={h} k={k} s={s})");
            }
        }
    }

    /// The span bounds must agree with the per-element predicate for every
    /// kernel offset, including spans clamped empty.
    #[test]
    fn ox_span_matches_predicate() {
        for &(h, w, k, s, p) in &[
            (8usize, 8usize, 3usize, 1usize, 1usize),
            (7, 5, 3, 2, 0),
            (9, 4, 3, 2, 2),
            (12, 12, 5, 1, 4),
            (5, 3, 3, 1, 0),
            (6, 2, 1, 3, 0),
        ] {
            let g = geom(1, h, w, k, s, p);
            let ow = g.out_w();
            for kx in 0..k {
                let (lo, hi, shift) = ox_span(&g, kx, ow);
                assert!(lo <= hi && hi <= ow, "span bounds (k={k} s={s} p={p} kx={kx})");
                for ox in 0..ow {
                    let ix = (ox * s + kx) as isize - p as isize;
                    let valid = ix >= 0 && (ix as usize) < w;
                    assert_eq!(
                        valid,
                        ox >= lo && ox < hi,
                        "kx={kx} ox={ox} (k={k} s={s} p={p} shift={shift})"
                    );
                }
            }
        }
    }

    /// Degenerate shapes (zero channels → empty matrices) must short-circuit
    /// identically under any task count.
    #[test]
    fn parallel_conv_transforms_handle_empty_shapes() {
        let g = geom(0, 3, 3, 1, 1, 0);
        for &t in &[1usize, 2, 7] {
            let mut col: Vec<f32> = Vec::new();
            im2col_with_threads(&[], &g, &mut col, t);
            let mut img: Vec<f32> = Vec::new();
            col2im_acc_with_threads(&[], &g, &mut img, t);
        }
    }

    #[test]
    fn col2im_is_im2col_transpose() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y (adjoint test).
        forall(20, |g_| {
            let c = g_.usize(1, 3);
            let h = g_.usize(3, 7);
            let k = g_.usize(1, 3.min(h));
            let g = geom(c, h, h, k, 1, g_.usize(0, 1));
            let x = g_.f32_vec(c * h * h, -1.0, 1.0);
            let y = g_.f32_vec(g.col_rows() * g.col_cols(), -1.0, 1.0);
            let mut cx = vec![0.0; y.len()];
            im2col(&x, &g, &mut cx);
            let mut ty = vec![0.0; x.len()];
            col2im(&y, &g, &mut ty);
            let lhs: f32 = cx.iter().zip(&y).map(|(a, b)| a * b).sum();
            let rhs: f32 = x.iter().zip(&ty).map(|(a, b)| a * b).sum();
            prop_close(&[lhs], &[rhs], 1e-2, 1e-3, "adjoint")
        });
    }

    #[test]
    fn maxpool_forward_backward() {
        let g = geom(1, 4, 4, 2, 2, 0);
        let input = Blob::from_vec(
            &[1, 1, 4, 4],
            vec![1., 2., 3., 4., 5., 6., 7., 8., 9., 10., 11., 12., 13., 14., 15., 16.],
        );
        let (out, arg) = maxpool_forward(&input, &g);
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        assert_eq!(out.data(), &[6., 8., 14., 16.]);
        let go = Blob::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let d = maxpool_backward(input.shape(), &go, &arg);
        assert_eq!(d.data()[5], 1.0);
        assert_eq!(d.data()[7], 2.0);
        assert_eq!(d.data()[13], 3.0);
        assert_eq!(d.data()[15], 4.0);
        assert_eq!(d.sum(), 10.0);
    }

    #[test]
    fn avgpool_values() {
        let g = geom(1, 2, 2, 2, 2, 0);
        let input = Blob::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let out = avgpool_forward(&input, &g);
        assert_eq!(out.data(), &[2.5]);
    }

    #[test]
    fn lrn_shape_preserving_and_shrinks() {
        let mut rng = Rng::new(3);
        let x = Blob::from_vec(&[1, 4, 2, 2], rng.uniform_vec(16, 0.5, 1.5));
        let y = lrn_forward(&x, 3, 1e-2, 0.75, 2.0);
        assert_eq!(y.shape(), x.shape());
        // k=2, beta>0 → outputs strictly smaller in magnitude
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!(b.abs() < a.abs());
        }
    }
}
