//! Kernel dispatch layer — scalar oracle vs explicit AVX2/FMA microkernels.
//!
//! The blocked GEMM in [`super::gemm`] and the conv transforms in
//! [`super::conv`] route their inner loops through this module. Two
//! implementations exist per hot loop:
//!
//! * **scalar** — the portable path, written so LLVM auto-vectorizes the
//!   8-wide lanes. It is the test oracle and the default: its per-element
//!   operation sequence is the historical one, so the bit-identical-at-
//!   every-thread-count contract is untouched.
//! * **simd** — explicit `std::arch` AVX2/FMA kernels (x86_64 only). The
//!   GEMM microkernel holds an MR x NR register tile across the whole `kb`
//!   loop, so its FMA accumulation order differs from the scalar oracle:
//!   results are approximately equal (pinned by property tests), not
//!   bitwise. The conv span kernels are pure lane-independent copies/adds
//!   and stay bitwise identical to scalar.
//!
//! The active kind is resolved **once** per process from `PALLAS_KERNEL`
//! (`scalar` | `simd` | `auto`) plus CPU feature detection — see
//! [`crate::runtime::kernel`] — and logged through
//! [`crate::runtime::manifest::log_kernel_once`]. `simd` silently degrades
//! to scalar (with a note in the log line) when the host lacks AVX2+FMA,
//! so the knob is safe to set unconditionally in CI.

/// Which microkernel family executes the tensor hot loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable autovectorized loops — default, test oracle.
    Scalar,
    /// Explicit AVX2/FMA microkernels (x86_64 with runtime detection).
    Simd,
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
        }
    }
}

/// Outcome of resolving the `PALLAS_KERNEL` knob against the host CPU.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelChoice {
    /// Sanitized form of the request: `scalar` | `simd` | `auto` |
    /// `(unset)` | `(invalid)`.
    pub requested: String,
    /// Whether runtime detection found AVX2 and FMA on this host.
    pub avx2_fma: bool,
    /// The kind every kernel call dispatches on.
    pub chosen: KernelKind,
    /// Present when the choice differs from the request (fallbacks).
    pub note: Option<String>,
}

/// Pure resolution policy: knob value x detected features -> choice.
/// Unset and `scalar` keep the oracle; `simd` and `auto` take the AVX2
/// path only when the host supports it; anything else falls back to
/// scalar with a note.
pub fn resolve(env: Option<&str>, avx2_fma: bool) -> KernelChoice {
    let token = env.map(|s| s.trim().to_ascii_lowercase());
    match token.as_deref() {
        None | Some("") => KernelChoice {
            requested: "(unset)".to_string(),
            avx2_fma,
            chosen: KernelKind::Scalar,
            note: None,
        },
        Some("scalar") => KernelChoice {
            requested: "scalar".to_string(),
            avx2_fma,
            chosen: KernelKind::Scalar,
            note: None,
        },
        Some("simd") => {
            if avx2_fma {
                KernelChoice {
                    requested: "simd".to_string(),
                    avx2_fma,
                    chosen: KernelKind::Simd,
                    note: None,
                }
            } else {
                KernelChoice {
                    requested: "simd".to_string(),
                    avx2_fma,
                    chosen: KernelKind::Scalar,
                    note: Some("AVX2+FMA not detected; falling back to scalar".to_string()),
                }
            }
        }
        Some("auto") => KernelChoice {
            requested: "auto".to_string(),
            avx2_fma,
            chosen: if avx2_fma { KernelKind::Simd } else { KernelKind::Scalar },
            note: None,
        },
        Some(_) => KernelChoice {
            requested: "(invalid)".to_string(),
            avx2_fma,
            chosen: KernelKind::Scalar,
            note: Some("unrecognized PALLAS_KERNEL value; using scalar".to_string()),
        },
    }
}

/// Runtime CPU check for the simd path (AVX2 and FMA both present).
pub fn simd_supported() -> bool {
    simd_supported_impl()
}

#[cfg(target_arch = "x86_64")]
fn simd_supported_impl() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_supported_impl() -> bool {
    false
}

// ---------------------------------------------------------------------------
// Shared lane helpers — the one place the chunks_exact(8) + remainder edge
// pattern is written. Both kernel families use these for their scalar
// edges, so tails behave identically everywhere.
// ---------------------------------------------------------------------------

/// `c[i] += av * b[i]` over full 8-wide lanes plus the remainder tail.
/// One multiply + one add per element, in index order — the historical
/// per-element operation sequence of the gemm accumulate loops.
#[inline]
pub fn axpy8(av: f32, b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(b.len(), c.len());
    let mut b8 = b.chunks_exact(8);
    let mut c8 = c.chunks_exact_mut(8);
    for (bv, cv) in (&mut b8).zip(&mut c8) {
        for i in 0..8 {
            cv[i] += av * bv[i];
        }
    }
    for (bv, cv) in b8.remainder().iter().zip(c8.into_remainder()) {
        *cv += av * bv;
    }
}

/// `c[i] *= beta` over full 8-wide lanes plus the remainder tail — the
/// gemm beta prologue, one multiply per element in index order.
#[inline]
pub fn scale8(beta: f32, c: &mut [f32]) {
    let mut c8 = c.chunks_exact_mut(8);
    for cv in &mut c8 {
        for v in cv.iter_mut() {
            *v *= beta;
        }
    }
    for v in c8.into_remainder() {
        *v *= beta;
    }
}

// ---------------------------------------------------------------------------
// GEMM microkernel: C_tile += alpha * Apack @ Bpack over packed tiles.
// ---------------------------------------------------------------------------

/// Dispatching microkernel over packed tiles. `a_pack` is `mb x kb`
/// row-major, `b_pack` is `kb x nb` with rows `ldb` apart, and `c` points
/// at the top-left of the C tile with rows `ldc` apart.
#[allow(clippy::too_many_arguments)]
pub fn microkernel(
    kind: KernelKind,
    mb: usize,
    nb: usize,
    kb: usize,
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    debug_assert!(a_pack.len() >= mb * kb, "A pack too small");
    debug_assert!(nb <= ldb && b_pack.len() + ldb >= kb * ldb + nb, "B pack too small");
    // The detection re-check makes `Simd` total on every host (std caches
    // the cpuid result, so this is one relaxed atomic load per tile):
    // callers may pass Simd unconditionally and still get defined
    // behaviour — it degrades to the scalar oracle without AVX2+FMA.
    if kind == KernelKind::Simd && simd_supported() {
        microkernel_simd(mb, nb, kb, alpha, a_pack, b_pack, ldb, c, ldc);
        return;
    }
    microkernel_scalar(mb, nb, kb, alpha, a_pack, b_pack, ldb, c, ldc);
}

/// Portable microkernel: 2-row register blocking over [`axpy8`] lanes.
/// Each C element still sees exactly one `+= (a*alpha) * b` per `p`, in
/// `p` order — the historical bit pattern.
#[allow(clippy::too_many_arguments)]
fn microkernel_scalar(
    mb: usize,
    nb: usize,
    kb: usize,
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let mut r = 0;
    while r + 2 <= mb {
        let arow0 = &a_pack[r * kb..r * kb + kb];
        let arow1 = &a_pack[(r + 1) * kb..(r + 1) * kb + kb];
        let (c0, c1) = c[r * ldc..].split_at_mut(ldc);
        let c0 = &mut c0[..nb];
        let c1 = &mut c1[..nb];
        for p in 0..kb {
            let brow = &b_pack[p * ldb..p * ldb + nb];
            axpy8(arow0[p] * alpha, brow, c0);
            axpy8(arow1[p] * alpha, brow, c1);
        }
        r += 2;
    }
    if r < mb {
        let arow = &a_pack[r * kb..r * kb + kb];
        let crow = &mut c[r * ldc..r * ldc + nb];
        for (p, &av) in arow.iter().enumerate() {
            axpy8(av * alpha, &b_pack[p * ldb..p * ldb + nb], crow);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn microkernel_simd(
    mb: usize,
    nb: usize,
    kb: usize,
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    if mb == 0 || nb == 0 || kb == 0 {
        return;
    }
    // SAFETY: the dispatcher re-checked `simd_supported()` (AVX2+FMA
    // detected at runtime) before calling here, and the asserted
    // pack/tile bounds keep every pointer inside its slice: B reads stop
    // at `(kb-1)*ldb + nb <= b_pack.len()`, A at `mb*kb <= a_pack.len()`,
    // C at `(mb-1)*ldc + nb <= c.len()`.
    let done = unsafe {
        avx2::microkernel(
            mb, nb, kb, alpha, a_pack.as_ptr(), b_pack.as_ptr(), ldb, c.as_mut_ptr(), ldc,
        )
    };
    if done < nb {
        // Sub-8-column edge: shared scalar tail over the same lane helper.
        microkernel_scalar(
            mb, nb - done, kb, alpha, a_pack, &b_pack[done..], ldb, &mut c[done..], ldc,
        );
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
fn microkernel_simd(
    mb: usize,
    nb: usize,
    kb: usize,
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    // `resolve` never picks Simd without detection, but stay total.
    microkernel_scalar(mb, nb, kb, alpha, a_pack, b_pack, ldb, c, ldc);
}

// ---------------------------------------------------------------------------
// Conv span kernels: contiguous copy / accumulate used by the stride-1
// im2col/col2im fast paths. Lane-independent, so bitwise equal to scalar.
// ---------------------------------------------------------------------------

/// `dst[i] = src[i]`.
pub fn copy_span(kind: KernelKind, src: &[f32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    if kind == KernelKind::Simd && simd_supported() {
        copy_span_simd(src, dst);
        return;
    }
    dst.copy_from_slice(src);
}

/// `dst[i] += src[i]`.
pub fn add_span(kind: KernelKind, src: &[f32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    if kind == KernelKind::Simd && simd_supported() {
        add_span_simd(src, dst);
        return;
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

#[cfg(target_arch = "x86_64")]
fn copy_span_simd(src: &[f32], dst: &mut [f32]) {
    // SAFETY: the dispatcher re-checked `simd_supported()` before calling
    // here; the lengths were asserted equal by the caller.
    unsafe { avx2::copy_span(src.as_ptr(), dst.as_mut_ptr(), dst.len()) }
}

#[cfg(target_arch = "x86_64")]
fn add_span_simd(src: &[f32], dst: &mut [f32]) {
    // SAFETY: as for `copy_span_simd`.
    unsafe { avx2::add_span(src.as_ptr(), dst.as_mut_ptr(), dst.len()) }
}

#[cfg(not(target_arch = "x86_64"))]
fn copy_span_simd(src: &[f32], dst: &mut [f32]) {
    dst.copy_from_slice(src);
}

#[cfg(not(target_arch = "x86_64"))]
fn add_span_simd(src: &[f32], dst: &mut [f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Explicit AVX2/FMA kernels. Every function carries `#[target_feature]`
/// and must only be called after runtime detection ([`simd_supported`]).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Rows per register tile (4 x 16 block = 8 accumulator vectors).
    const MR: usize = 4;
    /// Columns per register tile (two 8-wide lanes).
    const NR: usize = 16;

    // One monomorphic tile kernel per row count, generated by macro so the
    // accumulator array length is a literal and stays in registers.
    macro_rules! tile16 {
        ($name:ident, $mr:expr) => {
            /// `C[0..mr, 0..16] += alpha * A[0..mr, 0..kb] @ B[0..kb, 0..16]`
            /// with the whole accumulator block held in ymm registers for
            /// the `kb` loop — the FMA-ordering difference vs the scalar
            /// oracle.
            #[target_feature(enable = "avx2", enable = "fma")]
            unsafe fn $name(
                kb: usize,
                alpha: f32,
                a: *const f32,
                lda: usize,
                b: *const f32,
                ldb: usize,
                c: *mut f32,
                ldc: usize,
            ) {
                // SAFETY: caller contract (module docs) — AVX2+FMA verified
                // at runtime, A spans `$mr` rows x `kb` at stride `lda`, B
                // spans `kb` rows x 16 at stride `ldb`, C spans `$mr` rows
                // x 16 at stride `ldc`; all arithmetic below stays inside
                // those spans.
                unsafe {
                    let mut lo = [_mm256_setzero_ps(); $mr];
                    let mut hi = [_mm256_setzero_ps(); $mr];
                    for p in 0..kb {
                        let bp = b.add(p * ldb);
                        let b0 = _mm256_loadu_ps(bp);
                        let b1 = _mm256_loadu_ps(bp.add(8));
                        for r in 0..$mr {
                            let av = _mm256_set1_ps(*a.add(r * lda + p));
                            lo[r] = _mm256_fmadd_ps(av, b0, lo[r]);
                            hi[r] = _mm256_fmadd_ps(av, b1, hi[r]);
                        }
                    }
                    let al = _mm256_set1_ps(alpha);
                    for r in 0..$mr {
                        let cp = c.add(r * ldc);
                        _mm256_storeu_ps(cp, _mm256_fmadd_ps(al, lo[r], _mm256_loadu_ps(cp)));
                        let cq = cp.add(8);
                        _mm256_storeu_ps(cq, _mm256_fmadd_ps(al, hi[r], _mm256_loadu_ps(cq)));
                    }
                }
            }
        };
    }

    tile16!(tile16x4, 4);
    tile16!(tile16x2, 2);
    tile16!(tile16x1, 1);

    macro_rules! tile8 {
        ($name:ident, $mr:expr) => {
            /// 8-column variant of the register tile.
            #[target_feature(enable = "avx2", enable = "fma")]
            unsafe fn $name(
                kb: usize,
                alpha: f32,
                a: *const f32,
                lda: usize,
                b: *const f32,
                ldb: usize,
                c: *mut f32,
                ldc: usize,
            ) {
                // SAFETY: caller contract as for `tile16!`, with 8-wide
                // column spans instead of 16.
                unsafe {
                    let mut acc = [_mm256_setzero_ps(); $mr];
                    for p in 0..kb {
                        let b0 = _mm256_loadu_ps(b.add(p * ldb));
                        for r in 0..$mr {
                            let av = _mm256_set1_ps(*a.add(r * lda + p));
                            acc[r] = _mm256_fmadd_ps(av, b0, acc[r]);
                        }
                    }
                    let al = _mm256_set1_ps(alpha);
                    for r in 0..$mr {
                        let cp = c.add(r * ldc);
                        _mm256_storeu_ps(cp, _mm256_fmadd_ps(al, acc[r], _mm256_loadu_ps(cp)));
                    }
                }
            }
        };
    }

    tile8!(tile8x4, 4);
    tile8!(tile8x2, 2);
    tile8!(tile8x1, 1);

    // One column strip (16 or 8 wide) over all mb rows: MR-row tiles with
    // 2-row and 1-row edge tiles.
    macro_rules! col_strip {
        ($name:ident, $t4:ident, $t2:ident, $t1:ident) => {
            #[target_feature(enable = "avx2", enable = "fma")]
            unsafe fn $name(
                mb: usize,
                kb: usize,
                alpha: f32,
                a: *const f32,
                b: *const f32,
                ldb: usize,
                c: *mut f32,
                ldc: usize,
            ) {
                // SAFETY: the tile calls partition the `mb` rows exactly
                // (4/2/1 edge tiles), so each inherits in-bounds spans from
                // this function's caller contract; the tiles share this
                // function's target features.
                unsafe {
                    let mut r = 0;
                    while r + MR <= mb {
                        $t4(kb, alpha, a.add(r * kb), kb, b, ldb, c.add(r * ldc), ldc);
                        r += MR;
                    }
                    if r + 2 <= mb {
                        $t2(kb, alpha, a.add(r * kb), kb, b, ldb, c.add(r * ldc), ldc);
                        r += 2;
                    }
                    if r < mb {
                        $t1(kb, alpha, a.add(r * kb), kb, b, ldb, c.add(r * ldc), ldc);
                    }
                }
            }
        };
    }

    col_strip!(col_strip16, tile16x4, tile16x2, tile16x1);
    col_strip!(col_strip8, tile8x4, tile8x2, tile8x1);

    /// Register-blocked microkernel body: 16-wide column strips, then one
    /// 8-wide strip. Returns the number of columns processed; the caller
    /// handles the `nb % 8` edge with the shared scalar tail.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn microkernel(
        mb: usize,
        nb: usize,
        kb: usize,
        alpha: f32,
        a: *const f32,
        b: *const f32,
        ldb: usize,
        c: *mut f32,
        ldc: usize,
    ) -> usize {
        // SAFETY: caller contract (`microkernel_simd`) — AVX2+FMA detected
        // and the pack/tile bounds hold; the strips advance `j` by whole
        // 16/8-column spans that stay inside B and C.
        unsafe {
            let mut j = 0;
            while j + NR <= nb {
                col_strip16(mb, kb, alpha, a, b.add(j), ldb, c.add(j), ldc);
                j += NR;
            }
            if j + 8 <= nb {
                col_strip8(mb, kb, alpha, a, b.add(j), ldb, c.add(j), ldc);
                j += 8;
            }
            j
        }
    }

    /// `dst[0..n] = src[0..n]` with 8-wide unaligned loads/stores.
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_span(src: *const f32, dst: *mut f32, n: usize) {
        // SAFETY: caller contract — `src` and `dst` are valid for `n`
        // elements and do not overlap; unaligned load/store intrinsics have
        // no alignment requirement beyond validity.
        unsafe {
            let mut i = 0;
            while i + 8 <= n {
                _mm256_storeu_ps(dst.add(i), _mm256_loadu_ps(src.add(i)));
                i += 8;
            }
            while i < n {
                *dst.add(i) = *src.add(i);
                i += 1;
            }
        }
    }

    /// `dst[0..n] += src[0..n]` — independent lane adds, bitwise equal to
    /// the scalar accumulate.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_span(src: *const f32, dst: *mut f32, n: usize) {
        // SAFETY: caller contract as for `copy_span`.
        unsafe {
            let mut i = 0;
            while i + 8 <= n {
                let s = _mm256_loadu_ps(src.add(i));
                let d = _mm256_loadu_ps(dst.add(i));
                _mm256_storeu_ps(dst.add(i), _mm256_add_ps(d, s));
                i += 8;
            }
            while i < n {
                *dst.add(i) += *src.add(i);
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Rng;

    #[test]
    fn resolve_policy_table() {
        // (env, detected) -> (requested, chosen, has_note)
        let cases: &[(Option<&str>, bool, &str, KernelKind, bool)] = &[
            (None, true, "(unset)", KernelKind::Scalar, false),
            (None, false, "(unset)", KernelKind::Scalar, false),
            (Some(""), true, "(unset)", KernelKind::Scalar, false),
            (Some("scalar"), true, "scalar", KernelKind::Scalar, false),
            (Some("SIMD"), true, "simd", KernelKind::Simd, false),
            (Some("simd"), false, "simd", KernelKind::Scalar, true),
            (Some("auto"), true, "auto", KernelKind::Simd, false),
            (Some("auto"), false, "auto", KernelKind::Scalar, false),
            (Some("fast"), true, "(invalid)", KernelKind::Scalar, true),
        ];
        for &(env, det, req, chosen, noted) in cases {
            let c = resolve(env, det);
            assert_eq!(c.requested, req, "env={env:?}");
            assert_eq!(c.avx2_fma, det, "env={env:?}");
            assert_eq!(c.chosen, chosen, "env={env:?}");
            assert_eq!(c.note.is_some(), noted, "env={env:?}");
        }
    }

    #[test]
    fn axpy8_and_scale8_match_naive() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 7, 8, 9, 16, 23, 64] {
            let b = rng.uniform_vec(n, -1.0, 1.0);
            let c0 = rng.uniform_vec(n, -1.0, 1.0);
            let av = 0.37f32;
            let mut c1 = c0.clone();
            axpy8(av, &b, &mut c1);
            let mut c2 = c0.clone();
            for i in 0..n {
                c2[i] += av * b[i];
            }
            assert_eq!(c1, c2, "axpy8 n={n}");
            let mut s1 = c0.clone();
            scale8(-2.5, &mut s1);
            let mut s2 = c0.clone();
            for v in s2.iter_mut() {
                *v *= -2.5;
            }
            assert_eq!(s1, s2, "scale8 n={n}");
        }
    }

    #[test]
    fn spans_match_scalar_exactly() {
        if !simd_supported() {
            eprintln!("NOTICE: AVX2+FMA not detected; span kernels degrade to scalar");
        }
        let kind = if simd_supported() { KernelKind::Simd } else { KernelKind::Scalar };
        let mut rng = Rng::new(21);
        for n in [0usize, 1, 5, 8, 13, 16, 31, 100] {
            let src = rng.uniform_vec(n, -2.0, 2.0);
            let d0 = rng.uniform_vec(n, -2.0, 2.0);
            let mut d1 = d0.clone();
            copy_span(kind, &src, &mut d1);
            assert_eq!(d1, src, "copy_span n={n}");
            let mut a1 = d0.clone();
            add_span(kind, &src, &mut a1);
            let mut a2 = d0.clone();
            for (d, s) in a2.iter_mut().zip(&src) {
                *d += s;
            }
            assert_eq!(a1, a2, "add_span n={n}");
        }
    }

    /// The simd microkernel must approximate the scalar oracle over tiles
    /// covering every row/column edge combination (mb % 4, nb % 16 / % 8,
    /// sub-8 tails, ldb > nb).
    #[test]
    fn simd_microkernel_matches_scalar_on_edges() {
        if !simd_supported() {
            eprintln!("NOTICE: AVX2+FMA not detected; skipping simd microkernel test");
            return;
        }
        let mut rng = Rng::new(31);
        for &mb in &[1usize, 2, 3, 4, 5, 6, 7, 8, 11] {
            for &nb in &[1usize, 2, 7, 8, 9, 15, 16, 17, 24, 25, 40] {
                for &kb in &[1usize, 2, 8, 33] {
                    let ldb = nb + 3;
                    let ldc = nb + 5;
                    let a = rng.uniform_vec(mb * kb, -1.0, 1.0);
                    let b = rng.uniform_vec(kb * ldb, -1.0, 1.0);
                    let c0 = rng.uniform_vec(mb * ldc, -1.0, 1.0);
                    let mut cs = c0.clone();
                    microkernel(KernelKind::Scalar, mb, nb, kb, 1.3, &a, &b, ldb, &mut cs, ldc);
                    let mut cv = c0.clone();
                    microkernel(KernelKind::Simd, mb, nb, kb, 1.3, &a, &b, ldb, &mut cv, ldc);
                    for (i, (x, y)) in cv.iter().zip(&cs).enumerate() {
                        assert!(
                            (x - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                            "mb={mb} nb={nb} kb={kb} idx={i}: {x} vs {y}"
                        );
                    }
                    // Columns past nb (and rows past mb) must be untouched.
                    for r in 0..mb {
                        let (lo, hi) = (r * ldc + nb, r * ldc + ldc);
                        assert_eq!(cv[lo..hi], c0[lo..hi]);
                    }
                }
            }
        }
    }
}
