//! Elementwise / reduction / matrix ops used by the built-in layers.
//!
//! These are the "linear algebra functions" the paper exposes to layer
//! implementers (§5.1); in SINGA they dispatch to CPU or GPU — here they are
//! the native-backend implementations, with the XLA path covering the
//! AOT-compiled production loop.
//!
//! Every hot-path primitive exists in a destination-passing `_into` form
//! (layered on [`gemm`]'s `beta`/`C` support) so the planned executor can
//! run the steady-state training loop without allocating; the allocating
//! versions are thin wrappers over the `_into` forms and therefore produce
//! bit-identical results. `beta` follows BLAS: `0.0` overwrites the
//! destination, `1.0` accumulates into it.
//!
//! All matmul forms inherit [`gemm`]'s intra-op threading
//! (`PALLAS_NUM_THREADS`, see [`crate::runtime::threads`]) and its
//! determinism guarantee: layer outputs are bit-for-bit identical at every
//! thread count, so training trajectories never depend on the knob.

use super::blob::Blob;
use super::gemm::{gemm, Transpose};

/// `C = alpha_implicit(1) * A @ B + beta * C` on the matrix views.
/// `c` must already have `a.rows() x b.cols()` elements (any shape whose
/// matrix view matches, e.g. an NCHW gradient slot).
pub fn matmul_into(a: &Blob, b: &Blob, c: &mut Blob, beta: f32) {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dim: {:?} @ {:?}", a.shape(), b.shape());
    assert_eq!((c.rows(), c.cols()), (m, n), "matmul_into dst {:?}", c.shape());
    gemm(Transpose::No, Transpose::No, m, n, k, 1.0, a.data(), b.data(), beta, c.data_mut());
}

/// `C = A^T @ B + beta * C`.
pub fn matmul_tn_into(a: &Blob, b: &Blob, c: &mut Blob, beta: f32) {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_tn inner dim");
    assert_eq!((c.rows(), c.cols()), (m, n), "matmul_tn_into dst {:?}", c.shape());
    gemm(Transpose::Yes, Transpose::No, m, n, k, 1.0, a.data(), b.data(), beta, c.data_mut());
}

/// `C = A @ B^T + beta * C`.
pub fn matmul_nt_into(a: &Blob, b: &Blob, c: &mut Blob, beta: f32) {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner dim");
    assert_eq!((c.rows(), c.cols()), (m, n), "matmul_nt_into dst {:?}", c.shape());
    gemm(Transpose::No, Transpose::Yes, m, n, k, 1.0, a.data(), b.data(), beta, c.data_mut());
}

/// `C = A @ B` on the matrix views of the blobs.
pub fn matmul(a: &Blob, b: &Blob) -> Blob {
    let mut c = Blob::zeros(&[a.rows(), b.cols()]);
    matmul_into(a, b, &mut c, 0.0);
    c
}

/// `C = A^T @ B`.
pub fn matmul_tn(a: &Blob, b: &Blob) -> Blob {
    let mut c = Blob::zeros(&[a.cols(), b.cols()]);
    matmul_tn_into(a, b, &mut c, 0.0);
    c
}

/// `C = A @ B^T`.
pub fn matmul_nt(a: &Blob, b: &Blob) -> Blob {
    let mut c = Blob::zeros(&[a.rows(), b.rows()]);
    matmul_nt_into(a, b, &mut c, 0.0);
    c
}

/// Add a row vector (bias) to every row of the matrix view.
pub fn add_row_vec(x: &mut Blob, bias: &Blob) {
    let cols = x.cols();
    assert_eq!(bias.len(), cols, "bias length");
    for row in x.data_mut().chunks_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias.data()) {
            *v += b;
        }
    }
}

/// Column-wise sum of the matrix view accumulated into a row vector
/// (`out += colsum(x)` when `accumulate`, else `out = colsum(x)`).
pub fn sum_rows_into(x: &Blob, out: &mut Blob, accumulate: bool) {
    let cols = x.cols();
    assert_eq!(out.len(), cols, "sum_rows_into dst length");
    if !accumulate {
        out.fill(0.0);
    }
    for row in x.data().chunks(cols) {
        for (o, v) in out.data_mut().iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Column-wise sum of the matrix view → row vector (bias gradient).
pub fn sum_rows(x: &Blob) -> Blob {
    let mut out = Blob::zeros(&[x.cols()]);
    sum_rows_into(x, &mut out, false);
    out
}

/// Scalar sigmoid, shared by the blob-level forms and the GRU gate loops.
#[inline]
pub fn sigmoid_scalar(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

// Scalar chain-rule steps given the activation OUTPUT `y` and the upstream
// gradient `dy` — the single source of truth for every backward
// implementation (inner-product, standalone activation, RBM, GRU).

/// `dy * σ'` expressed through the output: `dy * y * (1 - y)`.
#[inline]
pub fn dsigmoid(y: f32, dy: f32) -> f32 {
    dy * y * (1.0 - y)
}

/// `dy * tanh'` through the output: `dy * (1 - y²)`.
#[inline]
pub fn dtanh(y: f32, dy: f32) -> f32 {
    dy * (1.0 - y * y)
}

/// `dy * relu'` through the output (y is 0 exactly where the input was
/// non-positive, so the output gates the gradient).
#[inline]
pub fn drelu_from_out(y: f32, dy: f32) -> f32 {
    if y > 0.0 {
        dy
    } else {
        0.0
    }
}

pub fn sigmoid(x: &Blob) -> Blob {
    map(x, sigmoid_scalar)
}

pub fn sigmoid_into(x: &Blob, out: &mut Blob) {
    map_into(x, out, sigmoid_scalar);
}

/// Apply the sigmoid in place — the in-place activation path used when the
/// producer (pre-activation) and consumer share one workspace buffer.
pub fn sigmoid_inplace(x: &mut Blob) {
    x.data_mut().iter_mut().for_each(|v| *v = sigmoid_scalar(*v));
}

/// d/dx of sigmoid given the *output* y: y * (1 - y).
pub fn sigmoid_grad(y: &Blob, dy: &Blob) -> Blob {
    zip(y, dy, dsigmoid)
}

pub fn tanh(x: &Blob) -> Blob {
    map(x, f32::tanh)
}

pub fn tanh_into(x: &Blob, out: &mut Blob) {
    map_into(x, out, f32::tanh);
}

pub fn tanh_inplace(x: &mut Blob) {
    x.data_mut().iter_mut().for_each(|v| *v = v.tanh());
}

pub fn tanh_grad(y: &Blob, dy: &Blob) -> Blob {
    zip(y, dy, dtanh)
}

pub fn relu(x: &Blob) -> Blob {
    map(x, |v| v.max(0.0))
}

pub fn relu_into(x: &Blob, out: &mut Blob) {
    map_into(x, out, |v| v.max(0.0));
}

pub fn relu_inplace(x: &mut Blob) {
    x.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
}

pub fn relu_grad(x: &Blob, dy: &Blob) -> Blob {
    zip(x, dy, |xv, dv| if xv > 0.0 { dv } else { 0.0 })
}

/// Row-wise softmax written into `out` (resized to `x`'s shape).
pub fn softmax_into(x: &Blob, out: &mut Blob) {
    out.copy_from(x);
    let cols = x.cols();
    for row in out.data_mut().chunks_mut(cols) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Row-wise softmax of the matrix view (numerically stabilized).
pub fn softmax(x: &Blob) -> Blob {
    let mut out = Blob::zeros(x.shape());
    softmax_into(x, &mut out);
    out
}

/// Mean softmax cross-entropy against integer labels with the logits
/// gradient `(p - onehot)/batch` written into `grad` (resized to the logits
/// shape). Returns the loss.
pub fn softmax_xent_into(logits: &Blob, labels: &[usize], grad: &mut Blob) -> f32 {
    softmax_into(logits, grad);
    let cols = logits.cols();
    let rows = logits.rows();
    assert_eq!(labels.len(), rows, "labels length");
    let mut loss = 0.0f32;
    for (r, &label) in labels.iter().enumerate() {
        assert!(label < cols, "label {label} out of range {cols}");
        let p = grad.data()[r * cols + label].max(1e-12);
        loss -= p.ln();
        grad.data_mut()[r * cols + label] -= 1.0;
    }
    grad.scale(1.0 / rows as f32);
    loss / rows as f32
}

/// Mean cross-entropy loss of row-wise softmax probabilities `p` against
/// integer labels, plus the gradient w.r.t. the logits (p - onehot)/batch.
pub fn softmax_xent(logits: &Blob, labels: &[usize]) -> (f32, Blob) {
    let mut grad = Blob::zeros(logits.shape());
    let loss = softmax_xent_into(logits, labels, &mut grad);
    (loss, grad)
}

/// Fraction of rows whose argmax equals the label.
pub fn accuracy(logits: &Blob, labels: &[usize]) -> f32 {
    let cols = logits.cols();
    let mut correct = 0;
    for (r, &label) in labels.iter().enumerate() {
        let row = &logits.data()[r * cols..(r + 1) * cols];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if argmax == label {
            correct += 1;
        }
    }
    correct as f32 / labels.len().max(1) as f32
}

/// Euclidean loss with the gradient w.r.t. `a` written into `grad` (resized
/// to `a`'s shape). Returns the loss.
pub fn euclidean_loss_into(a: &Blob, b: &Blob, grad: &mut Blob) -> f32 {
    assert_eq!(a.shape(), b.shape(), "euclidean shapes");
    let rows = a.rows().max(1);
    grad.copy_from(a);
    grad.axpy(-1.0, b);
    let loss = 0.5 * grad.data().iter().map(|v| v * v).sum::<f32>() / rows as f32;
    grad.scale(1.0 / rows as f32);
    loss
}

/// Mean squared euclidean distance between rows of a and b: loss and grad
/// w.r.t. a ((a-b)/batch). Used by the EuclideanLoss layer in MDNN.
pub fn euclidean_loss(a: &Blob, b: &Blob) -> (f32, Blob) {
    let mut grad = Blob::zeros(a.shape());
    let loss = euclidean_loss_into(a, b, &mut grad);
    (loss, grad)
}

/// Elementwise map written into `out` (resized to `x`'s shape).
pub fn map_into<F: Fn(f32) -> f32>(x: &Blob, out: &mut Blob, f: F) {
    out.resize(x.shape());
    for (o, &v) in out.data_mut().iter_mut().zip(x.data()) {
        *o = f(v);
    }
}

pub fn map<F: Fn(f32) -> f32>(x: &Blob, f: F) -> Blob {
    let mut out = Blob::zeros(x.shape());
    map_into(x, &mut out, f);
    out
}

/// Elementwise zip written into `out` (resized to `a`'s shape). `out` may
/// not alias `a` or `b` (enforced by borrowing).
pub fn zip_into<F: Fn(f32, f32) -> f32>(a: &Blob, b: &Blob, out: &mut Blob, f: F) {
    assert_eq!(a.shape(), b.shape(), "zip shapes");
    out.resize(a.shape());
    for ((o, &x), &y) in out.data_mut().iter_mut().zip(a.data()).zip(b.data()) {
        *o = f(x, y);
    }
}

/// Elementwise zip ACCUMULATED into `out` (`out += f(a, b)`), the form
/// backward passes use to add a gradient contribution into a shared
/// workspace slot. Only element counts must agree (the slot may be NCHW
/// while the operands are matrix views).
pub fn zip_acc<F: Fn(f32, f32) -> f32>(a: &Blob, b: &Blob, out: &mut Blob, f: F) {
    assert_eq!(a.len(), b.len(), "zip_acc operand lengths");
    assert_eq!(a.len(), out.len(), "zip_acc dst length");
    for ((o, &x), &y) in out.data_mut().iter_mut().zip(a.data()).zip(b.data()) {
        *o += f(x, y);
    }
}

pub fn zip<F: Fn(f32, f32) -> f32>(a: &Blob, b: &Blob, f: F) -> Blob {
    let mut out = Blob::zeros(a.shape());
    zip_into(a, b, &mut out, f);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_assert, prop_close};
    use crate::utils::rng::Rng;

    #[test]
    fn matmul_shapes_and_values() {
        let a = Blob::from_vec(&[2, 3], vec![1., 0., 2., 0., 1., 1.]);
        let b = Blob::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.data(), &[11., 14., 8., 10.]);
    }

    #[test]
    fn transposed_matmuls_agree() {
        let mut rng = Rng::new(4);
        let a = Blob::from_vec(&[3, 5], rng.uniform_vec(15, -1.0, 1.0));
        let b = Blob::from_vec(&[3, 4], rng.uniform_vec(12, -1.0, 1.0));
        // A^T @ B  vs materialized transpose
        let at = transpose(&a);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&at, &b);
        prop_close(c1.data(), c2.data(), 1e-5, 1e-5, "tn").unwrap();
        // A @ B^T
        let b2 = Blob::from_vec(&[4, 5], rng.uniform_vec(20, -1.0, 1.0));
        let c3 = matmul_nt(&a, &b2);
        let c4 = matmul(&a, &transpose(&b2));
        prop_close(c3.data(), c4.data(), 1e-5, 1e-5, "nt").unwrap();
    }

    fn transpose(x: &Blob) -> Blob {
        let (r, c) = (x.rows(), x.cols());
        let mut out = Blob::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data_mut()[j * r + i] = x.data()[i * c + j];
            }
        }
        out
    }

    #[test]
    fn bias_and_sum_rows_roundtrip() {
        let mut x = Blob::zeros(&[3, 2]);
        let bias = Blob::from_vec(&[2], vec![1.0, -2.0]);
        add_row_vec(&mut x, &bias);
        assert_eq!(x.data(), &[1., -2., 1., -2., 1., -2.]);
        let s = sum_rows(&x);
        assert_eq!(s.data(), &[3.0, -6.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        forall(30, |g| {
            let rows = g.usize(1, 8);
            let cols = g.usize(1, 10);
            let x = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -30.0, 30.0));
            let p = softmax(&x);
            for r in 0..rows {
                let s: f32 = p.data()[r * cols..(r + 1) * cols].iter().sum();
                prop_assert((s - 1.0).abs() < 1e-4, &format!("row {r} sums to {s}"))?;
                prop_assert(
                    p.data()[r * cols..(r + 1) * cols].iter().all(|&v| v >= 0.0),
                    "non-negative",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_shift_invariant() {
        let x = Blob::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let y = Blob::from_vec(&[1, 3], vec![101.0, 102.0, 103.0]);
        prop_close(softmax(&x).data(), softmax(&y).data(), 1e-6, 0.0, "shift").unwrap();
    }

    #[test]
    fn xent_matches_manual() {
        // Uniform logits → loss = ln(C).
        let x = Blob::zeros(&[2, 4]);
        let (loss, grad) = softmax_xent(&x, &[0, 3]);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        // grad rows sum to 0
        for r in 0..2 {
            let s: f32 = grad.data()[r * 4..(r + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn xent_gradient_numerically() {
        let mut rng = Rng::new(10);
        let x = Blob::from_vec(&[2, 3], rng.uniform_vec(6, -1.0, 1.0));
        let labels = [1usize, 2];
        let (_, grad) = softmax_xent(&x, &labels);
        let eps = 1e-3;
        for i in 0..6 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let (lp, _) = softmax_xent(&xp, &labels);
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let (lm, _) = softmax_xent(&xm, &labels);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - grad.data()[i]).abs() < 1e-3,
                "idx {i}: numeric {num} vs analytic {}",
                grad.data()[i]
            );
        }
    }

    #[test]
    fn accuracy_counts() {
        let x = Blob::from_vec(&[2, 3], vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1]);
        assert_eq!(accuracy(&x, &[1, 0]), 1.0);
        assert_eq!(accuracy(&x, &[0, 0]), 0.5);
    }

    #[test]
    fn euclidean_loss_grad() {
        let a = Blob::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Blob::from_vec(&[2, 2], vec![0., 2., 3., 2.]);
        let (loss, grad) = euclidean_loss(&a, &b);
        assert!((loss - 0.5 * (1.0 + 4.0) / 2.0).abs() < 1e-6);
        assert_eq!(grad.data(), &[0.5, 0.0, 0.0, 1.0]);
    }

    /// Every `_into` op must match its allocating counterpart bit-for-bit
    /// (the allocating versions are wrappers, but this pins the contract
    /// against future divergence) and in-place activations must match too.
    #[test]
    fn into_ops_match_allocating_bit_for_bit() {
        forall(40, |g| {
            let m = g.usize(1, 10);
            let k = g.usize(1, 10);
            let n = g.usize(1, 10);
            let a = Blob::from_vec(&[m, k], g.f32_vec(m * k, -2.0, 2.0));
            let b = Blob::from_vec(&[k, n], g.f32_vec(k * n, -2.0, 2.0));
            let mut c = Blob::zeros(&[m, n]);
            matmul_into(&a, &b, &mut c, 0.0);
            prop_close(c.data(), matmul(&a, &b).data(), 0.0, 0.0, "matmul")?;

            let at = Blob::from_vec(&[k, m], g.f32_vec(k * m, -2.0, 2.0));
            let mut c = Blob::zeros(&[m, n]);
            matmul_tn_into(&at, &b, &mut c, 0.0);
            prop_close(c.data(), matmul_tn(&at, &b).data(), 0.0, 0.0, "matmul_tn")?;

            let bt = Blob::from_vec(&[n, k], g.f32_vec(n * k, -2.0, 2.0));
            let mut c = Blob::zeros(&[m, n]);
            matmul_nt_into(&a, &bt, &mut c, 0.0);
            prop_close(c.data(), matmul_nt(&a, &bt).data(), 0.0, 0.0, "matmul_nt")?;

            let x = Blob::from_vec(&[m, n], g.f32_vec(m * n, -4.0, 4.0));
            let mut o = Blob::zeros(&[m, n]);
            sigmoid_into(&x, &mut o);
            prop_close(o.data(), sigmoid(&x).data(), 0.0, 0.0, "sigmoid")?;
            tanh_into(&x, &mut o);
            prop_close(o.data(), tanh(&x).data(), 0.0, 0.0, "tanh")?;
            relu_into(&x, &mut o);
            prop_close(o.data(), relu(&x).data(), 0.0, 0.0, "relu")?;
            softmax_into(&x, &mut o);
            prop_close(o.data(), softmax(&x).data(), 0.0, 0.0, "softmax")?;

            let mut inp = x.clone();
            sigmoid_inplace(&mut inp);
            prop_close(inp.data(), sigmoid(&x).data(), 0.0, 0.0, "sigmoid_inplace")?;
            let mut inp = x.clone();
            tanh_inplace(&mut inp);
            prop_close(inp.data(), tanh(&x).data(), 0.0, 0.0, "tanh_inplace")?;
            let mut inp = x.clone();
            relu_inplace(&mut inp);
            prop_close(inp.data(), relu(&x).data(), 0.0, 0.0, "relu_inplace")?;

            let mut s = Blob::zeros(&[n]);
            sum_rows_into(&x, &mut s, false);
            prop_close(s.data(), sum_rows(&x).data(), 0.0, 0.0, "sum_rows")?;

            let labels: Vec<usize> = (0..m).map(|i| i % n).collect();
            let mut gr = Blob::zeros(&[1]);
            let l1 = softmax_xent_into(&x, &labels, &mut gr);
            let (l2, gr2) = softmax_xent(&x, &labels);
            prop_assert(l1 == l2, "xent loss")?;
            prop_close(gr.data(), gr2.data(), 0.0, 0.0, "xent grad")?;

            let y = Blob::from_vec(&[m, n], g.f32_vec(m * n, -2.0, 2.0));
            let mut ge = Blob::zeros(&[1]);
            let l1 = euclidean_loss_into(&x, &y, &mut ge);
            let (l2, ge2) = euclidean_loss(&x, &y);
            prop_assert(l1 == l2, "euclid loss")?;
            prop_close(ge.data(), ge2.data(), 0.0, 0.0, "euclid grad")
        });
    }

    /// `beta` semantics of the matmul `_into` ops: beta=1 accumulates.
    #[test]
    fn matmul_into_beta_accumulates() {
        let a = Blob::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Blob::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        let mut c = Blob::full(&[2, 2], 10.0);
        matmul_into(&a, &b, &mut c, 1.0);
        assert_eq!(c.data(), &[11., 12., 13., 14.]);
        matmul_into(&a, &b, &mut c, 0.0);
        assert_eq!(c.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn zip_acc_accumulates() {
        let a = Blob::from_vec(&[2], vec![1., 2.]);
        let b = Blob::from_vec(&[2], vec![3., 4.]);
        let mut out = Blob::full(&[2], 1.0);
        zip_acc(&a, &b, &mut out, |x, y| x * y);
        assert_eq!(out.data(), &[4.0, 9.0]);
    }

    #[test]
    fn activation_grads_numerically() {
        let mut rng = Rng::new(2);
        let x = Blob::from_vec(&[1, 8], rng.uniform_vec(8, -2.0, 2.0));
        let dy = Blob::full(&[1, 8], 1.0);
        let eps = 1e-3;

        // sigmoid
        let y = sigmoid(&x);
        let g = sigmoid_grad(&y, &dy);
        for i in 0..8 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let num = (sigmoid(&xp).data()[i] - sigmoid(&xm).data()[i]) / (2.0 * eps);
            assert!((num - g.data()[i]).abs() < 1e-3, "sigmoid idx {i}");
        }
        // tanh
        let y = tanh(&x);
        let g = tanh_grad(&y, &dy);
        for i in 0..8 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let num = (tanh(&xp).data()[i] - tanh(&xm).data()[i]) / (2.0 * eps);
            assert!((num - g.data()[i]).abs() < 1e-3, "tanh idx {i}");
        }
        // relu (away from 0 kink)
        let g = relu_grad(&x, &dy);
        for i in 0..8 {
            if x.data()[i].abs() < 0.05 {
                continue;
            }
            let expect = if x.data()[i] > 0.0 { 1.0 } else { 0.0 };
            assert_eq!(g.data()[i], expect);
        }
    }
}
