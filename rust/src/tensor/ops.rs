//! Elementwise / reduction / matrix ops used by the built-in layers.
//!
//! These are the "linear algebra functions" the paper exposes to layer
//! implementers (§5.1); in SINGA they dispatch to CPU or GPU — here they are
//! the native-backend implementations, with the XLA path covering the
//! AOT-compiled production loop.

use super::blob::Blob;
use super::gemm::{gemm, Transpose};

/// `C = A @ B` on the matrix views of the blobs.
pub fn matmul(a: &Blob, b: &Blob) -> Blob {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dim: {:?} @ {:?}", a.shape(), b.shape());
    let mut c = Blob::zeros(&[m, n]);
    gemm(Transpose::No, Transpose::No, m, n, k, 1.0, a.data(), b.data(), 0.0, c.data_mut());
    c
}

/// `C = A^T @ B`.
pub fn matmul_tn(a: &Blob, b: &Blob) -> Blob {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_tn inner dim");
    let mut c = Blob::zeros(&[m, n]);
    gemm(Transpose::Yes, Transpose::No, m, n, k, 1.0, a.data(), b.data(), 0.0, c.data_mut());
    c
}

/// `C = A @ B^T`.
pub fn matmul_nt(a: &Blob, b: &Blob) -> Blob {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner dim");
    let mut c = Blob::zeros(&[m, n]);
    gemm(Transpose::No, Transpose::Yes, m, n, k, 1.0, a.data(), b.data(), 0.0, c.data_mut());
    c
}

/// Add a row vector (bias) to every row of the matrix view.
pub fn add_row_vec(x: &mut Blob, bias: &Blob) {
    let cols = x.cols();
    assert_eq!(bias.len(), cols, "bias length");
    for row in x.data_mut().chunks_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias.data()) {
            *v += b;
        }
    }
}

/// Column-wise sum of the matrix view → row vector (bias gradient).
pub fn sum_rows(x: &Blob) -> Blob {
    let cols = x.cols();
    let mut out = Blob::zeros(&[cols]);
    for row in x.data().chunks(cols) {
        for (o, v) in out.data_mut().iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

pub fn sigmoid(x: &Blob) -> Blob {
    map(x, |v| 1.0 / (1.0 + (-v).exp()))
}

/// d/dx of sigmoid given the *output* y: y * (1 - y).
pub fn sigmoid_grad(y: &Blob, dy: &Blob) -> Blob {
    zip(y, dy, |yv, dv| dv * yv * (1.0 - yv))
}

pub fn tanh(x: &Blob) -> Blob {
    map(x, f32::tanh)
}

pub fn tanh_grad(y: &Blob, dy: &Blob) -> Blob {
    zip(y, dy, |yv, dv| dv * (1.0 - yv * yv))
}

pub fn relu(x: &Blob) -> Blob {
    map(x, |v| v.max(0.0))
}

pub fn relu_grad(x: &Blob, dy: &Blob) -> Blob {
    zip(x, dy, |xv, dv| if xv > 0.0 { dv } else { 0.0 })
}

/// Row-wise softmax of the matrix view (numerically stabilized).
pub fn softmax(x: &Blob) -> Blob {
    let cols = x.cols();
    let mut out = x.clone();
    for row in out.data_mut().chunks_mut(cols) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Mean cross-entropy loss of row-wise softmax probabilities `p` against
/// integer labels, plus the gradient w.r.t. the logits (p - onehot)/batch.
pub fn softmax_xent(logits: &Blob, labels: &[usize]) -> (f32, Blob) {
    let probs = softmax(logits);
    let cols = probs.cols();
    let rows = probs.rows();
    assert_eq!(labels.len(), rows, "labels length");
    let mut loss = 0.0f32;
    let mut grad = probs.clone();
    for (r, &label) in labels.iter().enumerate() {
        assert!(label < cols, "label {label} out of range {cols}");
        let p = probs.data()[r * cols + label].max(1e-12);
        loss -= p.ln();
        grad.data_mut()[r * cols + label] -= 1.0;
    }
    grad.scale(1.0 / rows as f32);
    (loss / rows as f32, grad)
}

/// Fraction of rows whose argmax equals the label.
pub fn accuracy(logits: &Blob, labels: &[usize]) -> f32 {
    let cols = logits.cols();
    let mut correct = 0;
    for (r, &label) in labels.iter().enumerate() {
        let row = &logits.data()[r * cols..(r + 1) * cols];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if argmax == label {
            correct += 1;
        }
    }
    correct as f32 / labels.len().max(1) as f32
}

/// Mean squared euclidean distance between rows of a and b: loss and grad
/// w.r.t. a ((a-b)/batch). Used by the EuclideanLoss layer in MDNN.
pub fn euclidean_loss(a: &Blob, b: &Blob) -> (f32, Blob) {
    assert_eq!(a.shape(), b.shape(), "euclidean shapes");
    let rows = a.rows().max(1);
    let mut grad = a.clone();
    grad.axpy(-1.0, b);
    let loss = 0.5 * grad.data().iter().map(|v| v * v).sum::<f32>() / rows as f32;
    grad.scale(1.0 / rows as f32);
    (loss, grad)
}

pub fn map<F: Fn(f32) -> f32>(x: &Blob, f: F) -> Blob {
    Blob::from_vec(x.shape(), x.data().iter().map(|&v| f(v)).collect())
}

pub fn zip<F: Fn(f32, f32) -> f32>(a: &Blob, b: &Blob, f: F) -> Blob {
    assert_eq!(a.shape(), b.shape(), "zip shapes");
    Blob::from_vec(
        a.shape(),
        a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_assert, prop_close};
    use crate::utils::rng::Rng;

    #[test]
    fn matmul_shapes_and_values() {
        let a = Blob::from_vec(&[2, 3], vec![1., 0., 2., 0., 1., 1.]);
        let b = Blob::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.data(), &[11., 14., 8., 10.]);
    }

    #[test]
    fn transposed_matmuls_agree() {
        let mut rng = Rng::new(4);
        let a = Blob::from_vec(&[3, 5], rng.uniform_vec(15, -1.0, 1.0));
        let b = Blob::from_vec(&[3, 4], rng.uniform_vec(12, -1.0, 1.0));
        // A^T @ B  vs materialized transpose
        let at = transpose(&a);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&at, &b);
        prop_close(c1.data(), c2.data(), 1e-5, 1e-5, "tn").unwrap();
        // A @ B^T
        let b2 = Blob::from_vec(&[4, 5], rng.uniform_vec(20, -1.0, 1.0));
        let c3 = matmul_nt(&a, &b2);
        let c4 = matmul(&a, &transpose(&b2));
        prop_close(c3.data(), c4.data(), 1e-5, 1e-5, "nt").unwrap();
    }

    fn transpose(x: &Blob) -> Blob {
        let (r, c) = (x.rows(), x.cols());
        let mut out = Blob::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data_mut()[j * r + i] = x.data()[i * c + j];
            }
        }
        out
    }

    #[test]
    fn bias_and_sum_rows_roundtrip() {
        let mut x = Blob::zeros(&[3, 2]);
        let bias = Blob::from_vec(&[2], vec![1.0, -2.0]);
        add_row_vec(&mut x, &bias);
        assert_eq!(x.data(), &[1., -2., 1., -2., 1., -2.]);
        let s = sum_rows(&x);
        assert_eq!(s.data(), &[3.0, -6.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        forall(30, |g| {
            let rows = g.usize(1, 8);
            let cols = g.usize(1, 10);
            let x = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -30.0, 30.0));
            let p = softmax(&x);
            for r in 0..rows {
                let s: f32 = p.data()[r * cols..(r + 1) * cols].iter().sum();
                prop_assert((s - 1.0).abs() < 1e-4, &format!("row {r} sums to {s}"))?;
                prop_assert(
                    p.data()[r * cols..(r + 1) * cols].iter().all(|&v| v >= 0.0),
                    "non-negative",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_shift_invariant() {
        let x = Blob::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let y = Blob::from_vec(&[1, 3], vec![101.0, 102.0, 103.0]);
        prop_close(softmax(&x).data(), softmax(&y).data(), 1e-6, 0.0, "shift").unwrap();
    }

    #[test]
    fn xent_matches_manual() {
        // Uniform logits → loss = ln(C).
        let x = Blob::zeros(&[2, 4]);
        let (loss, grad) = softmax_xent(&x, &[0, 3]);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        // grad rows sum to 0
        for r in 0..2 {
            let s: f32 = grad.data()[r * 4..(r + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn xent_gradient_numerically() {
        let mut rng = Rng::new(10);
        let x = Blob::from_vec(&[2, 3], rng.uniform_vec(6, -1.0, 1.0));
        let labels = [1usize, 2];
        let (_, grad) = softmax_xent(&x, &labels);
        let eps = 1e-3;
        for i in 0..6 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let (lp, _) = softmax_xent(&xp, &labels);
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let (lm, _) = softmax_xent(&xm, &labels);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - grad.data()[i]).abs() < 1e-3,
                "idx {i}: numeric {num} vs analytic {}",
                grad.data()[i]
            );
        }
    }

    #[test]
    fn accuracy_counts() {
        let x = Blob::from_vec(&[2, 3], vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1]);
        assert_eq!(accuracy(&x, &[1, 0]), 1.0);
        assert_eq!(accuracy(&x, &[0, 0]), 0.5);
    }

    #[test]
    fn euclidean_loss_grad() {
        let a = Blob::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Blob::from_vec(&[2, 2], vec![0., 2., 3., 2.]);
        let (loss, grad) = euclidean_loss(&a, &b);
        assert!((loss - 0.5 * (1.0 + 4.0) / 2.0).abs() < 1e-6);
        assert_eq!(grad.data(), &[0.5, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn activation_grads_numerically() {
        let mut rng = Rng::new(2);
        let x = Blob::from_vec(&[1, 8], rng.uniform_vec(8, -2.0, 2.0));
        let dy = Blob::full(&[1, 8], 1.0);
        let eps = 1e-3;

        // sigmoid
        let y = sigmoid(&x);
        let g = sigmoid_grad(&y, &dy);
        for i in 0..8 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let num = (sigmoid(&xp).data()[i] - sigmoid(&xm).data()[i]) / (2.0 * eps);
            assert!((num - g.data()[i]).abs() < 1e-3, "sigmoid idx {i}");
        }
        // tanh
        let y = tanh(&x);
        let g = tanh_grad(&y, &dy);
        for i in 0..8 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let num = (tanh(&xp).data()[i] - tanh(&xm).data()[i]) / (2.0 * eps);
            assert!((num - g.data()[i]).abs() < 1e-3, "tanh idx {i}");
        }
        // relu (away from 0 kink)
        let g = relu_grad(&x, &dy);
        for i in 0..8 {
            if x.data()[i].abs() < 0.05 {
                continue;
            }
            let expect = if x.data()[i] > 0.0 { 1.0 } else { 0.0 };
            assert_eq!(g.data()[i], expect);
        }
    }
}
