//! pallas-lint: repo-invariant static analysis for `rust/src`.
//!
//! A std-only, line-oriented scanner that machine-checks the invariants this
//! repo previously kept only in comments and review habit:
//!
//! * **raw-sync** — `std::sync::{Mutex, Condvar}` may appear only in
//!   `runtime/sync.rs`; everywhere else the rank-ordered wrappers
//!   (`OrderedMutex` / `OrderedCondvar`) are mandatory so the lock-order
//!   sanitizer sees every acquisition.
//! * **alloc** — steady-state hot-path files (exchange driver, workspace,
//!   server, wire codecs, tensor kernels) must not introduce allocation
//!   tokens (`Blob::new(`, `vec![`, `.to_vec()`, `Vec::new(`) without a
//!   waiver naming why the allocation is not on the steady-state path.
//! * **panic** — hardened input paths (`comm/codec.rs`, `model/checkpoint.rs`,
//!   `config/mod.rs`, `utils/json.rs`) must not call `.unwrap()` /
//!   `.expect(` on malformed input; infallible uses carry a waiver.
//! * **target-feature** — `#[target_feature]` functions and the `avx2::`
//!   module are referenced only from `tensor/kernel.rs`, where runtime
//!   detection gates every call.
//! * **safety** — every `unsafe` block / `unsafe impl` carries a `// SAFETY:`
//!   comment within the ten preceding lines (`unsafe fn` *declarations* are
//!   contracts, not operations, and are enforced by
//!   `#![deny(unsafe_op_in_unsafe_fn)]` instead).
//!
//! Waiver syntax: `// lint: <rule>-ok(reason)` on the offending line or the
//! line directly above it. A waiver attached to a `fn` line covers the whole
//! function body. `#[cfg(test)]` modules are skipped entirely.
//!
//! String literals and comments are stripped before token matching, so
//! prose never trips a rule; waivers and `SAFETY:` markers are read from the
//! raw lines. Exit status: 0 clean, 1 findings, 2 usage/IO error.

use std::path::{Path, PathBuf};

const ALL_RULES: &[&str] = &["raw-sync", "alloc", "panic", "target-feature", "safety"];

/// Files where steady-state allocation tokens require a waiver.
const HOT_ALLOC_FILES: &[&str] = &[
    "coordinator/exchange.rs",
    "coordinator/workspace.rs",
    "server/mod.rs",
    "comm/codec.rs",
    "tensor/gemm.rs",
    "tensor/conv.rs",
    "tensor/ops.rs",
    "tensor/kernel.rs",
];

/// Hardened never-panic-on-input files.
const NO_PANIC_FILES: &[&str] =
    &["comm/codec.rs", "model/checkpoint.rs", "config/mod.rs", "utils/json.rs"];

/// The one file allowed to name raw `std::sync` primitives (it wraps them).
const SYNC_EXEMPT_FILES: &[&str] = &["runtime/sync.rs"];

/// The one file allowed to declare `#[target_feature]` fns or name `avx2::`.
const TARGET_FEATURE_HOME: &str = "tensor/kernel.rs";

const ALLOC_TOKENS: &[&str] = &["Blob::new(", "vec![", ".to_vec()", "Vec::new("];
const PANIC_TOKENS: &[&str] = &[".unwrap()", ".expect("];
const SYNC_WORDS: &[&str] = &["Mutex", "Condvar"];
const TF_TOKENS: &[&str] = &["#[target_feature", "avx2::"];

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    snippet: String,
}

fn main() {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src"),
    };
    match run(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("pallas-lint: clean ({})", root.display());
        }
        Ok(findings) => {
            for f in &findings {
                println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.snippet);
            }
            println!(
                "pallas-lint: {} finding(s). Waive with `// lint: <rule>-ok(reason)` on the \
                 line, the line above, or a `fn` line to cover that function.",
                findings.len()
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("pallas-lint: error: {e}");
            std::process::exit(2);
        }
    }
}

fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        scan_file(&rel, &text, &mut findings);
    }
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|x| x.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scoped waiver: attached to a `fn` line, it covers until the function's
/// closing brace.
struct FnWaiver {
    rule: &'static str,
    base_depth: i64,
    entered_body: bool,
}

fn scan_file(rel: &str, text: &str, out: &mut Vec<Finding>) {
    let code = strip_noncode(text);
    let raw_lines: Vec<&str> = text.lines().collect();
    let code_lines: Vec<&str> = code.lines().collect();
    let n = raw_lines.len().min(code_lines.len());

    let hot = HOT_ALLOC_FILES.contains(&rel);
    let no_panic = NO_PANIC_FILES.contains(&rel);
    let sync_exempt = SYNC_EXEMPT_FILES.contains(&rel);
    let tf_home = rel == TARGET_FEATURE_HOME;

    let mut depth: i64 = 0;
    let mut test_mod_close: Option<i64> = None;
    let mut pending_cfg_test = false;
    let mut fn_waivers: Vec<FnWaiver> = Vec::new();
    let mut carried: Vec<&'static str> = Vec::new();

    for i in 0..n {
        let raw = raw_lines[i];
        let cl = code_lines[i];
        let depth_before = depth;
        let opens = cl.bytes().filter(|&b| b == b'{').count() as i64;
        let closes = cl.bytes().filter(|&b| b == b'}').count() as i64;
        depth = depth_before + opens - closes;

        // Waivers written on this raw line (comments included).
        let mut here: Vec<&'static str> = Vec::new();
        for &rule in ALL_RULES {
            if raw.contains(&format!("lint: {rule}-ok(")) {
                here.push(rule);
            }
        }
        let mut effective: Vec<&'static str> = here.clone();
        effective.extend(carried.iter().copied());
        effective.extend(fn_waivers.iter().map(|w| w.rule));

        // `#[cfg(test)] mod ... { }` bodies are out of scope for every rule.
        if test_mod_close.is_none() {
            if cl.contains("#[cfg(test)]") {
                pending_cfg_test = true;
            }
            if pending_cfg_test {
                if !find_word(cl, "mod").is_empty() {
                    test_mod_close = Some(depth_before);
                    pending_cfg_test = false;
                } else if !cl.trim().is_empty() && !cl.trim_start().starts_with("#[") {
                    // The cfg(test) attached to something other than a mod
                    // (a fn, a use): stop waiting for one.
                    pending_cfg_test = false;
                }
            }
        }
        let in_test = test_mod_close.is_some();

        // A waiver attached to a `fn` line covers the whole function.
        if (!here.is_empty() || !carried.is_empty()) && !find_word(cl, "fn").is_empty() {
            for &rule in here.iter().chain(carried.iter()) {
                if depth > depth_before {
                    // Body opened on this line and is still open.
                    fn_waivers.push(FnWaiver { rule, base_depth: depth_before, entered_body: true });
                } else if depth == depth_before && opens == 0 {
                    // Multi-line signature: body opens on a later line.
                    fn_waivers.push(FnWaiver { rule, base_depth: depth_before, entered_body: false });
                }
                // One-line fn (opened and closed here): same-line coverage
                // already applied; nothing outlives this line.
            }
        }

        if !in_test {
            let waived = |rule: &str| effective.iter().any(|&w| w == rule);
            if !sync_exempt && !waived("raw-sync") {
                for &w in SYNC_WORDS {
                    if !find_word(cl, w).is_empty() {
                        push(out, rel, i + 1, "raw-sync", raw);
                        break;
                    }
                }
            }
            if hot && !waived("alloc") && ALLOC_TOKENS.iter().any(|t| cl.contains(t)) {
                push(out, rel, i + 1, "alloc", raw);
            }
            if no_panic && !waived("panic") && PANIC_TOKENS.iter().any(|t| cl.contains(t)) {
                push(out, rel, i + 1, "panic", raw);
            }
            if !tf_home
                && !waived("target-feature")
                && TF_TOKENS.iter().any(|t| cl.contains(t))
            {
                push(out, rel, i + 1, "target-feature", raw);
            }
            if !waived("safety") && has_unsafe_op(cl) {
                let lo = i.saturating_sub(10);
                let documented = raw_lines[lo..=i].iter().any(|l| l.contains("SAFETY:"));
                if !documented {
                    push(out, rel, i + 1, "safety", raw);
                }
            }
        }

        // Close the test module once its brace depth unwinds.
        if let Some(d) = test_mod_close {
            if depth <= d {
                test_mod_close = None;
            }
        }
        // Comment-only (or blank) lines carry their waivers to the next code
        // line; a code line consumes them.
        if cl.trim().is_empty() {
            for &rule in &here {
                if !carried.contains(&rule) {
                    carried.push(rule);
                }
            }
        } else {
            carried.clear();
        }
        for w in fn_waivers.iter_mut() {
            if depth > w.base_depth {
                w.entered_body = true;
            }
        }
        fn_waivers.retain(|w| !(w.entered_body && depth <= w.base_depth));
    }
}

fn push(out: &mut Vec<Finding>, rel: &str, line: usize, rule: &'static str, raw: &str) {
    let mut snippet: String = raw.trim().chars().take(120).collect();
    if raw.trim().chars().count() > 120 {
        snippet.push('…');
    }
    out.push(Finding { file: rel.to_string(), line, rule, snippet });
}

/// `unsafe` occurrences that are operations (blocks, `unsafe impl`), not
/// `unsafe fn` declarations.
fn has_unsafe_op(cl: &str) -> bool {
    for at in find_word(cl, "unsafe") {
        let rest = cl[at + "unsafe".len()..].trim_start();
        let is_fn_decl = rest.starts_with("fn")
            && rest[2..].chars().next().map(|c| !is_ident_char(c)).unwrap_or(true);
        if !is_fn_decl {
            return true;
        }
    }
    false
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Byte offsets where `word` occurs with non-identifier characters (or line
/// edges) on both sides.
fn find_word(code: &str, word: &str) -> Vec<usize> {
    let cb = code.as_bytes();
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident_byte(cb[at - 1]);
        let after_ok = end >= cb.len() || !is_ident_byte(cb[end]);
        if before_ok && after_ok {
            hits.push(at);
        }
        from = at + word.len();
    }
    hits
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Replace string-literal contents and comments with spaces (newlines kept),
/// so token matching only ever sees code. Handles line + nested block
/// comments, plain/byte strings with escapes, raw strings `r#".."#`, and
/// char literals vs lifetimes.
fn strip_noncode(src: &str) -> String {
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
    }
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut st = St::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            out.push(b'\n');
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == b'/' && b.get(i + 1) == Some(&b'/') {
                    st = St::LineComment;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    st = St::BlockComment(1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    st = St::Str;
                    out.push(b'"');
                    i += 1;
                } else if let Some(hashes) = raw_string_open(b, i) {
                    let skip = raw_prefix_len(b, i) + hashes + 1; // prefix + #s + quote
                    for _ in 0..skip {
                        out.push(b' ');
                    }
                    st = St::RawStr(hashes);
                    i += skip;
                } else if c == b'b' && b.get(i + 1) == Some(&b'"') && prev_not_ident(b, i) {
                    out.extend_from_slice(b" \"");
                    st = St::Str;
                    i += 2;
                } else if c == b'\'' {
                    if let Some(end) = char_literal_end(b, i) {
                        for _ in i..=end {
                            out.push(b' ');
                        }
                        i = end + 1;
                    } else {
                        out.push(c); // lifetime tick
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                out.push(b' ');
                i += 1;
            }
            St::BlockComment(d) => {
                if c == b'*' && b.get(i + 1) == Some(&b'/') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    st = if d == 1 { St::Code } else { St::BlockComment(d - 1) };
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    st = St::BlockComment(d + 1);
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == b'\\' {
                    // Preserve the newline of a `\`-continued string so raw
                    // and stripped line numbering stay aligned.
                    if b.get(i + 1) == Some(&b'\n') {
                        out.extend_from_slice(b" \n");
                    } else {
                        out.extend_from_slice(b"  ");
                    }
                    i += 2;
                } else if c == b'"' {
                    out.push(b'"');
                    st = St::Code;
                    i += 1;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == b'"' && b[i + 1..].iter().take(hashes).filter(|&&x| x == b'#').count() == hashes
                {
                    for _ in 0..=hashes {
                        out.push(b' ');
                    }
                    i += 1 + hashes;
                    st = St::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
        }
    }
    out.truncate(b.len());
    String::from_utf8(out).unwrap_or_default()
}

/// At `i` in code state: does a raw-string literal (`r"`, `r#"`, `br"`, …)
/// open here? Returns the hash count.
fn raw_string_open(b: &[u8], i: usize) -> Option<usize> {
    if !prev_not_ident(b, i) {
        return None;
    }
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some(hashes)
    } else {
        None
    }
}

fn raw_prefix_len(b: &[u8], i: usize) -> usize {
    if b.get(i) == Some(&b'b') {
        2 // `br`
    } else {
        1 // `r`
    }
}

fn prev_not_ident(b: &[u8], i: usize) -> bool {
    i == 0 || !is_ident_byte(b[i - 1])
}

/// If a char literal opens at the `'` at `i`, return the index of its
/// closing quote; `None` means it is a lifetime tick.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    match b.get(i + 1) {
        Some(&b'\\') => {
            // Escaped: `'\n'`, `'\''`, `'\x41'`, `'\u{1F600}'` — the
            // escaped char at i+2 is consumed, closing quote comes later.
            (i + 3..=(i + 14).min(b.len().saturating_sub(1)))
                .find(|&j| b[j] == b'\'')
        }
        Some(&ch) => {
            if b.get(i + 2) == Some(&b'\'') {
                Some(i + 2) // single-byte char
            } else if ch >= 0x80 {
                // A single multibyte char, closing within a few bytes.
                (i + 2..=(i + 5).min(b.len().saturating_sub(1)))
                    .find(|&j| b[j] == b'\'')
            } else {
                None // `'a` lifetime
            }
        }
        None => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, text: &str) -> Vec<(usize, &'static str)> {
        let mut out = Vec::new();
        scan_file(rel, text, &mut out);
        out.into_iter().map(|f| (f.line, f.rule)).collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let code = strip_noncode("let x = \"Mutex\"; // Mutex here\n/* Mutex */ let y = 1;\n");
        assert!(!code.contains("Mutex"), "{code}");
        assert!(code.contains("let x ="));
        assert!(code.contains("let y = 1;"));
    }

    #[test]
    fn raw_strings_and_char_literals_are_stripped() {
        let code = strip_noncode("let s = r#\"Mutex \" inner\"#; let c = '\"'; let d = 'x';\n");
        assert!(!code.contains("Mutex"), "{code}");
        assert!(!code.contains("inner"), "{code}");
        assert!(code.contains("let d ="), "{code}");
        // Lifetimes survive as code.
        let code = strip_noncode("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(code.contains("<'a>"), "{code}");
    }

    #[test]
    fn word_boundary_spares_wrapper_names() {
        assert!(find_word("let m = OrderedMutex::new(1, \"s\", 0);", "Mutex").is_empty());
        assert!(!find_word("use std::sync::Mutex;", "Mutex").is_empty());
        assert!(find_word("MutexGuard", "Mutex").is_empty());
    }

    #[test]
    fn raw_sync_rule_fires_outside_sync_module() {
        let hits = scan("server/mod.rs", "use std::sync::Mutex;\n");
        assert_eq!(hits, vec![(1, "raw-sync")]);
        assert!(scan("runtime/sync.rs", "use std::sync::Mutex;\n").is_empty());
        // Doc prose does not count.
        assert!(scan("server/mod.rs", "/// a Mutex-shaped story\n").is_empty());
    }

    #[test]
    fn alloc_rule_respects_waivers_and_tests() {
        let src = "fn f() {\n    let v = vec![0u8; 4];\n}\n";
        assert_eq!(scan("comm/codec.rs", src), vec![(2, "alloc")]);
        assert!(scan("cluster/mod.rs", src).is_empty(), "non-hot file");
        let waived = "fn f() {\n    let v = vec![0u8; 4]; // lint: alloc-ok(test scratch)\n}\n";
        assert!(scan("comm/codec.rs", waived).is_empty());
        let above = "fn f() {\n    // lint: alloc-ok(scratch)\n    let v = vec![0u8; 4];\n}\n";
        assert!(scan("comm/codec.rs", above).is_empty());
        let tests = "#[cfg(test)]\nmod tests {\n    fn f() { let v = vec![0u8; 4]; }\n}\n";
        assert!(scan("comm/codec.rs", tests).is_empty());
    }

    #[test]
    fn fn_scoped_waiver_covers_whole_body() {
        let src = "fn build() -> V { // lint: alloc-ok(construction)\n    let a = Vec::new();\n    let b = vec![0; 3];\n    b\n}\nfn other() {\n    let c = Vec::new();\n}\n";
        assert_eq!(scan("server/mod.rs", src), vec![(7, "alloc")]);
    }

    #[test]
    fn panic_rule_only_in_hardened_files() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        assert_eq!(scan("utils/json.rs", src), vec![(2, "panic")]);
        assert!(scan("tensor/gemm.rs", src).is_empty());
        let waived = "fn f(x: Option<u8>) -> u8 {\n    // lint: panic-ok(checked above)\n    x.unwrap()\n}\n";
        assert!(scan("utils/json.rs", waived).is_empty());
    }

    #[test]
    fn target_feature_rule_keeps_kernels_contained() {
        let src = "fn f() { avx2::copy_span(p, q, n); }\n";
        assert_eq!(scan("tensor/gemm.rs", src), vec![(1, "target-feature")]);
        assert!(scan("tensor/kernel.rs", src).is_empty());
    }

    #[test]
    fn unsafe_needs_nearby_safety_comment() {
        let bad = "fn f() {\n    unsafe { do_it() }\n}\n";
        assert_eq!(scan("model/net.rs", bad), vec![(2, "safety")]);
        let good = "fn f() {\n    // SAFETY: checked by caller.\n    unsafe { do_it() }\n}\n";
        assert!(scan("model/net.rs", good).is_empty());
        // Declarations are the compiler's job (unsafe_op_in_unsafe_fn).
        let decl = "unsafe fn g() {}\n";
        assert!(scan("model/net.rs", decl).is_empty());
    }
}
