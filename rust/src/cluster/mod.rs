//! Cluster topology (paper §5.2): the number and size of worker and server
//! groups determines the training framework. Worker groups run
//! asynchronously against their server group; workers inside a group run
//! synchronously.
//!
//! | Framework            | worker groups | group size | server groups |
//! |----------------------|---------------|------------|---------------|
//! | Sandblaster (Fig 11a)| 1             | W          | 1 (global)    |
//! | AllReduce  (Fig 11b) | 1             | W          | 1, server/node|
//! | Downpour   (Fig 11c) | G > 1         | W/G        | 1 (global)    |
//! | Hogwild    (Fig 11d) | G > 1         | W/G        | G (local)     |

use crate::comm::{CostModel, LinkModel};

/// The four classic frameworks as presets; `Custom` covers the full design
/// space (the paper's hybrid framework search).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    Sandblaster,
    AllReduce,
    Downpour,
    DistributedHogwild,
}

/// Cluster topology configuration — the fourth component of a SINGA job
/// (paper §3).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTopology {
    /// Number of worker groups (model replicas). >1 → asynchronous.
    pub nworker_groups: usize,
    /// Workers per group (synchronous parallelism inside a group).
    pub nworkers_per_group: usize,
    /// Number of server groups.
    pub nserver_groups: usize,
    /// Servers (shards) per server group.
    pub nservers_per_group: usize,
    /// Steps between neighbouring server-group synchronizations
    /// (distributed Hogwild); 0 disables.
    pub group_sync_interval: u64,
}

impl ClusterTopology {
    /// Sandblaster (Fig 11a): one worker group, one global server group.
    pub fn sandblaster(workers: usize, servers: usize) -> ClusterTopology {
        ClusterTopology {
            nworker_groups: 1,
            nworkers_per_group: workers,
            nserver_groups: 1,
            nservers_per_group: servers,
            group_sync_interval: 0,
        }
    }

    /// AllReduce (Fig 11b): one worker group spanning `nodes`, one server
    /// bound per node (server group size = node count).
    pub fn allreduce(nodes: usize, workers_per_node: usize) -> ClusterTopology {
        ClusterTopology {
            nworker_groups: 1,
            nworkers_per_group: nodes * workers_per_node,
            nserver_groups: 1,
            nservers_per_group: nodes,
            group_sync_interval: 0,
        }
    }

    /// Downpour (Fig 11c): several asynchronous groups sharing one global
    /// server group.
    pub fn downpour(groups: usize, workers_per_group: usize, servers: usize) -> ClusterTopology {
        ClusterTopology {
            nworker_groups: groups,
            nworkers_per_group: workers_per_group,
            nserver_groups: 1,
            nservers_per_group: servers,
            group_sync_interval: 0,
        }
    }

    /// Distributed Hogwild (Fig 11d): one worker group + one server group
    /// per node; neighbours sync every `sync_interval` steps.
    pub fn hogwild(nodes: usize, workers_per_node: usize, sync_interval: u64) -> ClusterTopology {
        ClusterTopology {
            nworker_groups: nodes,
            nworkers_per_group: workers_per_node,
            nserver_groups: nodes,
            nservers_per_group: 1,
            group_sync_interval: sync_interval,
        }
    }

    /// Which preset this topology realizes (None for custom hybrids).
    pub fn framework(&self) -> Option<Framework> {
        match (self.nworker_groups, self.nserver_groups) {
            (1, 1) if self.nservers_per_group == 1 => Some(Framework::Sandblaster),
            (1, 1) => Some(Framework::AllReduce),
            (g, 1) if g > 1 => Some(Framework::Downpour),
            (g, s) if g > 1 && g == s => Some(Framework::DistributedHogwild),
            _ => None,
        }
    }

    /// Synchronous ⇔ a single worker group (identical convergence to
    /// sequential SGD, §5.2.1).
    pub fn is_synchronous(&self) -> bool {
        self.nworker_groups == 1
    }

    /// Total worker count.
    pub fn total_workers(&self) -> usize {
        self.nworker_groups * self.nworkers_per_group
    }

    /// Server group index serving worker group `g` (round-robin).
    pub fn server_group_of(&self, worker_group: usize) -> usize {
        worker_group % self.nserver_groups
    }

    /// The link parameter traffic travels over in this topology: a single
    /// co-located server group shares memory with its workers, while
    /// multi-server-group or sharded-server deployments reach their servers
    /// across the cluster network. Single source of truth for the fetch and
    /// push paths (previously duplicated inline conditionals that could
    /// drift apart).
    pub fn param_link<'a>(&self, cost: &'a CostModel) -> &'a LinkModel {
        if self.nserver_groups > 1 || self.nservers_per_group > 1 {
            &cost.network
        } else {
            &cost.intra_node
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_classify() {
        assert_eq!(
            ClusterTopology::sandblaster(4, 1).framework(),
            Some(Framework::Sandblaster)
        );
        assert_eq!(ClusterTopology::allreduce(8, 4).framework(), Some(Framework::AllReduce));
        assert_eq!(
            ClusterTopology::downpour(4, 2, 8).framework(),
            Some(Framework::Downpour)
        );
        assert_eq!(
            ClusterTopology::hogwild(4, 2, 100).framework(),
            Some(Framework::DistributedHogwild)
        );
    }

    #[test]
    fn sync_vs_async() {
        assert!(ClusterTopology::sandblaster(16, 4).is_synchronous());
        assert!(ClusterTopology::allreduce(32, 4).is_synchronous());
        assert!(!ClusterTopology::downpour(2, 1, 1).is_synchronous());
    }

    #[test]
    fn param_link_picks_network_only_for_remote_servers() {
        let cost = CostModel::numa_server();
        // one local server group, one shard: shared memory
        let local = ClusterTopology::sandblaster(4, 1);
        assert_eq!(*local.param_link(&cost), cost.intra_node);
        // sharded servers cross the network
        let sharded = ClusterTopology::sandblaster(4, 3);
        assert_eq!(*sharded.param_link(&cost), cost.network);
        // multiple server groups cross the network
        let hogwild = ClusterTopology::hogwild(2, 1, 10);
        assert_eq!(*hogwild.param_link(&cost), cost.network);
    }

    #[test]
    fn worker_counts_and_routing() {
        let t = ClusterTopology::hogwild(4, 3, 10);
        assert_eq!(t.total_workers(), 12);
        assert_eq!(t.server_group_of(0), 0);
        assert_eq!(t.server_group_of(3), 3);
        let d = ClusterTopology::downpour(4, 1, 2);
        assert_eq!(d.server_group_of(3), 0); // single global group
    }
}
