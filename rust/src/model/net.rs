//! `NeuralNet`: the dataflow graph of layers (paper §4.1.1).
//!
//! Users declare `LayerConf`s (each recording its *source* layers, Fig 4b);
//! `NetBuilder::build` instantiates layer objects, topologically sorts the
//! graph, runs shape inference (`Layer::setup`) and produces a `NeuralNet`
//! ready for the `TrainOneBatch` algorithms. Distributed training assigns
//! sub-graphs to workers (paper §4.1.2) — see [`super::partition`].

use super::layer::{create_layer, Layer, LayerConf, Phase};
use super::layers_basic::InputLayer;
use crate::tensor::blob::Param;
use crate::tensor::Blob;
use crate::utils::rng::Rng;
use std::cell::Cell;
use std::collections::HashMap;

thread_local! {
    /// Executor-scratch allocations charged to this thread: growth of the
    /// reused per-node ref lists, slot stores, and the duplicate-source
    /// scratch pool. The same pattern as `Blob::alloc_count` /
    /// `gemm::pack_alloc_count`, one level up — the steady-state alloc
    /// probe in [`crate::bench`] asserts it stays flat after warm-up.
    static EXEC_SCRATCH_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Executor-scratch allocations made by the current thread so far (see
/// [`crate::bench::alloc_probe`]): grows only while the reused forward /
/// backward scratch warms up, then stays flat.
pub fn exec_scratch_alloc_count() -> u64 {
    EXEC_SCRATCH_ALLOCS.with(|c| c.get())
}

fn note_exec_alloc() {
    EXEC_SCRATCH_ALLOCS.with(|c| c.set(c.get() + 1));
}

/// Ensure `v` (assumed just cleared) can hold `n` elements, counting pool
/// growth on the executor-scratch counter.
fn reserve_counted<T>(v: &mut Vec<T>, n: usize) {
    if v.capacity() < n {
        note_exec_alloc();
        v.reserve(n);
    }
}

/// Reusable backing store for the per-node `&Blob` source lists the
/// executor hands to `compute_feature` / `compute_gradient`: rebuilt in
/// place each node, so steady-state passes allocate nothing. Between calls
/// the vector holds stale pointers that are never dereferenced.
#[derive(Default)]
struct SrcRefs(Vec<*const Blob>);

// SAFETY: the raw pointers are inert storage between calls; they are only
// read through the slice `fill` returns, whose every entry was re-derived
// from a live reference inside the same call.
unsafe impl Send for SrcRefs {}

impl SrcRefs {
    fn fill<'a>(&mut self, feats: &'a [Blob], idxs: &[usize]) -> &[&'a Blob] {
        self.0.clear();
        reserve_counted(&mut self.0, idxs.len());
        for &s in idxs {
            self.0.push(&feats[s] as *const Blob);
        }
        // SAFETY: `&Blob` and `*const Blob` have identical layout, every
        // entry was just derived from a live `&'a Blob`, and the returned
        // slice keeps `self` borrowed (no refill) and `'a` alive while it
        // is in use.
        unsafe { std::slice::from_raw_parts(self.0.as_ptr() as *const &'a Blob, self.0.len()) }
    }
}

/// Reusable backing store for the `Option<&mut Blob>` slot lists handed to
/// `compute_gradient` (null = `None`), exploiting the guaranteed niche
/// layout of `Option<&mut Blob>`. Same reuse story as [`SrcRefs`].
#[derive(Default)]
struct SlotRefs(Vec<*mut Blob>);

// SAFETY: as for `SrcRefs` — stale pointers are never dereferenced.
unsafe impl Send for SlotRefs {}

impl SlotRefs {
    fn fill<'a>(&mut self, store: &'a mut [Option<Blob>]) -> &mut [Option<&'a mut Blob>] {
        self.0.clear();
        reserve_counted(&mut self.0, store.len());
        for slot in store.iter_mut() {
            self.0.push(match slot {
                Some(b) => b as *mut Blob,
                None => std::ptr::null_mut(),
            });
        }
        // SAFETY: `Option<&mut Blob>` is guaranteed pointer-sized with
        // `None` ⇔ null (niche optimization); each non-null entry points at
        // a distinct live slot of `store`, whose `&'a mut` borrow the
        // returned slice keeps alive — so the handed-out `&mut Blob`s are
        // disjoint and exclusive for the slice's lifetime.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.0.as_mut_ptr() as *mut Option<&'a mut Blob>,
                self.0.len(),
            )
        }
    }
}

/// One vertex of the dataflow graph.
pub struct Node {
    pub layer: Box<dyn Layer>,
    /// Indices of source nodes (always smaller than this node's index after
    /// topological sorting).
    pub srcs: Vec<usize>,
    /// Indices of consumer nodes.
    pub consumers: Vec<usize>,
    /// Inferred output shape.
    pub out_shape: Vec<usize>,
    /// Worker slot this node is placed on (0 when unpartitioned).
    pub location: usize,
}

/// The preallocated buffer pool backing the planned executor: one feature
/// blob and one gradient blob per node, sized from the inferred shapes at
/// `NetBuilder::build` time and reused every step. Gradient slots are
/// zeroed lazily (only when a consumer is about to write) and tracked by
/// `grad_seen`, which doubles as the "did any gradient reach this node"
/// signal the backward pass uses to skip dead paths.
pub struct Workspace {
    features: Vec<Blob>,
    grads: Vec<Blob>,
    grad_seen: Vec<bool>,
    /// Reused per-node backward store: the gradient slots moved out of
    /// `grads` for the duration of one `compute_gradient` call. Cleared and
    /// refilled each node into retained capacity — no per-step allocation.
    slot_store: Vec<Option<Blob>>,
    /// Parallel to `slot_store`: marks slots backed by duplicate-source
    /// scratch rather than the canonical gradient blob.
    is_dup: Vec<bool>,
    /// Preallocated scratch accumulators for the duplicate-source fallback
    /// (a layer listing the same source twice): grown at first use, parked
    /// and reused every step after.
    dup_scratch: Vec<Blob>,
}

impl Workspace {
    fn for_shapes(shapes: &[&[usize]]) -> Workspace {
        Workspace {
            features: shapes.iter().map(|s| Blob::zeros(s)).collect(),
            grads: shapes.iter().map(|s| Blob::zeros(s)).collect(),
            grad_seen: vec![false; shapes.len()],
            slot_store: Vec::new(),
            is_dup: Vec::new(),
            dup_scratch: Vec::new(),
        }
    }

    /// Feature blob of node `i` (most recent forward pass).
    pub fn feature(&self, i: usize) -> &Blob {
        &self.features[i]
    }

    /// Accumulated gradient w.r.t. node `i`'s feature, if any consumer
    /// produced one during the most recent backward pass.
    pub fn grad(&self, i: usize) -> Option<&Blob> {
        if self.grad_seen[i] {
            Some(&self.grads[i])
        } else {
            None
        }
    }

    /// Total bytes held by the pool (capacity accounting).
    pub fn byte_size(&self) -> usize {
        self.features.iter().chain(&self.grads).map(|b| b.byte_size()).sum()
    }
}

/// Observer of backward-pass progress: [`GradObserver::grads_ready`] fires
/// once per node and step, at the moment that node's *parameter* gradients
/// are final — for BP, in reverse-topological order right after its
/// `compute_gradient` returns (the paper's per-layer transfer hook: "the
/// gradients are sent as soon as the layer finishes its ComputeGradient").
/// Parameter-less and skipped nodes fire too, so an observer counting
/// completions always reaches its target. The net is borrowed shared
/// during the callback: observers may read features, gradients, and params
/// but not mutate the net.
pub trait GradObserver {
    fn grads_ready(&mut self, net: &NeuralNet, node: usize);
}

/// No-op observer backing the plain [`NeuralNet::backward`] entry point.
pub struct NoopObserver;

impl GradObserver for NoopObserver {
    fn grads_ready(&mut self, _net: &NeuralNet, _node: usize) {}
}

/// The neural net instance passed to `TrainOneBatch` (paper Fig 6).
pub struct NeuralNet {
    nodes: Vec<Node>,
    by_name: HashMap<String, usize>,
    ws: Workspace,
    /// Reused executor scratch (see [`SrcRefs`] / [`SlotRefs`]).
    src_refs: SrcRefs,
    slot_refs: SlotRefs,
}

/// Builder accumulating layer configurations.
#[derive(Default, Clone)]
pub struct NetBuilder {
    confs: Vec<LayerConf>,
}

impl NetBuilder {
    pub fn new() -> NetBuilder {
        NetBuilder { confs: Vec::new() }
    }

    /// Append a layer configuration.
    pub fn add(mut self, conf: LayerConf) -> NetBuilder {
        self.confs.push(conf);
        self
    }

    pub fn confs(&self) -> &[LayerConf] {
        &self.confs
    }

    pub fn confs_mut(&mut self) -> &mut Vec<LayerConf> {
        &mut self.confs
    }

    /// Instantiate, topo-sort and shape-infer the net.
    ///
    /// Panics on malformed graphs: unknown source names, duplicate layer
    /// names, or cycles (recurrent connections must be unrolled first —
    /// paper Fig 5).
    pub fn build(self, rng: &mut Rng) -> NeuralNet {
        let mut by_name: HashMap<String, usize> = HashMap::new();
        for (i, c) in self.confs.iter().enumerate() {
            if by_name.insert(c.name.clone(), i).is_some() {
                panic!("duplicate layer name '{}'", c.name);
            }
        }
        // Adjacency on config indices.
        let n = self.confs.len();
        let mut srcs: Vec<Vec<usize>> = Vec::with_capacity(n);
        for c in &self.confs {
            let s: Vec<usize> = c
                .srcs
                .iter()
                .map(|s| {
                    *by_name
                        .get(s)
                        .unwrap_or_else(|| panic!("layer '{}': unknown source '{s}'", c.name))
                })
                .collect();
            srcs.push(s);
        }
        // Kahn topological sort.
        let mut indegree: Vec<usize> = srcs.iter().map(|s| s.len()).collect();
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, s) in srcs.iter().enumerate() {
            for &j in s {
                consumers[j].push(i);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut qi = 0;
        while qi < queue.len() {
            let u = queue[qi];
            qi += 1;
            order.push(u);
            for &v in &consumers[u] {
                indegree[v] -= 1;
                if indegree[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(
            order.len(),
            n,
            "cycle detected in the layer graph; unroll recurrent connections (paper Fig 5)"
        );
        // Remap to topo positions.
        let mut pos = vec![0usize; n];
        for (p, &i) in order.iter().enumerate() {
            pos[i] = p;
        }
        let mut nodes: Vec<Node> = Vec::with_capacity(n);
        let mut final_by_name = HashMap::new();
        for &ci in &order {
            let conf = &self.confs[ci];
            let layer = create_layer(conf);
            final_by_name.insert(conf.name.clone(), nodes.len());
            nodes.push(Node {
                layer,
                srcs: srcs[ci].iter().map(|&s| pos[s]).collect(),
                consumers: consumers[ci].iter().map(|&c| pos[c]).collect(),
                out_shape: Vec::new(),
                location: conf.location.unwrap_or(0),
            });
        }
        // Shape inference in topo order.
        for i in 0..nodes.len() {
            let (before, rest) = nodes.split_at_mut(i);
            let node = &mut rest[0];
            let src_shapes: Vec<&[usize]> =
                node.srcs.iter().map(|&s| before[s].out_shape.as_slice()).collect();
            node.out_shape = node.layer.setup(&src_shapes, rng);
        }
        // Build the workspace from the inferred shapes: the plan's feature
        // and gradient buffers, allocated once and reused every step.
        let shapes: Vec<&[usize]> = nodes.iter().map(|n| n.out_shape.as_slice()).collect();
        let ws = Workspace::for_shapes(&shapes);
        NeuralNet {
            nodes,
            by_name: final_by_name,
            ws,
            src_refs: SrcRefs::default(),
            slot_refs: SlotRefs::default(),
        }
    }
}

impl NeuralNet {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn nodes_mut(&mut self) -> &mut [Node] {
        &mut self.nodes
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    /// The workspace backing this net's executor.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Disjoint mutable access to the layer graph alongside shared access to
    /// the workspace — what algorithm drivers (e.g. CD) need to run layer
    /// internals against already-materialized features without cloning them.
    pub fn split_mut(&mut self) -> (&mut [Node], &Workspace) {
        (&mut self.nodes, &self.ws)
    }

    /// Feed a mini-batch into the named input layer if it exists (data
    /// sources may provide fields a net does not consume, e.g. labels
    /// during unsupervised RBM pre-training). Returns whether it was set.
    pub fn try_set_input(&mut self, name: &str, batch: Blob) -> bool {
        self.try_set_input_ref(name, &batch)
    }

    /// Borrowing variant of [`NeuralNet::try_set_input`]: copies the batch
    /// into the input layer's workspace slot without consuming (or cloning)
    /// the caller's blob.
    pub fn try_set_input_ref(&mut self, name: &str, batch: &Blob) -> bool {
        if self.index_of(name).is_none() {
            return false;
        }
        self.set_input_ref(name, batch);
        true
    }

    /// Feed a mini-batch into the named input layer.
    pub fn set_input(&mut self, name: &str, batch: Blob) {
        self.set_input_ref(name, &batch);
    }

    /// Feed a mini-batch into the named input layer by copying it straight
    /// into the layer's workspace slot — the zero-allocation input path
    /// (the slot only reallocates when the batch size changes).
    pub fn set_input_ref(&mut self, name: &str, batch: &Blob) {
        let idx = self.index_of(name).unwrap_or_else(|| panic!("no layer '{name}'"));
        self.nodes[idx]
            .layer
            .as_any()
            .downcast_mut::<InputLayer>()
            .unwrap_or_else(|| panic!("layer '{name}' is not an Input layer"))
            .mark_fed();
        self.ws.features[idx].copy_from(batch);
    }

    /// Forward pass over all layers in topological order (first loop of the
    /// paper's Algorithm 1). Each layer writes into its preallocated
    /// workspace slot; sources are read from the slots of earlier nodes.
    /// The source ref lists are rebuilt in reused scratch, so a steady-state
    /// pass performs zero heap allocations in the executor.
    pub fn forward(&mut self, phase: Phase) {
        for seen in self.ws.grad_seen.iter_mut() {
            *seen = false;
        }
        for i in 0..self.nodes.len() {
            let node = &mut self.nodes[i];
            let (before, rest) = self.ws.features.split_at_mut(i);
            let out = &mut rest[0];
            let src_feats = self.src_refs.fill(before, &node.srcs);
            node.layer.compute_feature(phase, src_feats, out);
        }
    }

    /// Backward pass in reverse topological order (second loop of
    /// Algorithm 1): each layer accumulates into the pre-zeroed gradient
    /// slots of its sources — no per-step gradient allocation.
    pub fn backward(&mut self) {
        self.backward_observed(&mut NoopObserver);
    }

    /// [`NeuralNet::backward`] with completion hooks: after each node's
    /// gradients are final (its `compute_gradient` returned, or it was
    /// skipped — inputs and dead paths), `obs.grads_ready(net, i)` fires.
    /// This is what lets the coordinator flush a layer's parameter
    /// gradients to the servers while backward continues on the layers
    /// below (the overlapped exchange pipeline).
    pub fn backward_observed(&mut self, obs: &mut dyn GradObserver) {
        for i in (0..self.nodes.len()).rev() {
            self.backward_node(i);
            obs.grads_ready(self, i);
        }
    }

    /// Run one node's slice of the backward pass (no-op for input layers
    /// and nodes no gradient reached).
    fn backward_node(&mut self, i: usize) {
        let node = &mut self.nodes[i];
        if node.srcs.is_empty() {
            return; // input layers
        }
        let has_grad = self.ws.grad_seen[i];
        if !has_grad && !node.layer.is_loss() {
            // No gradient reached this node (e.g. the label parser
            // path); nothing to propagate.
            return;
        }
        // Lazily zero the source slots this layer will write (first
        // contribution of the step only), resizing if the runtime batch
        // changed since the workspace was planned.
        for (k, &s) in node.srcs.iter().enumerate() {
            if node.layer.needs_src_grad(k) && !self.ws.grad_seen[s] {
                self.ws.grads[s].resize(self.ws.features[s].shape());
                self.ws.grads[s].fill(0.0);
                self.ws.grad_seen[s] = true;
            }
        }
        // Move the writable slots out of the pool into the REUSED store
        // so the layer gets disjoint `&mut` access (duplicate sources —
        // legal but rare — borrow a preallocated scratch accumulator
        // merged back below). Everything here runs in retained
        // capacity: zero heap allocations at steady state.
        let nsrc = node.srcs.len();
        self.ws.slot_store.clear();
        self.ws.is_dup.clear();
        reserve_counted(&mut self.ws.slot_store, nsrc);
        reserve_counted(&mut self.ws.is_dup, nsrc);
        let mut ndup = 0usize;
        for (k, &s) in node.srcs.iter().enumerate() {
            if !node.layer.needs_src_grad(k) {
                self.ws.slot_store.push(None);
                self.ws.is_dup.push(false);
                continue;
            }
            let taken_before = node.srcs[..k]
                .iter()
                .enumerate()
                .any(|(p, &ps)| ps == s && node.layer.needs_src_grad(p));
            if taken_before {
                if ndup == self.ws.dup_scratch.len() {
                    note_exec_alloc();
                    self.ws.dup_scratch.push(Blob::default());
                }
                let mut scratch = std::mem::take(&mut self.ws.dup_scratch[ndup]);
                ndup += 1;
                scratch.resize(self.ws.features[s].shape());
                scratch.fill(0.0);
                self.ws.slot_store.push(Some(scratch));
                self.ws.is_dup.push(true);
            } else {
                self.ws.slot_store.push(Some(std::mem::take(&mut self.ws.grads[s])));
                self.ws.is_dup.push(false);
            }
        }
        {
            let src_feats = self.src_refs.fill(&self.ws.features, &node.srcs);
            let own = &self.ws.features[i];
            let grad_out = if has_grad { Some(&self.ws.grads[i]) } else { None };
            let slots = self.slot_refs.fill(&mut self.ws.slot_store);
            node.layer.compute_gradient(src_feats, own, grad_out, slots);
        }
        // Return the slots to the pool, merging duplicate-source
        // scratch into the canonical slot and parking the scratch blob
        // for reuse next step.
        let mut ndup = 0usize;
        for (k, &s) in node.srcs.iter().enumerate() {
            if let Some(blob) = self.ws.slot_store[k].take() {
                if self.ws.is_dup[k] {
                    self.ws.grads[s].add_assign(&blob);
                    self.ws.dup_scratch[ndup] = blob;
                    ndup += 1;
                } else {
                    self.ws.grads[s] = blob;
                }
            }
        }
    }

    /// Losses reported by loss layers: `(layer name, loss, metric)`.
    pub fn losses(&self) -> Vec<(String, f32, f32)> {
        self.nodes
            .iter()
            .filter_map(|n| {
                n.layer.loss().map(|(l, m)| (n.layer.name().to_string(), l, m))
            })
            .collect()
    }

    /// Sum of all loss-layer losses (the training objective).
    pub fn total_loss(&self) -> f32 {
        self.losses().iter().map(|(_, l, _)| l).sum()
    }

    /// Feature blob of a named layer (after `forward`).
    pub fn feature(&self, name: &str) -> &Blob {
        self.feature_of(self.index_of(name).unwrap_or_else(|| panic!("no layer '{name}'")))
    }

    /// Feature blob of node `i` (after `forward`).
    pub fn feature_of(&self, i: usize) -> &Blob {
        self.ws.feature(i)
    }

    /// Accumulated gradient w.r.t. node `i`'s feature (after `backward`),
    /// `None` when no gradient reached it.
    pub fn grad_of(&self, i: usize) -> Option<&Blob> {
        self.ws.grad(i)
    }

    /// All parameters across layers.
    pub fn params(&self) -> Vec<&Param> {
        self.nodes.iter().flat_map(|n| n.layer.params()).collect()
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        self.nodes.iter_mut().flat_map(|n| n.layer.params_mut()).collect()
    }

    /// Total scalar parameter count.
    pub fn param_count(&self) -> usize {
        self.params().iter().map(|p| p.size()).sum()
    }

    /// Zero all parameter gradients (start of an SGD iteration).
    pub fn zero_grads(&mut self) {
        for p in self.params_mut() {
            p.grad.fill(0.0);
        }
    }

    /// Bytes moved across bridge layers in the last forward pass — the
    /// partitioner's communication ledger (§5.4.1).
    pub fn bridge_bytes(&mut self) -> usize {
        use super::layers_basic::BridgeLayer;
        self.nodes
            .iter_mut()
            .filter_map(|n| n.layer.as_any().downcast_mut::<BridgeLayer>().map(|b| b.last_bytes))
            .sum()
    }

    /// Human-readable summary (name, type, shape, params, location).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for n in &self.nodes {
            let pc: usize = n.layer.params().iter().map(|p| p.size()).sum();
            out.push_str(&format!(
                "{:<24} {:<14} {:>18} params={:<10} loc={}\n",
                n.layer.name(),
                n.layer.type_name(),
                format!("{:?}", n.out_shape),
                pc,
                n.location
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerKind};

    fn mlp_builder(batch: usize, in_dim: usize, hidden: usize, classes: usize) -> NetBuilder {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, in_dim] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(LayerConf::new(
                "hidden",
                LayerKind::InnerProduct { out: hidden, act: Activation::Sigmoid, init_std: 0.5 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.5 },
                &["hidden"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
    }

    #[test]
    fn build_topo_and_shapes() {
        let net = mlp_builder(4, 6, 8, 3).build(&mut Rng::new(1));
        assert_eq!(net.len(), 5);
        let idx = net.index_of("logits").unwrap();
        assert_eq!(net.nodes()[idx].out_shape, vec![4, 3]);
        assert_eq!(net.param_count(), 6 * 8 + 8 + 8 * 3 + 3);
        assert!(net.summary().contains("InnerProduct"));
    }

    #[test]
    fn build_order_independent_of_declaration() {
        // Declare layers in reverse order; topo sort must fix it.
        let b = NetBuilder::new()
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 2, act: Activation::Identity, init_std: 0.1 },
                &["data"],
            ))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![2] }, &[]))
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3] }, &[]));
        let mut net = b.build(&mut Rng::new(1));
        net.set_input("data", Blob::zeros(&[2, 3]));
        net.set_input("label", Blob::zeros(&[2]));
        net.forward(Phase::Train);
        assert_eq!(net.losses().len(), 1);
    }

    #[test]
    #[should_panic(expected = "unknown source")]
    fn unknown_source_panics() {
        NetBuilder::new()
            .add(LayerConf::new("a", LayerKind::Input { shape: vec![1] }, &["ghost"]))
            .build(&mut Rng::new(1));
    }

    #[test]
    #[should_panic(expected = "duplicate layer name")]
    fn duplicate_name_panics() {
        NetBuilder::new()
            .add(LayerConf::new("a", LayerKind::Input { shape: vec![1] }, &[]))
            .add(LayerConf::new("a", LayerKind::Input { shape: vec![1] }, &[]))
            .build(&mut Rng::new(1));
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_panics() {
        NetBuilder::new()
            .add(LayerConf::new("a", LayerKind::Split, &["b"]))
            .add(LayerConf::new("b", LayerKind::Split, &["a"]))
            .build(&mut Rng::new(1));
    }

    /// End-to-end sanity: an MLP trained with plain SGD on a separable
    /// synthetic task must drive the loss down and accuracy up.
    #[test]
    fn mlp_learns_separable_task() {
        let batch = 16;
        let mut net = mlp_builder(batch, 4, 16, 2).build(&mut Rng::new(3));
        let mut rng = Rng::new(9);
        let mut first_loss = None;
        let mut last_acc = 0.0;
        for _ in 0..200 {
            // Class 0: x ~ N(+1); class 1: x ~ N(-1) on first two dims.
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for _ in 0..batch {
                let c = rng.below(2);
                let sign = if c == 0 { 1.0 } else { -1.0 };
                xs.push(sign + 0.3 * rng.gaussian());
                xs.push(sign + 0.3 * rng.gaussian());
                xs.push(0.3 * rng.gaussian());
                xs.push(0.3 * rng.gaussian());
                ys.push(c as f32);
            }
            net.set_input("data", Blob::from_vec(&[batch, 4], xs));
            net.set_input("label", Blob::from_vec(&[batch], ys));
            net.zero_grads();
            net.forward(Phase::Train);
            net.backward();
            for p in net.params_mut() {
                p.sgd_step(0.5);
            }
            let (_, loss, acc) = net.losses()[0].clone();
            if first_loss.is_none() {
                first_loss = Some(loss);
            }
            last_acc = acc;
        }
        assert!(last_acc > 0.9, "accuracy should exceed 0.9, got {last_acc}");
        assert!(net.total_loss() < first_loss.unwrap());
    }

    #[test]
    fn split_fanout_accumulates_grads() {
        // data -> split -> two ip layers -> euclidean loss between them.
        let b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3] }, &[]))
            .add(LayerConf::new("split", LayerKind::Split, &["data"]))
            .add(LayerConf::new(
                "a",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                &["split"],
            ))
            .add(LayerConf::new(
                "b",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                &["split"],
            ))
            .add(LayerConf::new("loss", LayerKind::EuclideanLoss { weight: 1.0 }, &["a", "b"]));
        let mut net = b.build(&mut Rng::new(5));
        net.set_input("data", Blob::full(&[2, 3], 0.5));
        net.forward(Phase::Train);
        net.backward();
        // The split node must have received gradient contributions from both
        // consumers (accumulated), and its own source (data) gets one too.
        let split_idx = net.index_of("split").unwrap();
        assert!(net.grad_of(split_idx).is_some());
        let data_idx = net.index_of("data").unwrap();
        assert!(net.grad_of(data_idx).is_some());
    }

    /// The accumulated fan-out gradient must equal the SUM of both
    /// consumers' contributions — the semantics the pre-zeroed accumulate
    /// contract has to preserve.
    #[test]
    fn fanout_grad_is_sum_of_consumers() {
        let b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3] }, &[]))
            .add(LayerConf::new("split", LayerKind::Split, &["data"]))
            .add(LayerConf::new(
                "a",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                &["split"],
            ))
            .add(LayerConf::new(
                "b",
                LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                &["split"],
            ))
            .add(LayerConf::new("loss", LayerKind::EuclideanLoss { weight: 1.0 }, &["a", "b"]));
        let mut net = b.build(&mut Rng::new(5));
        net.set_input("data", Blob::full(&[2, 3], 0.5));
        net.forward(Phase::Train);
        net.backward();
        // Recompute each consumer's dx independently and check the sum.
        let split_idx = net.index_of("split").unwrap();
        let accumulated = net.grad_of(split_idx).unwrap().clone();
        let mut expect = Blob::zeros(accumulated.shape());
        for name in ["a", "b"] {
            let i = net.index_of(name).unwrap();
            let dy = net.grad_of(i).unwrap().clone();
            let w = net.nodes()[i].layer.params()[0].data.clone();
            expect.add_assign(&crate::tensor::ops::matmul_nt(&dy, &w));
        }
        for (x, y) in accumulated.data().iter().zip(expect.data()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    /// A layer listing the same source twice exercises the duplicate-source
    /// fallback: each duplicate slot accumulates into preallocated scratch
    /// and the canonical gradient receives the SUM of both contributions.
    #[test]
    fn duplicate_source_grads_sum_through_reused_scratch() {
        let build = || {
            NetBuilder::new()
                .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3] }, &[]))
                .add(LayerConf::new(
                    "a",
                    LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                    &["data"],
                ))
                // Concat of the same source twice: backward slices the
                // output gradient into two slots aimed at the SAME node.
                .add(LayerConf::new("c", LayerKind::Concat { dim: 1 }, &["a", "a"]))
                .add(LayerConf::new("tgt", LayerKind::Input { shape: vec![2, 8] }, &[]))
                .add(LayerConf::new(
                    "loss",
                    LayerKind::EuclideanLoss { weight: 1.0 },
                    &["c", "tgt"],
                ))
                .build(&mut Rng::new(11))
        };
        let mut net = build();
        net.set_input("data", Blob::full(&[2, 3], 0.5));
        net.set_input("tgt", Blob::full(&[2, 8], 0.25));
        net.forward(Phase::Train);
        net.backward();
        let a_idx = net.index_of("a").unwrap();
        let c_idx = net.index_of("c").unwrap();
        let da = net.grad_of(a_idx).unwrap().clone();
        let dc = net.grad_of(c_idx).unwrap().clone();
        // dc is [2, 8]; node a's gradient must be the sum of both halves.
        assert_eq!(da.shape(), &[2, 4]);
        for r in 0..2 {
            for j in 0..4 {
                let expect = dc.data()[r * 8 + j] + dc.data()[r * 8 + 4 + j];
                let got = da.data()[r * 4 + j];
                assert!((got - expect).abs() < 1e-6, "[{r},{j}]: {got} vs {expect}");
            }
        }
        // The dup scratch and ref lists settle: repeated steps perform zero
        // executor-scratch (and zero blob) allocations after warm-up.
        let run = |net: &mut NeuralNet| {
            net.zero_grads();
            net.forward(Phase::Train);
            net.backward();
        };
        run(&mut net);
        let exec_before = exec_scratch_alloc_count();
        let blobs_before = Blob::alloc_count();
        for _ in 0..5 {
            run(&mut net);
        }
        assert_eq!(
            exec_scratch_alloc_count(),
            exec_before,
            "steady state must not grow executor scratch"
        );
        assert_eq!(
            Blob::alloc_count(),
            blobs_before,
            "steady state must not allocate blobs (dup scratch must be reused)"
        );
    }

    /// The backward hook fires once per node, in reverse topological
    /// order, including parameter-less and skipped nodes — the completion
    /// contract the overlapped exchange's bucket counting relies on.
    #[test]
    fn backward_observer_fires_reverse_topo_for_every_node() {
        struct RecObs(Vec<usize>);
        impl GradObserver for RecObs {
            fn grads_ready(&mut self, _net: &NeuralNet, node: usize) {
                self.0.push(node);
            }
        }
        let mut net = mlp_builder(4, 6, 8, 3).build(&mut Rng::new(1));
        net.set_input("data", Blob::zeros(&[4, 6]));
        net.set_input("label", Blob::zeros(&[4]));
        net.forward(Phase::Train);
        let mut obs = RecObs(Vec::new());
        net.backward_observed(&mut obs);
        let want: Vec<usize> = (0..net.len()).rev().collect();
        assert_eq!(obs.0, want);
    }

    /// At fire time a node's parameter gradients are already final: the
    /// bits captured in the callback equal the post-backward bits.
    #[test]
    fn observer_sees_final_param_grads_at_fire_time() {
        struct CaptureObs {
            target: usize,
            bits: Vec<u32>,
        }
        impl GradObserver for CaptureObs {
            fn grads_ready(&mut self, net: &NeuralNet, node: usize) {
                if node == self.target {
                    self.bits = net.nodes()[node].layer.params()[0]
                        .grad
                        .data()
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                }
            }
        }
        let mut net = mlp_builder(4, 6, 8, 3).build(&mut Rng::new(2));
        net.set_input("data", Blob::full(&[4, 6], 0.3));
        net.set_input("label", Blob::zeros(&[4]));
        net.zero_grads();
        net.forward(Phase::Train);
        let target = net.index_of("hidden").unwrap();
        let mut obs = CaptureObs { target, bits: Vec::new() };
        net.backward_observed(&mut obs);
        let after: Vec<u32> = net.nodes()[target].layer.params()[0]
            .grad
            .data()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert!(!obs.bits.is_empty());
        assert_eq!(obs.bits, after, "hidden layer grads must be final when its hook fires");
    }

    #[test]
    fn test_phase_skips_dropout_noise() {
        let b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![1, 10] }, &[]))
            .add(LayerConf::new("drop", LayerKind::Dropout { keep: 0.5 }, &["data"]));
        let mut net = b.build(&mut Rng::new(1));
        net.set_input("data", Blob::full(&[1, 10], 1.0));
        net.forward(Phase::Test);
        assert_eq!(net.feature("drop").data(), &[1.0; 10]);
    }
}
