//! The `Layer` abstraction (paper Fig 6) and the declarative layer
//! configuration from which nets are built.
//!
//! # Execution contract: planned, buffer-reusing, allocation-free
//!
//! A layer owns its `Param`s and implements two functions invoked by the
//! `TrainOneBatch` algorithms through the [`super::net::NeuralNet`]
//! executor. Both follow a *write-into-workspace* contract rather than
//! allocate-per-call: the net builds a [`super::net::Workspace`] once at
//! `NetBuilder::build` time (one feature blob and one gradient blob per
//! node, sized from the inferred shapes) and hands layers the destination
//! buffers every step, so the steady-state training loop performs **zero**
//! feature/gradient-blob allocations (proven by the allocation probe in
//! [`crate::bench`]).
//!
//! * `compute_feature(phase, srcs, out)` — forward propagation. The layer
//!   must **overwrite** `out` completely. `out` arrives pre-sized with the
//!   shape `setup` returned; if the runtime batch differs from the declared
//!   one (e.g. evaluating a larger held-out batch), the layer resizes `out`
//!   via [`Blob::resize`], which is a no-op at steady state.
//! * `compute_gradient(srcs, own, grad_out, src_grads)` — backward
//!   propagation. The layer accumulates parameter gradients into
//!   `Param::grad` (`+=`) and **accumulates** (`+=`) the gradient w.r.t.
//!   each source into the corresponding `src_grads` slot. Slots are
//!   pre-zeroed by the executor before the first contribution of the step,
//!   so fan-out gradients from several consumers sum without temporaries.
//!   A slot is `None` when that source needs no gradient (see
//!   [`Layer::needs_src_grad`]).
//!
//! Per-layer scratch (im2col buffers, GRU unroll state, dropout masks,
//! activation-chain temporaries) is owned by the layer, allocated at
//! `setup`/first use, and reused across steps. Where producer and consumer
//! shapes match, activations run in place on the already-written
//! pre-activation buffer (`ops::*_inplace`).

use crate::tensor::{blob::Param, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Training vs evaluation phase (`flag` argument in the paper's Fig 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Train,
    Test,
}

/// Behaviour shared by every layer. Object-safe so user-defined layers can
/// be registered alongside the built-ins.
pub trait Layer: Send {
    /// Unique name within the net (e.g. `"conv1"` or, after partitioning,
    /// `"conv1@0of2"`).
    fn name(&self) -> &str;

    /// Static type tag (e.g. `"InnerProduct"`).
    fn type_name(&self) -> &'static str;

    /// Shape inference + parameter allocation. Called once while the
    /// `NeuralNet` is constructed, in topological order; receives the output
    /// shapes of the source layers and returns this layer's output shape.
    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize>;

    /// Forward propagation: write this layer's feature into the workspace
    /// slot `out` (see the module docs for the full contract). `out` must be
    /// completely overwritten; resize it when the runtime batch differs.
    fn compute_feature(&mut self, phase: Phase, srcs: &[&Blob], out: &mut Blob);

    /// Backward propagation: given source features, this layer's own
    /// feature, and the gradient w.r.t. that feature, accumulate parameter
    /// gradients (into `Param::grad`) and ACCUMULATE (`+=`) the gradient
    /// w.r.t. each source into the matching pre-zeroed `src_grads` slot.
    /// `src_grads[k]` is `None` when `needs_src_grad(k)` is false.
    ///
    /// Loss layers are invoked with `grad_out == None` and derive the
    /// gradient from their stored loss state.
    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own_feature: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    );

    /// Whether backward propagation produces a gradient for source `k`
    /// (default: every source). Layers whose sources are non-differentiable
    /// inputs (label paths, char ids) override this so the executor neither
    /// zeroes nor marks those slots — preserving the "no gradient reached
    /// this node" skip exactly as in the allocate-per-call contract.
    fn needs_src_grad(&self, _k: usize) -> bool {
        true
    }

    /// Learnable parameters (empty for most layers).
    fn params(&self) -> Vec<&Param> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        Vec::new()
    }

    /// Loss layers report `(loss, metric)` accumulated by the most recent
    /// forward pass; `metric` is task-specific (accuracy for softmax).
    fn loss(&self) -> Option<(f32, f32)> {
        None
    }

    /// Whether this layer is a connection layer inserted by the partitioner
    /// (bridge / slice / concat / split) — excluded from user-visible stats.
    fn is_connection(&self) -> bool {
        false
    }

    /// Loss layers derive their own gradient (invoked with
    /// `grad_out == None` during backward); every other layer is skipped
    /// when no gradient reaches it (e.g. the label path).
    fn is_loss(&self) -> bool {
        false
    }

    /// Downcast support (used by the CD algorithm to reach RBM internals).
    fn as_any(&mut self) -> &mut dyn Any;
}

/// Declarative configuration of a single layer — what the user writes in the
/// job configuration (paper §3). `NetBuilder` assembles these into a
/// `NeuralNet`; the partitioner rewrites them into sub-layer configs.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerConf {
    pub name: String,
    pub kind: LayerKind,
    /// Names of source layers (paper: "each layer records its own source
    /// layers").
    pub srcs: Vec<String>,
    /// Partitioning dimension for this layer: `None` (replicate whole layer /
    /// no split), `Some(0)` batch dimension → data parallelism, `Some(1)`
    /// feature dimension → model parallelism (paper §5.3).
    pub partition_dim: Option<usize>,
    /// Explicit placement: worker slot this layer (or all its sub-layers if
    /// partitioned) runs on. Advanced users set this to control placement
    /// (paper §5.3: MDNN image path on worker 0, text path on worker 1).
    pub location: Option<usize>,
}

impl LayerConf {
    pub fn new(name: &str, kind: LayerKind, srcs: &[&str]) -> LayerConf {
        LayerConf {
            name: name.to_string(),
            kind,
            srcs: srcs.iter().map(|s| s.to_string()).collect(),
            partition_dim: None,
            location: None,
        }
    }

    pub fn partition(mut self, dim: usize) -> LayerConf {
        self.partition_dim = Some(dim);
        self
    }

    pub fn at(mut self, location: usize) -> LayerConf {
        self.location = Some(location);
        self
    }
}

/// Built-in layer types (paper Table II). Each variant carries its static
/// hyper-parameters; runtime state lives in the constructed layer object.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Input fed externally each iteration with a mini-batch blob.
    Input { shape: Vec<usize> },
    /// Fully-connected: `y = act(x W + b)`.
    InnerProduct { out: usize, act: Activation, init_std: f32 },
    /// Standalone activation.
    Activation { act: Activation },
    /// Dropout with keep probability.
    Dropout { keep: f32 },
    /// 2-d convolution over NCHW blobs.
    Convolution { out_channels: usize, kernel: usize, stride: usize, pad: usize, init_std: f32 },
    /// Max pooling.
    MaxPool { kernel: usize, stride: usize },
    /// Average pooling.
    AvgPool { kernel: usize, stride: usize },
    /// Local response normalization across channels.
    Lrn { size: usize, alpha: f32, beta: f32, k: f32 },
    /// Softmax + cross entropy against integer labels (srcs: logits, labels).
    SoftmaxLoss,
    /// `weight` * 0.5 * mean squared distance between two source features
    /// (MDNN's cross-modal objective is a *weighted* sum with the label
    /// losses, paper §4.2.1).
    EuclideanLoss { weight: f32 },
    /// Restricted Boltzmann machine (visible src); trained by CD.
    Rbm { hidden: usize, init_std: f32 },
    /// Full-sequence GRU over `[batch, steps*in_dim]` input; BPTT inside.
    Gru { hidden: usize, steps: usize, init_std: f32 },
    /// Char ids `[batch, steps]` → one-hot `[batch, steps*vocab]`.
    OneHot { vocab: usize },
    /// Sequence softmax loss: logits `[batch, steps*vocab]` vs labels
    /// `[batch, steps]`.
    SeqSoftmaxLoss { steps: usize },
    // ---- Connection layers (Table II), normally inserted by the partitioner ----
    /// Slice the single source along `dim` into `parts`; this layer emits
    /// part `index`.
    Slice { dim: usize, parts: usize, index: usize },
    /// Concatenate all sources along `dim`.
    Concat { dim: usize },
    /// Replicate the source feature to multiple consumers (gradients sum).
    Split,
    /// Sending half of a cross-worker bridge.
    BridgeSrc,
    /// Receiving half of a cross-worker bridge.
    BridgeDst,
}

/// Nonlinearity selector for layers with fused activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Sigmoid,
    Tanh,
    Relu,
}

/// Instantiate a layer object from its configuration (factory used by
/// `NetBuilder`). User-defined layers can bypass this by adding
/// `Box<dyn Layer>` values directly.
pub fn create_layer(conf: &LayerConf) -> Box<dyn Layer> {
    use super::{layers_basic as lb, layers_conv as lc, layers_loss as ll};
    match &conf.kind {
        LayerKind::Input { shape } => Box::new(lb::InputLayer::new(&conf.name, shape.clone())),
        LayerKind::InnerProduct { out, act, init_std } => {
            Box::new(lb::InnerProductLayer::new(&conf.name, *out, *act, *init_std))
        }
        LayerKind::Activation { act } => Box::new(lb::ActivationLayer::new(&conf.name, *act)),
        LayerKind::Dropout { keep } => Box::new(lb::DropoutLayer::new(&conf.name, *keep)),
        LayerKind::Convolution { out_channels, kernel, stride, pad, init_std } => Box::new(
            lc::ConvolutionLayer::new(&conf.name, *out_channels, *kernel, *stride, *pad, *init_std),
        ),
        LayerKind::MaxPool { kernel, stride } => {
            Box::new(lc::PoolingLayer::new_max(&conf.name, *kernel, *stride))
        }
        LayerKind::AvgPool { kernel, stride } => {
            Box::new(lc::PoolingLayer::new_avg(&conf.name, *kernel, *stride))
        }
        LayerKind::Lrn { size, alpha, beta, k } => {
            Box::new(lc::LrnLayer::new(&conf.name, *size, *alpha, *beta, *k))
        }
        LayerKind::SoftmaxLoss => Box::new(ll::SoftmaxLossLayer::new(&conf.name)),
        LayerKind::EuclideanLoss { weight } => {
            Box::new(ll::EuclideanLossLayer::new(&conf.name, *weight))
        }
        LayerKind::Rbm { hidden, init_std } => {
            Box::new(super::rbm::RbmLayer::new(&conf.name, *hidden, *init_std))
        }
        LayerKind::Gru { hidden, steps, init_std } => {
            Box::new(super::gru::GruLayer::new(&conf.name, *hidden, *steps, *init_std))
        }
        LayerKind::OneHot { vocab } => Box::new(super::gru::OneHotLayer::new(&conf.name, *vocab)),
        LayerKind::SeqSoftmaxLoss { steps } => {
            Box::new(ll::SeqSoftmaxLossLayer::new(&conf.name, *steps))
        }
        LayerKind::Slice { dim, parts, index } => {
            Box::new(lb::SliceLayer::new(&conf.name, *dim, *parts, *index))
        }
        LayerKind::Concat { dim } => Box::new(lb::ConcatLayer::new(&conf.name, *dim)),
        LayerKind::Split => Box::new(lb::SplitLayer::new(&conf.name)),
        LayerKind::BridgeSrc => Box::new(lb::BridgeLayer::new_src(&conf.name)),
        LayerKind::BridgeDst => Box::new(lb::BridgeLayer::new_dst(&conf.name)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conf_builders() {
        let c = LayerConf::new("fc1", LayerKind::InnerProduct {
            out: 10,
            act: Activation::Relu,
            init_std: 0.01,
        }, &["data"])
        .partition(1)
        .at(2);
        assert_eq!(c.partition_dim, Some(1));
        assert_eq!(c.location, Some(2));
        assert_eq!(c.srcs, vec!["data"]);
    }

    #[test]
    fn factory_produces_right_types() {
        let cases: Vec<(LayerKind, &str)> = vec![
            (LayerKind::Input { shape: vec![4, 2] }, "Input"),
            (
                LayerKind::InnerProduct { out: 3, act: Activation::Identity, init_std: 0.1 },
                "InnerProduct",
            ),
            (LayerKind::Dropout { keep: 0.5 }, "Dropout"),
            (LayerKind::SoftmaxLoss, "SoftmaxLoss"),
            (LayerKind::Concat { dim: 0 }, "Concat"),
            (LayerKind::Split, "Split"),
            (LayerKind::BridgeSrc, "BridgeSrc"),
        ];
        for (kind, expect) in cases {
            let l = create_layer(&LayerConf::new("x", kind, &[]));
            assert_eq!(l.type_name(), expect);
        }
    }
}
