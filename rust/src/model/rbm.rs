//! Restricted Boltzmann Machine layer — category B (undirected) model
//! trained by Contrastive Divergence (paper §2.1, §4.2.2).
//!
//! The layer owns `W [visible, hidden]`, visible bias `bv` and hidden bias
//! `bh`. The CD-k `TrainOneBatch` algorithm (see [`crate::train::cd`]) calls
//! the sampling helpers directly (it downcasts through `Layer::as_any`),
//! while the generic `compute_feature` path exposes the deterministic
//! hidden activation so RBMs can also sit inside feed-forward nets after
//! pre-training (the deep auto-encoder use case, Fig 8).

use super::layer::{Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

pub struct RbmLayer {
    name: String,
    hidden: usize,
    init_std: f32,
    pub weight: Param,
    pub vbias: Param,
    pub hbias: Param,
    rng: Rng,
    /// (reconstruction error, 0) from the last CD step.
    last_loss: f32,
    /// Reused backward scratch (feed-forward fine-tuning path).
    dpre_scratch: Blob,
}

impl RbmLayer {
    pub fn new(name: &str, hidden: usize, init_std: f32) -> RbmLayer {
        RbmLayer {
            name: name.to_string(),
            hidden,
            init_std,
            weight: Param::new(&format!("{name}/weight"), Blob::zeros(&[0])),
            vbias: Param::new(&format!("{name}/vbias"), Blob::zeros(&[0])),
            hbias: Param::new(&format!("{name}/hbias"), Blob::zeros(&[0])),
            rng: Rng::new(0xb0b + name.len() as u64),
            last_loss: 0.0,
            dpre_scratch: Blob::default(),
        }
    }

    pub fn hidden_dim(&self) -> usize {
        self.hidden
    }

    /// `p(h=1 | v) = sigmoid(v W + bh)`.
    pub fn prop_up(&self, v: &Blob) -> Blob {
        let mut h = ops::matmul(&v.reshape(&[v.rows(), v.cols()]), &self.weight.data);
        ops::add_row_vec(&mut h, &self.hbias.data);
        ops::sigmoid(&h)
    }

    /// `p(v=1 | h) = sigmoid(h W^T + bv)`.
    pub fn prop_down(&self, h: &Blob) -> Blob {
        let mut v = ops::matmul_nt(h, &self.weight.data);
        ops::add_row_vec(&mut v, &self.vbias.data);
        ops::sigmoid(&v)
    }

    /// Bernoulli-sample a probability blob.
    pub fn sample(&mut self, p: &Blob) -> Blob {
        Blob::from_vec(
            p.shape(),
            p.data().iter().map(|&q| if self.rng.uniform() < q { 1.0 } else { 0.0 }).collect(),
        )
    }

    /// One CD-k step on a visible batch: accumulates gradients into the
    /// params (positive phase minus negative phase, scaled by 1/batch) and
    /// returns the reconstruction error. This is the body the paper's CD
    /// `TrainOneBatch` performs per iteration.
    pub fn cd_step(&mut self, v0: &Blob, k: usize) -> f32 {
        let batch = v0.rows() as f32;
        let h0 = self.prop_up(v0);
        // Gibbs chain.
        let mut hk = self.sample(&h0);
        let mut vk = self.prop_down(&hk);
        for _ in 1..k {
            hk = self.sample(&self.prop_up(&vk).clone());
            vk = self.prop_down(&hk);
        }
        let hk_prob = self.prop_up(&vk);

        // dW = -(v0^T h0 - vk^T hk) / batch  (negative log-likelihood grad)
        let v0m = v0.reshape(&[v0.rows(), v0.cols()]);
        let mut dw = ops::matmul_tn(&v0m, &h0);
        dw.axpy(-1.0, &ops::matmul_tn(&vk, &hk_prob));
        dw.scale(-1.0 / batch);
        self.weight.grad.add_assign(&dw);

        let mut dbv = ops::sum_rows(&v0m);
        dbv.axpy(-1.0, &ops::sum_rows(&vk));
        dbv.scale(-1.0 / batch);
        self.vbias.grad.add_assign(&dbv);

        let mut dbh = ops::sum_rows(&h0);
        dbh.axpy(-1.0, &ops::sum_rows(&hk_prob));
        dbh.scale(-1.0 / batch);
        self.hbias.grad.add_assign(&dbh);

        // Reconstruction error (mean squared).
        let mut diff = v0m.clone();
        diff.axpy(-1.0, &vk);
        let err = diff.data().iter().map(|x| x * x).sum::<f32>() / batch;
        self.last_loss = err;
        err
    }

    /// Free energy of visible configurations (diagnostic; lower is better
    /// for data the model has learned).
    pub fn free_energy(&self, v: &Blob) -> f32 {
        let vm = v.reshape(&[v.rows(), v.cols()]);
        let mut wx = ops::matmul(&vm, &self.weight.data);
        ops::add_row_vec(&mut wx, &self.hbias.data);
        let hidden_term: f32 = wx.data().iter().map(|&x| (1.0 + x.exp()).ln()).sum();
        let vbias_term: f32 = {
            let mut acc = 0.0;
            for r in 0..vm.rows() {
                for c in 0..vm.cols() {
                    acc += vm.data()[r * vm.cols() + c] * self.vbias.data.data()[c];
                }
            }
            acc
        };
        -(hidden_term + vbias_term) / v.rows() as f32
    }
}

impl Layer for RbmLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Rbm"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        let visible: usize = src_shapes[0][1..].iter().product();
        let batch = src_shapes[0][0];
        self.weight = Param::new(
            &format!("{}/weight", self.name),
            Blob::gaussian(&[visible, self.hidden], self.init_std, rng),
        );
        self.vbias = Param::new(&format!("{}/vbias", self.name), Blob::zeros(&[visible]))
            .with_wd_mult(0.0);
        self.hbias = Param::new(&format!("{}/hbias", self.name), Blob::zeros(&[self.hidden]))
            .with_wd_mult(0.0);
        vec![batch, self.hidden]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        // prop_up written into the workspace slot, activation in place.
        let v = srcs[0];
        out.resize(&[v.rows(), self.hidden]);
        ops::matmul_into(v, &self.weight.data, out, 0.0);
        ops::add_row_vec(out, &self.hbias.data);
        ops::sigmoid_inplace(out);
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        // Feed-forward fine-tuning path (auto-encoder after unfolding):
        // behave like a sigmoid inner-product layer.
        let dy = grad_out.expect("Rbm backward needs grad in feed-forward mode");
        ops::zip_into(own, dy, &mut self.dpre_scratch, ops::dsigmoid);
        let x = srcs[0];
        ops::matmul_tn_into(x, &self.dpre_scratch, &mut self.weight.grad, 1.0);
        ops::sum_rows_into(&self.dpre_scratch, &mut self.hbias.grad, true);
        if let Some(dx) = &mut src_grads[0] {
            ops::matmul_nt_into(&self.dpre_scratch, &self.weight.data, dx, 1.0);
        }
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.weight, &self.vbias, &self.hbias]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.weight, &mut self.vbias, &mut self.hbias]
    }

    fn loss(&self) -> Option<(f32, f32)> {
        Some((self.last_loss, 0.0))
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};

    fn setup_rbm(visible: usize, hidden: usize) -> RbmLayer {
        let mut l = RbmLayer::new("rbm", hidden, 0.1);
        l.setup(&[&[4, visible]], &mut Rng::new(2));
        l
    }

    #[test]
    fn shapes() {
        let l = setup_rbm(6, 3);
        assert_eq!(l.weight.data.shape(), &[6, 3]);
        assert_eq!(l.vbias.data.shape(), &[6]);
        assert_eq!(l.hbias.data.shape(), &[3]);
        assert_eq!(l.params().len(), 3);
    }

    #[test]
    fn prop_up_down_shapes_and_range() {
        let l = setup_rbm(6, 3);
        let mut r = Rng::new(4);
        let v = Blob::from_vec(&[4, 6], r.uniform_vec(24, 0.0, 1.0));
        let h = l.prop_up(&v);
        assert_eq!(h.shape(), &[4, 3]);
        assert!(h.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
        let vr = l.prop_down(&h);
        assert_eq!(vr.shape(), &[4, 6]);
        assert!(vr.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn sample_is_binary() {
        let mut l = setup_rbm(4, 4);
        let p = Blob::full(&[2, 4], 0.5);
        let s = l.sample(&p);
        assert!(s.data().iter().all(|&v| v == 0.0 || v == 1.0));
        // extremes
        let ones = l.sample(&Blob::full(&[1, 4], 1.0));
        assert!(ones.data().iter().all(|&v| v == 1.0));
        let zeros = l.sample(&Blob::full(&[1, 4], 0.0));
        assert!(zeros.data().iter().all(|&v| v == 0.0));
    }

    /// CD-1 on a tiny dataset must decrease reconstruction error — the core
    /// convergence signal of §4.2.2.
    #[test]
    fn cd_learning_reduces_reconstruction_error() {
        let mut l = setup_rbm(8, 16);
        let mut rng = Rng::new(9);
        // Two binary prototype patterns + noise.
        let proto = [
            [1., 1., 1., 1., 0., 0., 0., 0.],
            [0., 0., 0., 0., 1., 1., 1., 1.],
        ];
        let make_batch = |rng: &mut Rng| -> Blob {
            let mut data = Vec::new();
            for _ in 0..16 {
                let p = &proto[rng.below(2)];
                for &v in p.iter() {
                    let flip = rng.uniform() < 0.05;
                    data.push(if flip { 1.0 - v } else { v });
                }
            }
            Blob::from_vec(&[16, 8], data)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..300 {
            let batch = make_batch(&mut rng);
            let err = l.cd_step(&batch, 1);
            // SGD update
            for p in l.params_mut() {
                p.sgd_step(0.1);
                p.grad.fill(0.0);
            }
            if it == 0 {
                first = err;
            }
            last = err;
        }
        assert!(
            last < first * 0.5,
            "reconstruction error should halve: first {first}, last {last}"
        );
    }

    #[test]
    fn free_energy_lower_for_trained_patterns() {
        let mut l = setup_rbm(8, 16);
        let mut rng = Rng::new(9);
        let pattern = Blob::from_vec(&[1, 8], vec![1., 1., 1., 1., 0., 0., 0., 0.]);
        let anti = Blob::from_vec(&[1, 8], vec![0., 1., 0., 1., 0., 1., 0., 1.]);
        for _ in 0..300 {
            let mut data = Vec::new();
            for _ in 0..8 {
                data.extend_from_slice(pattern.data());
            }
            let batch = Blob::from_vec(&[8, 8], data);
            l.cd_step(&batch, 1);
            for p in l.params_mut() {
                p.sgd_step(0.1);
                p.grad.fill(0.0);
            }
            let _ = rng.next_u32();
        }
        assert!(
            l.free_energy(&pattern) < l.free_energy(&anti),
            "trained pattern should have lower free energy"
        );
    }

    #[test]
    fn feed_forward_backward_gradcheck() {
        let mut l = setup_rbm(5, 3);
        let mut r = Rng::new(6);
        let x = Blob::from_vec(&[2, 5], r.uniform_vec(10, 0.0, 1.0));
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let gs = backward(&mut l, &[&x], &y, Some(&dy));
        let dx = gs[0].as_ref().unwrap();
        let eps = 1e-2;
        for i in 0..x.len() {
            let mut p = x.clone();
            p.data_mut()[i] += eps;
            let mut m = x.clone();
            m.data_mut()[i] -= eps;
            let fp = l.prop_up(&p).sum();
            let fm = l.prop_up(&m).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - dx.data()[i]).abs() < 1e-2, "dx[{i}] {num} vs {}", dx.data()[i]);
        }
    }
}
