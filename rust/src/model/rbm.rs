//! Restricted Boltzmann Machine layer — category B (undirected) model
//! trained by Contrastive Divergence (paper §2.1, §4.2.2).
//!
//! The layer owns `W [visible, hidden]`, visible bias `bv` and hidden bias
//! `bh`. The CD-k `TrainOneBatch` algorithm (see [`crate::train::cd`]) calls
//! the sampling helpers directly (it downcasts through `Layer::as_any`),
//! while the generic `compute_feature` path exposes the deterministic
//! hidden activation so RBMs can also sit inside feed-forward nets after
//! pre-training (the deep auto-encoder use case, Fig 8).

use super::layer::{Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Reusable Gibbs-chain and gradient scratch owned by the layer, so a CD
/// step allocates nothing at steady state — the CD counterpart of the BP
/// path's `_into` workspace story below the Blob layer.
#[derive(Default)]
struct CdScratch {
    h0: Blob,
    hk: Blob,
    vk: Blob,
    hk_prob: Blob,
    dw: Blob,
    dneg: Blob,
    dbv: Blob,
    dbh: Blob,
    dtmp: Blob,
}

/// `out = sigmoid(v W + bh)` without allocating — the body shared by
/// [`RbmLayer::prop_up`], `compute_feature`, and the CD path. Free function
/// (not a method) so `cd_step` can borrow the params shared and the scratch
/// mutably at the same time.
fn prop_up_into(weight: &Blob, hbias: &Blob, v: &Blob, out: &mut Blob) {
    out.resize(&[v.rows(), hbias.len()]);
    ops::matmul_into(v, weight, out, 0.0);
    ops::add_row_vec(out, hbias);
    ops::sigmoid_inplace(out);
}

/// `out = sigmoid(h W^T + bv)` without allocating.
fn prop_down_into(weight: &Blob, vbias: &Blob, h: &Blob, out: &mut Blob) {
    out.resize(&[h.rows(), vbias.len()]);
    ops::matmul_nt_into(h, weight, out, 0.0);
    ops::add_row_vec(out, vbias);
    ops::sigmoid_inplace(out);
}

/// Bernoulli-sample probabilities into `out` (resized to `p`'s shape),
/// consuming one uniform per element in storage order.
fn sample_into(rng: &mut Rng, p: &Blob, out: &mut Blob) {
    out.resize(p.shape());
    for (o, &q) in out.data_mut().iter_mut().zip(p.data()) {
        *o = if rng.uniform() < q { 1.0 } else { 0.0 };
    }
}

pub struct RbmLayer {
    name: String,
    hidden: usize,
    init_std: f32,
    pub weight: Param,
    pub vbias: Param,
    pub hbias: Param,
    rng: Rng,
    /// (reconstruction error, 0) from the last CD step.
    last_loss: f32,
    /// Reused backward scratch (feed-forward fine-tuning path).
    dpre_scratch: Blob,
    /// Reused CD-step scratch (Gibbs chain + gradient staging).
    cd: CdScratch,
}

impl RbmLayer {
    pub fn new(name: &str, hidden: usize, init_std: f32) -> RbmLayer {
        RbmLayer {
            name: name.to_string(),
            hidden,
            init_std,
            weight: Param::new(&format!("{name}/weight"), Blob::zeros(&[0])),
            vbias: Param::new(&format!("{name}/vbias"), Blob::zeros(&[0])),
            hbias: Param::new(&format!("{name}/hbias"), Blob::zeros(&[0])),
            rng: Rng::new(0xb0b + name.len() as u64),
            last_loss: 0.0,
            dpre_scratch: Blob::default(),
            cd: CdScratch::default(),
        }
    }

    pub fn hidden_dim(&self) -> usize {
        self.hidden
    }

    /// `p(h=1 | v) = sigmoid(v W + bh)` (allocating wrapper over the
    /// `_into` body; bit-identical to the CD path's internal calls).
    pub fn prop_up(&self, v: &Blob) -> Blob {
        let mut h = Blob::default();
        prop_up_into(&self.weight.data, &self.hbias.data, v, &mut h);
        h
    }

    /// `p(v=1 | h) = sigmoid(h W^T + bv)`.
    pub fn prop_down(&self, h: &Blob) -> Blob {
        let mut v = Blob::default();
        prop_down_into(&self.weight.data, &self.vbias.data, h, &mut v);
        v
    }

    /// Bernoulli-sample a probability blob.
    pub fn sample(&mut self, p: &Blob) -> Blob {
        let mut s = Blob::default();
        sample_into(&mut self.rng, p, &mut s);
        s
    }

    /// One CD-k step on a visible batch: accumulates gradients into the
    /// params (positive phase minus negative phase, scaled by 1/batch) and
    /// returns the reconstruction error. This is the body the paper's CD
    /// `TrainOneBatch` performs per iteration. Runs entirely in layer-owned
    /// scratch: zero blob allocations per Gibbs step after the first call
    /// sizes the buffers.
    pub fn cd_step(&mut self, v0: &Blob, k: usize) -> f32 {
        let batch = v0.rows() as f32;
        let visible = v0.cols();
        let s = &mut self.cd;
        let w = &self.weight.data;

        // Positive phase + Gibbs chain, all in reusable scratch.
        prop_up_into(w, &self.hbias.data, v0, &mut s.h0);
        sample_into(&mut self.rng, &s.h0, &mut s.hk);
        prop_down_into(w, &self.vbias.data, &s.hk, &mut s.vk);
        for _ in 1..k {
            prop_up_into(w, &self.hbias.data, &s.vk, &mut s.hk_prob);
            sample_into(&mut self.rng, &s.hk_prob, &mut s.hk);
            prop_down_into(w, &self.vbias.data, &s.hk, &mut s.vk);
        }
        prop_up_into(w, &self.hbias.data, &s.vk, &mut s.hk_prob);

        // dW = -(v0^T h0 - vk^T hk) / batch  (negative log-likelihood grad)
        s.dw.resize(&[visible, self.hidden]);
        s.dneg.resize(&[visible, self.hidden]);
        ops::matmul_tn_into(v0, &s.h0, &mut s.dw, 0.0);
        ops::matmul_tn_into(&s.vk, &s.hk_prob, &mut s.dneg, 0.0);
        s.dw.axpy(-1.0, &s.dneg);
        s.dw.scale(-1.0 / batch);
        self.weight.grad.add_assign(&s.dw);

        s.dbv.resize(&[visible]);
        s.dtmp.resize(&[visible]);
        ops::sum_rows_into(v0, &mut s.dbv, false);
        ops::sum_rows_into(&s.vk, &mut s.dtmp, false);
        s.dbv.axpy(-1.0, &s.dtmp);
        s.dbv.scale(-1.0 / batch);
        self.vbias.grad.add_assign(&s.dbv);

        s.dbh.resize(&[self.hidden]);
        s.dtmp.resize(&[self.hidden]);
        ops::sum_rows_into(&s.h0, &mut s.dbh, false);
        ops::sum_rows_into(&s.hk_prob, &mut s.dtmp, false);
        s.dbh.axpy(-1.0, &s.dtmp);
        s.dbh.scale(-1.0 / batch);
        self.hbias.grad.add_assign(&s.dbh);

        // Reconstruction error (mean squared), computed pairwise.
        let err = v0
            .data()
            .iter()
            .zip(s.vk.data())
            .map(|(&x, &y)| {
                let d = x - y;
                d * d
            })
            .sum::<f32>()
            / batch;
        self.last_loss = err;
        err
    }

    /// Free energy of visible configurations (diagnostic; lower is better
    /// for data the model has learned).
    pub fn free_energy(&self, v: &Blob) -> f32 {
        let vm = v.reshape(&[v.rows(), v.cols()]);
        let mut wx = ops::matmul(&vm, &self.weight.data);
        ops::add_row_vec(&mut wx, &self.hbias.data);
        let hidden_term: f32 = wx.data().iter().map(|&x| (1.0 + x.exp()).ln()).sum();
        let vbias_term: f32 = {
            let mut acc = 0.0;
            for r in 0..vm.rows() {
                for c in 0..vm.cols() {
                    acc += vm.data()[r * vm.cols() + c] * self.vbias.data.data()[c];
                }
            }
            acc
        };
        -(hidden_term + vbias_term) / v.rows() as f32
    }
}

impl Layer for RbmLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Rbm"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        let visible: usize = src_shapes[0][1..].iter().product();
        let batch = src_shapes[0][0];
        self.weight = Param::new(
            &format!("{}/weight", self.name),
            Blob::gaussian(&[visible, self.hidden], self.init_std, rng),
        );
        self.vbias = Param::new(&format!("{}/vbias", self.name), Blob::zeros(&[visible]))
            .with_wd_mult(0.0);
        self.hbias = Param::new(&format!("{}/hbias", self.name), Blob::zeros(&[self.hidden]))
            .with_wd_mult(0.0);
        vec![batch, self.hidden]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        // prop_up written into the workspace slot, activation in place.
        prop_up_into(&self.weight.data, &self.hbias.data, srcs[0], out);
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        // Feed-forward fine-tuning path (auto-encoder after unfolding):
        // behave like a sigmoid inner-product layer.
        let dy = grad_out.expect("Rbm backward needs grad in feed-forward mode");
        ops::zip_into(own, dy, &mut self.dpre_scratch, ops::dsigmoid);
        let x = srcs[0];
        ops::matmul_tn_into(x, &self.dpre_scratch, &mut self.weight.grad, 1.0);
        ops::sum_rows_into(&self.dpre_scratch, &mut self.hbias.grad, true);
        if let Some(dx) = &mut src_grads[0] {
            ops::matmul_nt_into(&self.dpre_scratch, &self.weight.data, dx, 1.0);
        }
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.weight, &self.vbias, &self.hbias]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.weight, &mut self.vbias, &mut self.hbias]
    }

    fn loss(&self) -> Option<(f32, f32)> {
        Some((self.last_loss, 0.0))
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};

    fn setup_rbm(visible: usize, hidden: usize) -> RbmLayer {
        let mut l = RbmLayer::new("rbm", hidden, 0.1);
        l.setup(&[&[4, visible]], &mut Rng::new(2));
        l
    }

    #[test]
    fn shapes() {
        let l = setup_rbm(6, 3);
        assert_eq!(l.weight.data.shape(), &[6, 3]);
        assert_eq!(l.vbias.data.shape(), &[6]);
        assert_eq!(l.hbias.data.shape(), &[3]);
        assert_eq!(l.params().len(), 3);
    }

    #[test]
    fn prop_up_down_shapes_and_range() {
        let l = setup_rbm(6, 3);
        let mut r = Rng::new(4);
        let v = Blob::from_vec(&[4, 6], r.uniform_vec(24, 0.0, 1.0));
        let h = l.prop_up(&v);
        assert_eq!(h.shape(), &[4, 3]);
        assert!(h.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
        let vr = l.prop_down(&h);
        assert_eq!(vr.shape(), &[4, 6]);
        assert!(vr.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn sample_is_binary() {
        let mut l = setup_rbm(4, 4);
        let p = Blob::full(&[2, 4], 0.5);
        let s = l.sample(&p);
        assert!(s.data().iter().all(|&v| v == 0.0 || v == 1.0));
        // extremes
        let ones = l.sample(&Blob::full(&[1, 4], 1.0));
        assert!(ones.data().iter().all(|&v| v == 1.0));
        let zeros = l.sample(&Blob::full(&[1, 4], 0.0));
        assert!(zeros.data().iter().all(|&v| v == 0.0));
    }

    /// CD-1 on a tiny dataset must decrease reconstruction error — the core
    /// convergence signal of §4.2.2.
    #[test]
    fn cd_learning_reduces_reconstruction_error() {
        let mut l = setup_rbm(8, 16);
        let mut rng = Rng::new(9);
        // Two binary prototype patterns + noise.
        let proto = [
            [1., 1., 1., 1., 0., 0., 0., 0.],
            [0., 0., 0., 0., 1., 1., 1., 1.],
        ];
        let make_batch = |rng: &mut Rng| -> Blob {
            let mut data = Vec::new();
            for _ in 0..16 {
                let p = &proto[rng.below(2)];
                for &v in p.iter() {
                    let flip = rng.uniform() < 0.05;
                    data.push(if flip { 1.0 - v } else { v });
                }
            }
            Blob::from_vec(&[16, 8], data)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..300 {
            let batch = make_batch(&mut rng);
            let err = l.cd_step(&batch, 1);
            // SGD update
            for p in l.params_mut() {
                p.sgd_step(0.1);
                p.grad.fill(0.0);
            }
            if it == 0 {
                first = err;
            }
            last = err;
        }
        assert!(
            last < first * 0.5,
            "reconstruction error should halve: first {first}, last {last}"
        );
    }

    #[test]
    fn free_energy_lower_for_trained_patterns() {
        let mut l = setup_rbm(8, 16);
        let mut rng = Rng::new(9);
        let pattern = Blob::from_vec(&[1, 8], vec![1., 1., 1., 1., 0., 0., 0., 0.]);
        let anti = Blob::from_vec(&[1, 8], vec![0., 1., 0., 1., 0., 1., 0., 1.]);
        for _ in 0..300 {
            let mut data = Vec::new();
            for _ in 0..8 {
                data.extend_from_slice(pattern.data());
            }
            let batch = Blob::from_vec(&[8, 8], data);
            l.cd_step(&batch, 1);
            for p in l.params_mut() {
                p.sgd_step(0.1);
                p.grad.fill(0.0);
            }
            let _ = rng.next_u32();
        }
        assert!(
            l.free_energy(&pattern) < l.free_energy(&anti),
            "trained pattern should have lower free energy"
        );
    }

    /// The scratch-buffer CD step must match the historical allocating
    /// implementation bit-for-bit: a twin layer (same name → same RNG
    /// stream, same init) driven through the old per-step recipe with the
    /// public allocating helpers produces identical gradients and error.
    #[test]
    fn cd_step_matches_allocating_reference_bitwise() {
        let mut fused = setup_rbm(6, 5);
        let mut twin = setup_rbm(6, 5);
        let mut rng = Rng::new(31);
        for step in 0..3 {
            let v0 = Blob::from_vec(&[4, 6], rng.uniform_vec(24, 0.0, 1.0));
            let batch = v0.rows() as f32;
            let err_fused = fused.cd_step(&v0, 1);

            // Old two-phase recipe, allocating blobs at every stage.
            let h0 = twin.prop_up(&v0);
            let hk = twin.sample(&h0);
            let vk = twin.prop_down(&hk);
            let hk_prob = twin.prop_up(&vk);
            let mut dw = ops::matmul_tn(&v0, &h0);
            dw.axpy(-1.0, &ops::matmul_tn(&vk, &hk_prob));
            dw.scale(-1.0 / batch);
            twin.weight.grad.add_assign(&dw);
            let mut dbv = ops::sum_rows(&v0);
            dbv.axpy(-1.0, &ops::sum_rows(&vk));
            dbv.scale(-1.0 / batch);
            twin.vbias.grad.add_assign(&dbv);
            let mut dbh = ops::sum_rows(&h0);
            dbh.axpy(-1.0, &ops::sum_rows(&hk_prob));
            dbh.scale(-1.0 / batch);
            twin.hbias.grad.add_assign(&dbh);
            let mut diff = v0.clone();
            diff.axpy(-1.0, &vk);
            let err_ref = diff.data().iter().map(|x| x * x).sum::<f32>() / batch;

            assert_eq!(err_fused, err_ref, "step {step}: reconstruction error");
            assert_eq!(fused.weight.grad.data(), twin.weight.grad.data(), "step {step}: dW");
            assert_eq!(fused.vbias.grad.data(), twin.vbias.grad.data(), "step {step}: dbv");
            assert_eq!(fused.hbias.grad.data(), twin.hbias.grad.data(), "step {step}: dbh");
        }
    }

    /// THE zero-alloc CD acceptance probe: after warm-up sizes the layer's
    /// scratch, a CD-k Gibbs step allocates zero blobs (and zero gemm pack
    /// scratch).
    #[test]
    fn cd_step_is_allocation_free_after_warmup() {
        let mut l = setup_rbm(8, 16);
        let mut rng = Rng::new(12);
        let v = Blob::from_vec(&[16, 8], rng.uniform_vec(128, 0.0, 1.0));
        for _ in 0..2 {
            l.cd_step(&v, 2);
        }
        let blobs = Blob::alloc_count();
        let packs = crate::tensor::gemm::pack_alloc_count();
        for _ in 0..5 {
            l.cd_step(&v, 2);
        }
        assert_eq!(Blob::alloc_count(), blobs, "steady-state CD must not allocate blobs");
        assert_eq!(
            crate::tensor::gemm::pack_alloc_count(),
            packs,
            "steady-state CD must not allocate gemm pack scratch"
        );
    }

    #[test]
    fn feed_forward_backward_gradcheck() {
        let mut l = setup_rbm(5, 3);
        let mut r = Rng::new(6);
        let x = Blob::from_vec(&[2, 5], r.uniform_vec(10, 0.0, 1.0));
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let gs = backward(&mut l, &[&x], &y, Some(&dy));
        let dx = gs[0].as_ref().unwrap();
        let eps = 1e-2;
        for i in 0..x.len() {
            let mut p = x.clone();
            p.data_mut()[i] += eps;
            let mut m = x.clone();
            m.data_mut()[i] -= eps;
            let fp = l.prop_up(&p).sum();
            let fm = l.prop_up(&m).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - dx.data()[i]).abs() < 1e-2, "dx[{i}] {num} vs {}", dx.data()[i]);
        }
    }
}
