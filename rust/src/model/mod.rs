//! The SINGA programming model (paper §4): `Layer` abstraction, built-in
//! layers (Table II), the `NeuralNet` dataflow graph, and the neural-net
//! partitioner (§5.3) that realizes data / model / hybrid parallelism by
//! splitting layers into located sub-layers and auto-inserting connection
//! layers (slice / concat / split / bridge).

pub mod checkpoint;
pub mod layer;
pub mod layers_basic;
pub mod layers_conv;
pub mod layers_loss;
pub mod rbm;
pub mod gru;
pub mod net;
pub mod partition;

pub use layer::{Layer, LayerConf, LayerKind, Phase};
pub use net::{NetBuilder, NeuralNet};
pub use partition::partition_net;
