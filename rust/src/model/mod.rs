//! The SINGA programming model (paper §4): `Layer` abstraction, built-in
//! layers (Table II), the `NeuralNet` dataflow graph, and the neural-net
//! partitioner (§5.3) that realizes data / model / hybrid parallelism by
//! splitting layers into located sub-layers and auto-inserting connection
//! layers (slice / concat / split / bridge).

pub mod checkpoint;
pub mod layer;
pub mod layers_basic;
pub mod layers_conv;
pub mod layers_loss;
pub mod rbm;
pub mod gru;
pub mod net;
pub mod partition;

pub use layer::{Layer, LayerConf, LayerKind, Phase};
pub use net::{GradObserver, NetBuilder, NeuralNet, NoopObserver, Workspace};
pub use partition::partition_net;

/// Test-only stand-in for the planned executor: drives a single layer with
/// freshly zeroed destination buffers so unit tests can call
/// `compute_feature` / `compute_gradient` directly under the
/// write-into-workspace contract.
#[cfg(test)]
pub mod test_support {
    use super::layer::{Layer, Phase};
    use crate::tensor::Blob;

    /// Run forward into a fresh blob (layers size their own output).
    pub fn forward(l: &mut dyn Layer, phase: Phase, srcs: &[&Blob]) -> Blob {
        let mut out = Blob::default();
        l.compute_feature(phase, srcs, &mut out);
        out
    }

    /// Run backward against zeroed source-gradient slots, returning them
    /// (`None` where the layer declares no source gradient) — the shape the
    /// old allocate-per-call contract returned, for easy assertions.
    pub fn backward(
        l: &mut dyn Layer,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let mut slots: Vec<Option<Blob>> = (0..srcs.len())
            .map(|k| {
                if l.needs_src_grad(k) {
                    Some(Blob::zeros(srcs[k].shape()))
                } else {
                    None
                }
            })
            .collect();
        {
            let mut refs: Vec<Option<&mut Blob>> =
                slots.iter_mut().map(|o| o.as_mut()).collect();
            l.compute_gradient(srcs, own, grad_out, &mut refs);
        }
        slots
    }
}
