//! Convolution, pooling and LRN layers (the vision stack used by the CIFAR
//! convnet and the AlexNet-like benchmark model).

use super::layer::{Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::conv::{
    avgpool_forward_into, conv2d_backward_acc, conv2d_forward_into, lrn_forward_into,
    maxpool_backward_acc, maxpool_forward_into, Conv2dGeom, ConvScratch,
};
use crate::tensor::Blob;
use crate::utils::rng::Rng;
use std::any::Any;

/// 2-d convolution layer over NCHW blobs via im2col + GEMM. The im2col
/// buffers and the batched-GEMM packing scratch are owned by the layer and
/// reused across steps.
pub struct ConvolutionLayer {
    name: String,
    out_channels: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
    init_std: f32,
    geom: Option<Conv2dGeom>,
    weight: Param,
    bias: Param,
    /// im2col buffers of the last forward (reused in backward).
    cols: Vec<Vec<f32>>,
    scratch: ConvScratch,
}

impl ConvolutionLayer {
    pub fn new(
        name: &str,
        out_channels: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
        init_std: f32,
    ) -> ConvolutionLayer {
        ConvolutionLayer {
            name: name.to_string(),
            out_channels,
            kernel,
            stride,
            pad,
            init_std,
            geom: None,
            weight: Param::new(&format!("{name}/weight"), Blob::zeros(&[0])),
            bias: Param::new(&format!("{name}/bias"), Blob::zeros(&[0])),
            cols: Vec::new(),
            scratch: ConvScratch::new(),
        }
    }

    /// Parameter count (used by the partition cost model: conv layers hold
    /// ~5% of AlexNet parameters but 90-95% of compute).
    pub fn param_count(&self) -> usize {
        self.weight.data.len() + self.bias.data.len()
    }
}

impl Layer for ConvolutionLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Convolution"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 4, "{}: Convolution wants NCHW input, got {s:?}", self.name);
        let g = Conv2dGeom {
            in_c: s[1],
            in_h: s[2],
            in_w: s[3],
            kernel: self.kernel,
            stride: self.stride,
            pad: self.pad,
        };
        self.weight = Param::new(
            &format!("{}/weight", self.name),
            Blob::gaussian(&[self.out_channels, g.col_rows()], self.init_std, rng),
        );
        self.bias = Param::new(&format!("{}/bias", self.name), Blob::zeros(&[self.out_channels]))
            .with_lr_mult(2.0)
            .with_wd_mult(0.0);
        let out = vec![s[0], self.out_channels, g.out_h(), g.out_w()];
        self.geom = Some(g);
        out
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let g = self.geom.expect("setup not called");
        conv2d_forward_into(
            srcs[0],
            &self.weight.data,
            &self.bias.data,
            &g,
            out,
            &mut self.cols,
            &mut self.scratch,
        );
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let g = self.geom.expect("setup not called");
        let dy = grad_out.expect("Convolution needs grad");
        conv2d_backward_acc(
            srcs[0],
            &self.weight.data,
            dy,
            &self.cols,
            &g,
            src_grads[0].as_deref_mut(),
            &mut self.weight.grad,
            &mut self.bias.grad,
            &mut self.scratch,
        );
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.weight, &mut self.bias]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Max or average pooling.
pub struct PoolingLayer {
    name: String,
    kernel: usize,
    stride: usize,
    max: bool,
    geom: Option<Conv2dGeom>,
    argmax: Vec<usize>,
    in_shape: Vec<usize>,
}

impl PoolingLayer {
    pub fn new_max(name: &str, kernel: usize, stride: usize) -> PoolingLayer {
        PoolingLayer {
            name: name.to_string(),
            kernel,
            stride,
            max: true,
            geom: None,
            argmax: Vec::new(),
            in_shape: Vec::new(),
        }
    }

    pub fn new_avg(name: &str, kernel: usize, stride: usize) -> PoolingLayer {
        PoolingLayer { max: false, ..PoolingLayer::new_max(name, kernel, stride) }
    }
}

impl Layer for PoolingLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        if self.max {
            "MaxPool"
        } else {
            "AvgPool"
        }
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 4, "{}: Pooling wants NCHW input", self.name);
        let g = Conv2dGeom {
            in_c: s[1],
            in_h: s[2],
            in_w: s[3],
            kernel: self.kernel,
            stride: self.stride,
            pad: 0,
        };
        let out = vec![s[0], s[1], g.out_h(), g.out_w()];
        self.geom = Some(g);
        self.in_shape = s.to_vec();
        out
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let g = self.geom.expect("setup not called");
        if self.max {
            maxpool_forward_into(srcs[0], &g, out, &mut self.argmax);
        } else {
            avgpool_forward_into(srcs[0], &g, out);
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Pooling needs grad");
        let dx = src_grads[0].as_mut().expect("Pooling src slot");
        if self.max {
            maxpool_backward_acc(dy, &self.argmax, dx);
        } else {
            // Spread each output grad evenly over its window.
            let g = self.geom.expect("setup not called");
            let (oh, ow) = (g.out_h(), g.out_w());
            let k2 = (g.kernel * g.kernel) as f32;
            let img_len = g.in_c * g.in_h * g.in_w;
            let b = srcs[0].shape()[0];
            for i in 0..b {
                for c in 0..g.in_c {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let gval =
                                dy.data()[((i * g.in_c + c) * oh + oy) * ow + ox] / k2;
                            for ky in 0..g.kernel {
                                let iy = oy * g.stride + ky;
                                if iy >= g.in_h {
                                    continue;
                                }
                                for kx in 0..g.kernel {
                                    let ix = ox * g.stride + kx;
                                    if ix >= g.in_w {
                                        continue;
                                    }
                                    dx.data_mut()
                                        [i * img_len + c * g.in_h * g.in_w + iy * g.in_w + ix] +=
                                        gval;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Local response normalization. The backward pass uses the exact LRN
/// gradient restricted to the diagonal term plus the cross-channel term.
pub struct LrnLayer {
    name: String,
    size: usize,
    alpha: f32,
    beta: f32,
    k: f32,
    /// Reusable per-position channel denominators for backward.
    denom_scratch: Vec<f32>,
}

impl LrnLayer {
    pub fn new(name: &str, size: usize, alpha: f32, beta: f32, k: f32) -> LrnLayer {
        LrnLayer { name: name.to_string(), size, alpha, beta, k, denom_scratch: Vec::new() }
    }
}

impl Layer for LrnLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Lrn"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        lrn_forward_into(srcs[0], self.size, self.alpha, self.beta, self.k, out);
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Lrn needs grad");
        let x = srcs[0];
        let s = x.shape();
        let (b, c, h, w) = (s[0], s[1], s[2], s[3]);
        let plane = h * w;
        let dx = src_grads[0].as_mut().expect("Lrn src slot");
        let an = self.alpha / self.size as f32;
        if self.denom_scratch.len() != c {
            self.denom_scratch.clear();
            self.denom_scratch.resize(c, 0.0);
        }
        for i in 0..b {
            for y in 0..plane {
                // denom_c = k + an * sum a^2 over window(c)
                let denom = &mut self.denom_scratch;
                for ch in 0..c {
                    let lo = ch.saturating_sub(self.size / 2);
                    let hi = (ch + self.size / 2 + 1).min(c);
                    let mut acc = 0.0;
                    for cc in lo..hi {
                        let v = x.data()[(i * c + cc) * plane + y];
                        acc += v * v;
                    }
                    denom[ch] = self.k + an * acc;
                }
                for ch in 0..c {
                    // dL/dx_ch = dy_ch * denom_ch^-beta
                    //   - 2*an*beta * x_ch * sum_{c' : ch in window(c')}
                    //       dy_c' * y_c' / denom_c'
                    let mut v = dy.data()[(i * c + ch) * plane + y] * denom[ch].powf(-self.beta);
                    let lo = ch.saturating_sub(self.size / 2);
                    let hi = (ch + self.size / 2 + 1).min(c);
                    let mut cross = 0.0;
                    for cc in lo..hi {
                        cross += dy.data()[(i * c + cc) * plane + y]
                            * own.data()[(i * c + cc) * plane + y]
                            / denom[cc];
                    }
                    v -= 2.0 * an * self.beta * x.data()[(i * c + ch) * plane + y] * cross;
                    // Accumulate into the shared slot (+=, pre-zeroed).
                    dx.data_mut()[(i * c + ch) * plane + y] += v;
                }
            }
        }
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};

    fn rng() -> Rng {
        Rng::new(1)
    }

    #[test]
    fn conv_layer_shapes() {
        let mut l = ConvolutionLayer::new("conv1", 8, 5, 1, 2, 0.05);
        let out = l.setup(&[&[2, 3, 32, 32]], &mut rng());
        assert_eq!(out, vec![2, 8, 32, 32]);
        assert_eq!(l.params()[0].data.shape(), &[8, 75]);
        assert_eq!(l.param_count(), 8 * 75 + 8);
    }

    #[test]
    fn conv_layer_forward_backward_shapes() {
        let mut l = ConvolutionLayer::new("c", 4, 3, 1, 1, 0.1);
        l.setup(&[&[2, 3, 8, 8]], &mut rng());
        let mut r = Rng::new(7);
        let x = Blob::from_vec(&[2, 3, 8, 8], r.uniform_vec(2 * 3 * 64, -1.0, 1.0));
        let y = forward(&mut l, Phase::Train, &[&x]);
        assert_eq!(y.shape(), &[2, 4, 8, 8]);
        let dy = Blob::full(y.shape(), 0.5);
        let gs = backward(&mut l, &[&x], &y, Some(&dy));
        assert_eq!(gs[0].as_ref().unwrap().shape(), x.shape());
        // param grads accumulated
        assert!(l.params()[0].grad.norm() > 0.0);
        assert!(l.params()[1].grad.norm() > 0.0);
    }

    #[test]
    fn maxpool_layer_roundtrip() {
        let mut l = PoolingLayer::new_max("p", 2, 2);
        let out = l.setup(&[&[1, 1, 4, 4]], &mut rng());
        assert_eq!(out, vec![1, 1, 2, 2]);
        let x = Blob::from_vec(&[1, 1, 4, 4], (0..16).map(|v| v as f32).collect());
        let y = forward(&mut l, Phase::Train, &[&x]);
        assert_eq!(y.data(), &[5., 7., 13., 15.]);
        let dy = Blob::full(&[1, 1, 2, 2], 1.0);
        let dx = backward(&mut l, &[&x], &y, Some(&dy))[0].clone().unwrap();
        assert_eq!(dx.sum(), 4.0);
    }

    #[test]
    fn avgpool_backward_conserves_grad() {
        let mut l = PoolingLayer::new_avg("p", 2, 2);
        l.setup(&[&[1, 2, 4, 4]], &mut rng());
        let x = Blob::full(&[1, 2, 4, 4], 1.0);
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let dx = backward(&mut l, &[&x], &y, Some(&dy))[0].clone().unwrap();
        // total gradient mass is conserved
        assert!((dx.sum() - dy.sum()).abs() < 1e-5);
    }

    #[test]
    fn lrn_gradcheck() {
        let mut l = LrnLayer::new("n", 3, 5e-2, 0.75, 2.0);
        l.setup(&[&[1, 4, 2, 2]], &mut rng());
        let mut r = Rng::new(3);
        let x = Blob::from_vec(&[1, 4, 2, 2], r.uniform_vec(16, 0.5, 1.5));
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let dx = backward(&mut l, &[&x], &y, Some(&dy))[0].clone().unwrap();
        let eps = 1e-3;
        for i in 0..16 {
            let mut p = x.clone();
            p.data_mut()[i] += eps;
            let mut m = x.clone();
            m.data_mut()[i] -= eps;
            let fp = forward(&mut l, Phase::Train, &[&p]).sum();
            let fm = forward(&mut l, Phase::Train, &[&m]).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dx.data()[i]).abs() < 1e-2,
                "lrn dx[{i}]: numeric {num} vs analytic {}",
                dx.data()[i]
            );
        }
    }
}
