//! Loss layers (paper Table II): softmax cross-entropy (classification),
//! sequence softmax (Char-RNN), and euclidean distance (MDNN's cross-modal
//! objective).

use super::layer::{Layer, Phase};
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Softmax + cross-entropy against integer labels.
///
/// Sources: `[logits, labels]`; labels are a `[batch]` blob of label ids
/// stored as f32 (produced by the label parser layer). The forward output is
/// the probability matrix; `loss()` reports `(mean xent, accuracy)`.
pub struct SoftmaxLossLayer {
    name: String,
    loss: f32,
    accuracy: f32,
    /// Reused logits-gradient buffer (filled in forward, drained backward).
    grad: Blob,
    /// Reused integer-label decode buffer.
    labels_buf: Vec<usize>,
}

impl SoftmaxLossLayer {
    pub fn new(name: &str) -> SoftmaxLossLayer {
        SoftmaxLossLayer {
            name: name.to_string(),
            loss: 0.0,
            accuracy: 0.0,
            grad: Blob::default(),
            labels_buf: Vec::new(),
        }
    }
}

fn labels_into(blob: &Blob, out: &mut Vec<usize>) {
    out.clear();
    out.extend(blob.data().iter().map(|&v| v as usize));
}

impl Layer for SoftmaxLossLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "SoftmaxLoss"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        assert_eq!(src_shapes.len(), 2, "{}: SoftmaxLoss wants [logits, labels]", self.name);
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let logits = srcs[0];
        labels_into(srcs[1], &mut self.labels_buf);
        self.loss = ops::softmax_xent_into(logits, &self.labels_buf, &mut self.grad);
        self.accuracy = ops::accuracy(logits, &self.labels_buf);
        ops::softmax_into(logits, out);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dx = src_grads[0].as_mut().expect("SoftmaxLoss logits slot");
        dx.add_assign(&self.grad);
    }

    fn needs_src_grad(&self, k: usize) -> bool {
        k == 0 // the label path gets no gradient
    }

    fn is_loss(&self) -> bool {
        true
    }

    fn loss(&self) -> Option<(f32, f32)> {
        Some((self.loss, self.accuracy))
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Euclidean loss between two source features (MDNN: distance between image
/// and text embeddings). Forward output is the first source (pass-through so
/// retrieval code can read the embedding).
pub struct EuclideanLossLayer {
    name: String,
    weight: f32,
    loss: f32,
    /// Gradient w.r.t. the first source; the second source's gradient is its
    /// negation, applied directly at backward time (no second buffer).
    grad_a: Blob,
}

impl EuclideanLossLayer {
    pub fn new(name: &str, weight: f32) -> EuclideanLossLayer {
        EuclideanLossLayer { name: name.to_string(), weight, loss: 0.0, grad_a: Blob::default() }
    }
}

impl Layer for EuclideanLossLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "EuclideanLoss"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        assert_eq!(src_shapes.len(), 2, "{}: EuclideanLoss wants 2 srcs", self.name);
        assert_eq!(src_shapes[0], src_shapes[1], "{}: source shapes differ", self.name);
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let loss = ops::euclidean_loss_into(srcs[0], srcs[1], &mut self.grad_a);
        self.grad_a.scale(self.weight);
        self.loss = loss * self.weight;
        // Forward output is a pass-through of the first source so retrieval
        // code can read the embedding.
        out.copy_from(srcs[0]);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        if let Some(da) = &mut src_grads[0] {
            da.add_assign(&self.grad_a);
        }
        if let Some(db) = &mut src_grads[1] {
            db.axpy(-1.0, &self.grad_a);
        }
    }

    fn is_loss(&self) -> bool {
        true
    }

    fn loss(&self) -> Option<(f32, f32)> {
        Some((self.loss, 0.0))
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Per-timestep softmax cross-entropy for sequence models.
///
/// Sources: `[logits, labels]` with logits `[batch, steps*vocab]` and labels
/// `[batch, steps]` (the paper's Fig 9: the i-th SoftmaxLossLayer measures
/// the loss of predicting the (i+1)-th character; here the unrolled loss
/// layers are fused into one, averaging over steps).
pub struct SeqSoftmaxLossLayer {
    name: String,
    steps: usize,
    loss: f32,
    accuracy: f32,
    grad: Blob,
    /// Reused per-step scratch: gathered logits, their gradient, labels.
    step_logits: Blob,
    step_grad: Blob,
    step_labels: Vec<usize>,
}

impl SeqSoftmaxLossLayer {
    pub fn new(name: &str, steps: usize) -> SeqSoftmaxLossLayer {
        SeqSoftmaxLossLayer {
            name: name.to_string(),
            steps,
            loss: 0.0,
            accuracy: 0.0,
            grad: Blob::default(),
            step_logits: Blob::default(),
            step_grad: Blob::default(),
            step_labels: Vec::new(),
        }
    }
}

impl Layer for SeqSoftmaxLossLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "SeqSoftmaxLoss"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        assert_eq!(src_shapes.len(), 2);
        let logits = src_shapes[0];
        assert_eq!(logits[1] % self.steps, 0, "{}: logits not divisible by steps", self.name);
        logits.to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let logits = srcs[0];
        let labels = srcs[1];
        let batch = logits.rows();
        let vocab = logits.cols() / self.steps;
        let mut total_loss = 0.0;
        let mut total_acc = 0.0;
        self.grad.resize(logits.shape());
        self.step_logits.resize(&[batch, vocab]);
        for t in 0..self.steps {
            // Gather step-t logits [batch, vocab] and labels [batch].
            for b in 0..batch {
                let src = &logits.data()[b * self.steps * vocab + t * vocab..][..vocab];
                self.step_logits.data_mut()[b * vocab..(b + 1) * vocab].copy_from_slice(src);
            }
            self.step_labels.clear();
            self.step_labels
                .extend((0..batch).map(|b| labels.data()[b * self.steps + t] as usize));
            let l = ops::softmax_xent_into(&self.step_logits, &self.step_labels, &mut self.step_grad);
            total_loss += l;
            total_acc += ops::accuracy(&self.step_logits, &self.step_labels);
            for b in 0..batch {
                self.grad.data_mut()[b * self.steps * vocab + t * vocab..][..vocab]
                    .copy_from_slice(&self.step_grad.data()[b * vocab..(b + 1) * vocab]);
            }
        }
        self.loss = total_loss / self.steps as f32;
        self.accuracy = total_acc / self.steps as f32;
        self.grad.scale(1.0 / self.steps as f32);
        out.copy_from(logits);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dx = src_grads[0].as_mut().expect("SeqSoftmaxLoss logits slot");
        dx.add_assign(&self.grad);
    }

    fn needs_src_grad(&self, k: usize) -> bool {
        k == 0
    }

    fn is_loss(&self) -> bool {
        true
    }

    fn loss(&self) -> Option<(f32, f32)> {
        Some((self.loss, self.accuracy))
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};

    fn rng() -> Rng {
        Rng::new(1)
    }

    #[test]
    fn softmax_loss_uniform_logits() {
        let mut l = SoftmaxLossLayer::new("loss");
        l.setup(&[&[2, 4], &[2]], &mut rng());
        let logits = Blob::zeros(&[2, 4]);
        let labels = Blob::from_vec(&[2], vec![0.0, 3.0]);
        forward(&mut l, Phase::Train, &[&logits, &labels]);
        let (loss, _) = l.loss().unwrap();
        assert!((loss - (4f32).ln()).abs() < 1e-5);
        let gs = backward(&mut l, &[&logits, &labels], &logits, None);
        assert!(gs[0].is_some());
        assert!(gs[1].is_none());
    }

    #[test]
    fn softmax_loss_perfect_prediction() {
        let mut l = SoftmaxLossLayer::new("loss");
        l.setup(&[&[2, 3], &[2]], &mut rng());
        let logits = Blob::from_vec(&[2, 3], vec![10., 0., 0., 0., 0., 10.]);
        let labels = Blob::from_vec(&[2], vec![0.0, 2.0]);
        forward(&mut l, Phase::Train, &[&logits, &labels]);
        let (loss, acc) = l.loss().unwrap();
        assert!(loss < 1e-3);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn euclidean_loss_grads_are_opposite() {
        let mut l = EuclideanLossLayer::new("dist", 1.0);
        l.setup(&[&[2, 3], &[2, 3]], &mut rng());
        let a = Blob::full(&[2, 3], 1.0);
        let b = Blob::full(&[2, 3], 0.0);
        let out = forward(&mut l, Phase::Train, &[&a, &b]);
        assert_eq!(out, a);
        let (loss, _) = l.loss().unwrap();
        assert!((loss - 0.5 * 6.0 / 2.0).abs() < 1e-6);
        let gs = backward(&mut l, &[&a, &b], &out, None);
        let ga = gs[0].as_ref().unwrap();
        let gb = gs[1].as_ref().unwrap();
        for (x, y) in ga.data().iter().zip(gb.data()) {
            assert_eq!(*x, -*y);
        }
    }

    #[test]
    fn seq_softmax_matches_flat_softmax_for_one_step() {
        let mut seq = SeqSoftmaxLossLayer::new("seq", 1);
        seq.setup(&[&[3, 5], &[3, 1]], &mut rng());
        let mut flat = SoftmaxLossLayer::new("flat");
        flat.setup(&[&[3, 5], &[3]], &mut rng());
        let mut r = Rng::new(5);
        let logits = Blob::from_vec(&[3, 5], r.uniform_vec(15, -1.0, 1.0));
        let labels = Blob::from_vec(&[3, 1], vec![1.0, 4.0, 0.0]);
        let labels_flat = labels.reshape(&[3]);
        forward(&mut seq, Phase::Train, &[&logits, &labels]);
        forward(&mut flat, Phase::Train, &[&logits, &labels_flat]);
        let (ls, as_) = seq.loss().unwrap();
        let (lf, af) = flat.loss().unwrap();
        assert!((ls - lf).abs() < 1e-6);
        assert!((as_ - af).abs() < 1e-6);
        let gs = backward(&mut seq, &[&logits, &labels], &logits, None);
        let gf = backward(&mut flat, &[&logits, &labels_flat], &logits, None);
        for (a, b) in gs[0].as_ref().unwrap().data().iter().zip(gf[0].as_ref().unwrap().data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn seq_softmax_multi_step_gradcheck() {
        let steps = 3;
        let vocab = 4;
        let batch = 2;
        let mut l = SeqSoftmaxLossLayer::new("seq", steps);
        l.setup(&[&[batch, steps * vocab], &[batch, steps]], &mut rng());
        let mut r = Rng::new(8);
        let logits = Blob::from_vec(&[batch, steps * vocab], r.uniform_vec(batch * steps * vocab, -1.0, 1.0));
        let labels = Blob::from_vec(&[batch, steps], vec![0., 1., 2., 3., 0., 1.]);
        forward(&mut l, Phase::Train, &[&logits, &labels]);
        let g = backward(&mut l, &[&logits, &labels], &logits, None)[0].clone().unwrap();
        let eps = 1e-2;
        let mut probe = |ls: &Blob| -> f32 {
            let mut tmp = SeqSoftmaxLossLayer::new("t", steps);
            tmp.setup(&[&[batch, steps * vocab], &[batch, steps]], &mut rng());
            forward(&mut tmp, Phase::Train, &[ls, &labels]);
            tmp.loss().unwrap().0
        };
        for i in (0..logits.len()).step_by(3) {
            let mut p = logits.clone();
            p.data_mut()[i] += eps;
            let mut m = logits.clone();
            m.data_mut()[i] -= eps;
            let num = (probe(&p) - probe(&m)) / (2.0 * eps);
            assert!(
                (num - g.data()[i]).abs() < 1e-3,
                "idx {i}: numeric {num} vs {}",
                g.data()[i]
            );
        }
    }
}
