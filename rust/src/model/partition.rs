//! Neural-net partitioning (paper §5.3, Fig 12): rewrite a layer-level
//! `NetBuilder` into a partitioned one where layers become located
//! sub-layers and connection layers (slice / concat / bridge) are inserted
//! automatically, making communication transparent to the user.
//!
//! Strategies (paper's list at the end of §5.3):
//! 1. per-layer placement (`LayerConf::at`)            → model parallelism
//! 2. `partition_dim = 0` (batch dimension)            → data parallelism
//! 3. `partition_dim = 1` (feature dimension)          → model parallelism
//! 4. any mix of the above                             → hybrid parallelism
//!
//! Dim-0 sub-layers replicate their `Param`s (the server aggregates the
//! replicas' gradients); dim-1 sub-layers own disjoint parameter slices
//! (paper Fig 12: both W and b are split).

use super::layer::{LayerConf, LayerKind};
use super::net::NetBuilder;
use std::collections::HashMap;

/// How an original layer ended up partitioned.
#[derive(Debug, Clone)]
enum PartState {
    /// Unsplit; (name, location).
    Whole(String, usize),
    /// Split along `dim` into sub-layers (name, location) in order.
    Parts { dim: usize, parts: Vec<(String, usize)> },
}

/// Metadata the coordinator and parameter server need about a partitioned
/// net: where layers live and how many gradient contributions to expect per
/// logical parameter.
#[derive(Debug, Clone, Default)]
pub struct PartitionPlan {
    pub num_workers: usize,
    /// logical param name → number of replicas contributing gradients
    /// (dim-0 data parallelism replicates params across sub-layers).
    pub replicas: HashMap<String, usize>,
    /// layer name (after partitioning) → worker location.
    pub locations: HashMap<String, usize>,
}

/// Strip the sub-layer batch-replica suffix to recover the logical parameter
/// name: `"fc1#b2/weight"` → `"fc1/weight"`. Dim-1 slices (`#f`) keep
/// distinct names because their values genuinely differ per worker.
pub fn logical_param_name(name: &str) -> String {
    match name.find("#b") {
        Some(start) => {
            let rest = &name[start + 2..];
            let end = rest.find('/').map(|e| start + 2 + e).unwrap_or(name.len());
            format!("{}{}", &name[..start], &name[end..])
        }
        None => name.to_string(),
    }
}

/// Stable logical-name → slot resolution for a replica's parameter list:
/// returns `(slots, param_slot)` where `slots[s]` is the s-th distinct
/// logical name in first-appearance order and `param_slot[j]` is the slot
/// of the j-th parameter. Worker groups resolve this once at job start and
/// index by position every step afterwards (the zero-clone aggregation
/// path), so the mapping must be deterministic for a given name sequence —
/// first-appearance order is, HashMap iteration order is not.
pub fn logical_slot_map(param_names: &[&str]) -> (Vec<String>, Vec<usize>) {
    let mut slots: Vec<String> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut param_slot = Vec::with_capacity(param_names.len());
    for name in param_names {
        let logical = logical_param_name(name);
        let slot = *index.entry(logical).or_insert_with_key(|l| {
            slots.push(l.clone());
            slots.len() - 1
        });
        param_slot.push(slot);
    }
    (slots, param_slot)
}

/// Owning layer of a logical parameter name: `"h1/weight"` → `"h1"`,
/// `"logits#f0/bias"` → `"logits#f0"` (dim-1 slices are distinct owners —
/// their values live on different workers), a name without a `/` owns
/// itself. This is the grouping key for flush buckets: all of a layer's
/// parameters become exchangeable at the same backward instant, so they
/// ship together.
pub fn logical_layer_name(logical: &str) -> &str {
    match logical.rsplit_once('/') {
        Some((layer, _)) => layer,
        None => logical,
    }
}

/// Group a slot list (logical parameter names with their payload byte
/// sizes, in stable slot order) into fixed-order flush buckets: one bucket
/// per owning layer, coalescing consecutive layers while the open bucket's
/// payload is still below `coalesce_bytes` (so tiny params — biases, small
/// heads — ride along with a neighbour instead of paying a whole message
/// each). `coalesce_bytes == 0` yields pure per-layer buckets;
/// `usize::MAX` yields a single bucket (the sequential degenerate case).
/// Returns each bucket's slot indices; concatenated they are `0..n` in
/// order, so the bucket layout is deterministic for a given slot list.
pub fn bucket_slots(slots: &[(String, usize)], coalesce_bytes: usize) -> Vec<Vec<usize>> {
    let mut buckets: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_bytes = 0usize;
    let mut cur_layer: Option<&str> = None;
    for (s, (logical, bytes)) in slots.iter().enumerate() {
        let layer = logical_layer_name(logical);
        if cur_layer.is_some_and(|l| l != layer) && cur_bytes >= coalesce_bytes {
            buckets.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
        cur_layer = Some(layer);
        cur.push(s);
        cur_bytes += bytes;
    }
    if !cur.is_empty() {
        buckets.push(cur);
    }
    buckets
}

/// Partition a net across `num_workers` workers. Layers with
/// `partition_dim = Some(d)` are split into `num_workers` sub-layers along
/// `d`; unsplit layers stay at their configured location (default 0).
/// Returns the rewritten builder plus the [`PartitionPlan`].
pub fn partition_net(builder: &NetBuilder, num_workers: usize) -> (NetBuilder, PartitionPlan) {
    assert!(num_workers >= 1);
    let mut out = NetBuilder::new();
    let mut plan = PartitionPlan { num_workers, ..Default::default() };
    let mut states: HashMap<String, PartState> = HashMap::new();
    // Memoized full-view concat layers per original layer name.
    let mut full_views: HashMap<String, String> = HashMap::new();

    // Process in topological order of the original graph so source states
    // exist before consumers.
    let order = topo_order(builder.confs());

    for &ci in &order {
        let conf = &builder.confs()[ci];
        let split = conf.partition_dim.filter(|_| num_workers > 1);
        let splittable = !matches!(conf.kind, LayerKind::Input { .. });
        match split {
            Some(dim) if splittable => {
                validate_dim(conf, dim);
                let mut parts = Vec::new();
                for i in 0..num_workers {
                    let sub_name = sub_layer_name(&conf.name, dim, i);
                    let loc = conf.location.unwrap_or(i % num_workers);
                    let loc = if conf.location.is_some() { loc } else { i };
                    // Wire sources for this sub-layer.
                    let mut srcs = Vec::new();
                    for s in &conf.srcs {
                        let src_name = wire_source(
                            s,
                            dim,
                            i,
                            num_workers,
                            loc,
                            &states,
                            &mut full_views,
                            &mut out,
                            &mut plan,
                        );
                        srcs.push(src_name);
                    }
                    let kind = adjust_kind(&conf.kind, dim, i, num_workers);
                    let src_refs: Vec<&str> = srcs.iter().map(String::as_str).collect();
                    let mut c = LayerConf::new(&sub_name, kind, &src_refs);
                    c.location = Some(loc);
                    plan.locations.insert(sub_name.clone(), loc);
                    out = out.add(c);
                    parts.push((sub_name, loc));
                }
                // Record replica counts for dim-0 (replicated) params.
                if dim == 0 {
                    for pname in param_names(&conf.kind, &conf.name) {
                        plan.replicas.insert(pname, num_workers);
                    }
                }
                states.insert(conf.name.clone(), PartState::Parts { dim, parts });
            }
            _ => {
                // Keep whole; re-wire sources to full views.
                let loc = conf.location.unwrap_or(0);
                let mut srcs = Vec::new();
                for s in &conf.srcs {
                    let src_name = full_view_of(
                        s,
                        loc,
                        &states,
                        &mut full_views,
                        &mut out,
                        &mut plan,
                    );
                    srcs.push(src_name);
                }
                let src_refs: Vec<&str> = srcs.iter().map(String::as_str).collect();
                let mut c = LayerConf::new(&conf.name, conf.kind.clone(), &src_refs);
                c.location = Some(loc);
                plan.locations.insert(conf.name.clone(), loc);
                out = out.add(c);
                for pname in param_names(&conf.kind, &conf.name) {
                    plan.replicas.insert(pname, 1);
                }
                states.insert(conf.name.clone(), PartState::Whole(conf.name.clone(), loc));
            }
        }
    }

    // Post-pass: insert bridge pairs on every cross-location edge.
    let bridged = insert_bridges(out, &mut plan);
    (bridged, plan)
}

/// Name of sub-layer `i` of `base` split along `dim`. `#b` (batch) replicas
/// share logical params; `#f` (feature) slices do not.
fn sub_layer_name(base: &str, dim: usize, i: usize) -> String {
    if dim == 0 {
        format!("{base}#b{i}")
    } else {
        format!("{base}#f{i}")
    }
}

fn validate_dim(conf: &LayerConf, dim: usize) {
    assert!(dim <= 1, "layer '{}': partition_dim must be 0 or 1", conf.name);
    if dim == 1 {
        let ok = matches!(
            conf.kind,
            LayerKind::InnerProduct { .. }
                | LayerKind::Activation { .. }
                | LayerKind::Dropout { .. }
        );
        assert!(
            ok,
            "layer '{}' ({:?}): feature-dimension partitioning is supported for \
             InnerProduct and elementwise layers (paper §5.4.1: apply model \
             parallelism only where neuron dependency is element-wise or the \
             feature dimension is small)",
            conf.name, conf.kind
        );
    }
}

/// Per-sub-layer hyper-parameter adjustment: dim-1 InnerProduct sub-layers
/// own a slice of the output columns (paper Fig 12).
fn adjust_kind(kind: &LayerKind, dim: usize, i: usize, k: usize) -> LayerKind {
    match (kind, dim) {
        (LayerKind::InnerProduct { out, act, init_std }, 1) => {
            assert!(
                *out >= k,
                "feature-dimension partitioning needs at least one output \
                 unit per worker (out={out}, workers={k}); use fewer workers \
                 or partition_dim=0 for this layer"
            );
            let share = crate::tensor::Blob::split_points(*out, k)[i].1;
            LayerKind::InnerProduct { out: share, act: *act, init_std: *init_std }
        }
        _ => kind.clone(),
    }
}

/// Parameter names a layer kind will create (for replica accounting).
fn param_names(kind: &LayerKind, layer: &str) -> Vec<String> {
    match kind {
        LayerKind::InnerProduct { .. } | LayerKind::Convolution { .. } => {
            vec![format!("{layer}/weight"), format!("{layer}/bias")]
        }
        LayerKind::Rbm { .. } => vec![
            format!("{layer}/weight"),
            format!("{layer}/vbias"),
            format!("{layer}/hbias"),
        ],
        LayerKind::Gru { .. } => {
            vec![format!("{layer}/w"), format!("{layer}/u"), format!("{layer}/b")]
        }
        _ => Vec::new(),
    }
}

/// Produce the name of a layer yielding the input that sub-layer `i`
/// (split along `dim`, placed at `loc`) needs from source `src`.
#[allow(clippy::too_many_arguments)]
fn wire_source(
    src: &str,
    dim: usize,
    i: usize,
    k: usize,
    loc: usize,
    states: &HashMap<String, PartState>,
    full_views: &mut HashMap<String, String>,
    out: &mut NetBuilder,
    plan: &mut PartitionPlan,
) -> String {
    let state = states.get(src).unwrap_or_else(|| panic!("unknown source '{src}'")).clone();
    match (dim, &state) {
        // Batch-split consumer from same-K batch-split producer: 1-to-1.
        (0, PartState::Parts { dim: 0, parts }) if parts.len() == k => parts[i].0.clone(),
        // Batch-split consumer: slice row-shard i out of the full view.
        (0, _) => {
            let full = full_view_inner(src, &state, None, states, full_views, out, plan);
            let full_loc = plan.locations.get(&full).copied().unwrap_or(0);
            let name = format!("{src}->slice0.{i}");
            if out.confs().iter().any(|c| c.name == name) {
                return name;
            }
            let mut c = LayerConf::new(
                &name,
                LayerKind::Slice { dim: 0, parts: k, index: i },
                &[full.as_str()],
            );
            // Slice at the producer's location so only the shard crosses the
            // wire (paper §5.4.1: prefer low-traffic boundaries).
            c.location = Some(full_loc);
            plan.locations.insert(name.clone(), full_loc);
            *out = std::mem::take(out).add(c);
            name
        }
        // Feature-split consumer needs the FULL source feature (paper
        // Fig 13c: every hidden unit depends on the whole visible vector).
        (1, _) => full_view_inner(src, &state, Some(loc), states, full_views, out, plan),
        _ => unreachable!(),
    }
}

/// Full (unsplit) view of `src` for a consumer at `loc`.
fn full_view_of(
    src: &str,
    loc: usize,
    states: &HashMap<String, PartState>,
    full_views: &mut HashMap<String, String>,
    out: &mut NetBuilder,
    plan: &mut PartitionPlan,
) -> String {
    let state = states.get(src).unwrap_or_else(|| panic!("unknown source '{src}'")).clone();
    full_view_inner(src, &state, Some(loc), states, full_views, out, plan)
}

fn full_view_inner(
    src: &str,
    state: &PartState,
    prefer_loc: Option<usize>,
    _states: &HashMap<String, PartState>,
    full_views: &mut HashMap<String, String>,
    out: &mut NetBuilder,
    plan: &mut PartitionPlan,
) -> String {
    match state {
        PartState::Whole(name, _) => name.clone(),
        PartState::Parts { dim, parts } => {
            if let Some(existing) = full_views.get(src) {
                return existing.clone();
            }
            let name = format!("{src}->cat");
            let loc = prefer_loc.unwrap_or(parts[0].1);
            let part_names: Vec<&str> = parts.iter().map(|(n, _)| n.as_str()).collect();
            let mut c = LayerConf::new(&name, LayerKind::Concat { dim: *dim }, &part_names);
            c.location = Some(loc);
            plan.locations.insert(name.clone(), loc);
            *out = std::mem::take(out).add(c);
            full_views.insert(src.to_string(), name.clone());
            name
        }
    }
}

/// Insert BridgeSrc/BridgeDst pairs on every edge whose endpoints live on
/// different workers (paper §5.3: "if two connected sub-layers are located
/// at two different workers, then a pair of bridge layers is inserted").
fn insert_bridges(builder: NetBuilder, plan: &mut PartitionPlan) -> NetBuilder {
    let confs = builder.confs().to_vec();
    let loc_of: HashMap<String, usize> =
        confs.iter().map(|c| (c.name.clone(), c.location.unwrap_or(0))).collect();
    let mut out = NetBuilder::new();
    // bridge name per (src layer, dst location) so fan-outs share one bridge
    let mut bridges: HashMap<(String, usize), String> = HashMap::new();

    for conf in confs {
        let my_loc = conf.location.unwrap_or(0);
        let mut new_srcs = Vec::new();
        for s in &conf.srcs {
            let src_loc = *loc_of.get(s).unwrap_or(&0);
            if src_loc == my_loc {
                new_srcs.push(s.clone());
                continue;
            }
            let key = (s.clone(), my_loc);
            let bridge_dst = bridges.entry(key).or_insert_with(|| {
                let bs = format!("{s}->bs.{my_loc}");
                let bd = format!("{s}->bd.{my_loc}");
                let mut c1 = LayerConf::new(&bs, LayerKind::BridgeSrc, &[s.as_str()]);
                c1.location = Some(src_loc);
                plan.locations.insert(bs.clone(), src_loc);
                let mut c2 = LayerConf::new(&bd, LayerKind::BridgeDst, &[bs.as_str()]);
                c2.location = Some(my_loc);
                plan.locations.insert(bd.clone(), my_loc);
                out = std::mem::take(&mut out).add(c1).add(c2);
                bd
            });
            new_srcs.push(bridge_dst.clone());
        }
        let src_refs: Vec<&str> = new_srcs.iter().map(String::as_str).collect();
        let mut c = LayerConf::new(&conf.name, conf.kind.clone(), &src_refs);
        c.location = conf.location;
        c.partition_dim = conf.partition_dim;
        out = std::mem::take(&mut out).add(c);
    }
    out
}

/// Topological order over layer-config indices.
fn topo_order(confs: &[LayerConf]) -> Vec<usize> {
    let by_name: HashMap<&str, usize> =
        confs.iter().enumerate().map(|(i, c)| (c.name.as_str(), i)).collect();
    let n = confs.len();
    let mut indegree = vec![0usize; n];
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, c) in confs.iter().enumerate() {
        for s in &c.srcs {
            let j = *by_name.get(s.as_str()).unwrap_or_else(|| panic!("unknown source '{s}'"));
            consumers[j].push(i);
            indegree[i] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut qi = 0;
    while qi < queue.len() {
        let u = queue[qi];
        qi += 1;
        order.push(u);
        for &v in &consumers[u] {
            indegree[v] -= 1;
            if indegree[v] == 0 {
                queue.push(v);
            }
        }
    }
    assert_eq!(order.len(), n, "cycle in layer graph");
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, Phase};
    use crate::tensor::Blob;
    use crate::utils::rng::Rng;

    fn mlp(batch: usize) -> NetBuilder {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 8] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(
                LayerConf::new(
                    "h1",
                    LayerKind::InnerProduct { out: 12, act: Activation::Sigmoid, init_std: 0.3 },
                    &["data"],
                ),
            )
            .add(
                LayerConf::new(
                    "logits",
                    LayerKind::InnerProduct { out: 4, act: Activation::Identity, init_std: 0.3 },
                    &["h1"],
                ),
            )
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))
    }

    #[test]
    fn logical_names() {
        assert_eq!(logical_param_name("fc1#b2/weight"), "fc1/weight");
        assert_eq!(logical_param_name("fc1#f1/weight"), "fc1#f1/weight");
        assert_eq!(logical_param_name("fc1/weight"), "fc1/weight");
        assert_eq!(logical_param_name("conv#b10"), "conv");
    }

    #[test]
    fn logical_layer_names() {
        assert_eq!(logical_layer_name("h1/weight"), "h1");
        assert_eq!(logical_layer_name("logits#f0/bias"), "logits#f0");
        assert_eq!(logical_layer_name("conv"), "conv");
        assert_eq!(logical_layer_name("a/b/weight"), "a/b");
    }

    /// Bucket layout: per-layer at threshold 0, tiny layers coalesce under
    /// a byte threshold, everything merges at `usize::MAX`, and the layout
    /// is a fixed-order partition of the slot indices.
    #[test]
    fn bucket_slots_layouts() {
        let slots: Vec<(String, usize)> = vec![
            ("h1/weight".into(), 8192),
            ("h1/bias".into(), 128),
            ("logits/weight".into(), 640),
            ("logits/bias".into(), 20),
            ("head/weight".into(), 40),
        ];
        // Threshold 0: one bucket per owning layer.
        assert_eq!(
            bucket_slots(&slots, 0),
            vec![vec![0, 1], vec![2, 3], vec![4]]
        );
        // 4 KiB threshold: h1 alone exceeds it and closes at the layer
        // boundary; the tiny logits + head layers coalesce.
        assert_eq!(bucket_slots(&slots, 4096), vec![vec![0, 1], vec![2, 3, 4]]);
        // Single-bucket degenerate case.
        assert_eq!(bucket_slots(&slots, usize::MAX), vec![vec![0, 1, 2, 3, 4]]);
        // Empty slot list: no buckets.
        assert!(bucket_slots(&[], 0).is_empty());
        // The layout always partitions 0..n in order.
        let flat: Vec<usize> = bucket_slots(&slots, 4096).concat();
        assert_eq!(flat, (0..slots.len()).collect::<Vec<_>>());
    }

    #[test]
    fn logical_slot_map_is_stable_and_dedups_replicas() {
        let names = [
            "h1#b0/weight",
            "h1#b0/bias",
            "h1#b1/weight",
            "h1#b1/bias",
            "logits#f0/weight",
            "logits#f1/weight",
        ];
        let (slots, param_slot) = logical_slot_map(&names);
        // First-appearance order; dim-0 replicas share a slot, dim-1
        // slices keep their own.
        assert_eq!(
            slots,
            vec!["h1/weight", "h1/bias", "logits#f0/weight", "logits#f1/weight"]
        );
        assert_eq!(param_slot, vec![0, 1, 0, 1, 2, 3]);
        // Deterministic across calls (positional contract).
        assert_eq!(logical_slot_map(&names), (slots, param_slot));
    }

    #[test]
    fn k1_is_identity_modulo_locations() {
        let b = mlp(4);
        let (p, plan) = partition_net(&b, 1);
        assert_eq!(p.confs().len(), b.confs().len());
        assert_eq!(plan.num_workers, 1);
        // all at location 0
        assert!(p.confs().iter().all(|c| c.location == Some(0)));
    }

    /// Data parallelism (dim 0): the partitioned net must produce the SAME
    /// forward loss as the unpartitioned one (deterministic layers, shared
    /// init via replica params seeded identically).
    #[test]
    fn dim0_partition_preserves_forward_semantics() {
        let batch = 8;
        let b0 = mlp(batch);
        // Partition both IP layers on the batch dimension.
        let mut b1 = b0.clone();
        for c in b1.confs_mut().iter_mut() {
            if c.name == "h1" || c.name == "logits" || c.name == "loss" {
                c.partition_dim = Some(0);
            }
        }
        let (bp, plan) = partition_net(&b1, 2);
        assert_eq!(plan.replicas.get("h1/weight"), Some(&2));

        let mut net0 = b0.build(&mut Rng::new(42));
        let mut net1 = bp.build(&mut Rng::new(42));
        // Force identical params across replicas and with the reference:
        // copy from net0 by logical name.
        let ref_params: std::collections::HashMap<String, Blob> = net0
            .params()
            .iter()
            .map(|p| (p.name.clone(), p.data.clone()))
            .collect();
        for p in net1.params_mut() {
            let logical = logical_param_name(&p.name);
            if let Some(v) = ref_params.get(&logical) {
                assert_eq!(v.shape(), p.data.shape(), "replica shape {}", p.name);
                p.data = v.clone();
            }
        }

        let mut rng = Rng::new(5);
        let x = Blob::from_vec(&[batch, 8], rng.uniform_vec(batch * 8, -1.0, 1.0));
        let y = Blob::from_vec(&[batch], (0..batch).map(|i| (i % 4) as f32).collect());

        net0.set_input("data", x.clone());
        net0.set_input("label", y.clone());
        net0.forward(Phase::Train);
        let loss0 = net0.total_loss();

        net1.set_input("data", x);
        net1.set_input("label", y);
        net1.forward(Phase::Train);
        // Two loss shards, each over batch/2 rows; their mean equals the
        // full-batch loss because shards are equal-sized.
        let losses = net1.losses();
        assert_eq!(losses.len(), 2);
        let mean: f32 = losses.iter().map(|(_, l, _)| l).sum::<f32>() / 2.0;
        assert!((mean - loss0).abs() < 1e-4, "sharded {mean} vs full {loss0}");
    }

    /// Model parallelism (dim 1): sub-layers own column slices; the concat
    /// of their outputs must equal the unpartitioned layer's output.
    #[test]
    fn dim1_partition_preserves_forward_semantics() {
        let batch = 4;
        let b0 = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 6] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 10, act: Activation::Tanh, init_std: 0.3 },
                &["data"],
            ));
        let mut b1 = b0.clone();
        b1.confs_mut()[1].partition_dim = Some(1);
        let (bp, plan) = partition_net(&b1, 2);
        // dim-1 params are NOT replicated
        assert_eq!(plan.replicas.get("fc/weight"), None);

        let mut net0 = b0.build(&mut Rng::new(7));
        let mut net1 = bp.build(&mut Rng::new(7));

        // Copy slices of the reference weights into the sub-layers.
        let w = net0.params()[0].data.clone(); // [6,10]
        let bias = net0.params()[1].data.clone(); // [10]
        for p in net1.params_mut() {
            if p.name == "fc#f0/weight" {
                p.data = w.slice_cols(0, 5);
            } else if p.name == "fc#f1/weight" {
                p.data = w.slice_cols(5, 5);
            } else if p.name == "fc#f0/bias" {
                p.data = Blob::from_vec(&[5], bias.data()[0..5].to_vec());
            } else if p.name == "fc#f1/bias" {
                p.data = Blob::from_vec(&[5], bias.data()[5..10].to_vec());
            }
        }

        let mut rng = Rng::new(9);
        let x = Blob::from_vec(&[batch, 6], rng.uniform_vec(batch * 6, -1.0, 1.0));
        net0.set_input("data", x.clone());
        net0.forward(Phase::Train);
        net1.set_input("data", x);
        net1.forward(Phase::Train);

        let full = net0.feature("fc").clone();
        let p0 = net1.feature("fc#f0").clone();
        let p1 = net1.feature("fc#f1").clone();
        let refs = [&p0, &p1];
        let cat = Blob::concat_cols(&refs);
        for (a, b) in cat.data().iter().zip(full.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn bridges_inserted_on_cross_location_edges() {
        // Place h1 at worker 1, rest at 0 → edges data->h1 and h1->logits
        // cross locations and need bridges.
        let mut b = mlp(4);
        for c in b.confs_mut().iter_mut() {
            if c.name == "h1" {
                c.location = Some(1);
            }
        }
        let (bp, plan) = partition_net(&b, 2);
        let names: Vec<&str> = bp.confs().iter().map(|c| c.name.as_str()).collect();
        assert!(names.iter().any(|n| n.contains("->bs.")), "bridge src missing: {names:?}");
        assert!(names.iter().any(|n| n.contains("->bd.")), "bridge dst missing: {names:?}");
        // Graph still builds and runs.
        let mut net = bp.build(&mut Rng::new(3));
        net.set_input("data", Blob::zeros(&[4, 8]));
        net.set_input("label", Blob::zeros(&[4]));
        net.forward(Phase::Train);
        net.backward();
        assert!(net.bridge_bytes() > 0);
        assert_eq!(plan.locations.get("h1"), Some(&1));
    }

    #[test]
    fn hybrid_partition_builds_and_trains() {
        // Paper §5.4.1 hybrid for AlexNet-like nets: data parallelism below,
        // model parallelism for the fully connected layer.
        let batch = 8;
        let mut b = mlp(batch);
        for c in b.confs_mut().iter_mut() {
            match c.name.as_str() {
                "h1" => c.partition_dim = Some(0),
                "logits" => c.partition_dim = Some(1),
                "loss" => c.partition_dim = None,
                _ => {}
            }
        }
        let (bp, _plan) = partition_net(&b, 2);
        let mut net = bp.build(&mut Rng::new(8));
        let mut rng = Rng::new(2);
        net.set_input("data", Blob::from_vec(&[batch, 8], rng.uniform_vec(batch * 8, -1.0, 1.0)));
        net.set_input("label", Blob::from_vec(&[batch], vec![0., 1., 2., 3., 0., 1., 2., 3.]));
        net.zero_grads();
        net.forward(Phase::Train);
        net.backward();
        // Every learnable param received a gradient.
        for p in net.params_mut() {
            assert!(p.grad.norm() > 0.0, "param {} has zero grad", p.name);
        }
    }

    #[test]
    #[should_panic(expected = "feature-dimension partitioning")]
    fn dim1_conv_rejected() {
        let b = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3, 8, 8] }, &[]))
            .add(
                LayerConf::new(
                    "conv",
                    LayerKind::Convolution { out_channels: 4, kernel: 3, stride: 1, pad: 1, init_std: 0.1 },
                    &["data"],
                )
                .partition(1),
            );
        partition_net(&b, 2);
    }
}
