//! GRU sequence layer and one-hot encoding — the Char-RNN stack (paper
//! §4.2.3, Fig 9).
//!
//! The paper unrolls a recurrent layer into `unroll_len` directed
//! sub-layers (Fig 5b). Here a `GruLayer` processes the whole sequence:
//! `compute_feature` runs the unrolled forward loop, `compute_gradient`
//! runs back-propagation-through-time, so the BP `TrainOneBatch` algorithm
//! drives BPTT exactly as the paper describes ("for feed-forward and
//! recurrent models, the BP algorithm is provided"). Stacked GRU layers are
//! separate `GruLayer` instances, which is the unit of placement used by the
//! partitioning example (different stacks → different workers).
//!
//! Sequence blobs are `[batch, steps*dim]` row-major with step-major inner
//! layout (step t occupies columns `[t*dim, (t+1)*dim)`).
//!
//! Under the planned-executor contract the per-step unroll state (gate
//! activations, candidate, hidden states, gathered inputs) and every BPTT
//! temporary live in layer-owned scratch buffers allocated once and reused
//! each step — the whole BPTT loop is allocation-free at steady state.

use super::layer::{Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Gated recurrent unit over full sequences.
///
/// Gates (per step): `r = σ(x Wr + h Ur + br)`, `z = σ(x Wz + h Uz + bz)`,
/// candidate `c = tanh(x Wc + (r⊙h) Uc + bc)`, `h' = z⊙h + (1-z)⊙c`.
pub struct GruLayer {
    name: String,
    hidden: usize,
    steps: usize,
    init_std: f32,
    in_dim: usize,
    // Parameters: the three input projections stacked [in_dim, 3*hidden]
    // (r|z|c), the three recurrent projections [hidden, 3*hidden], bias
    // [3*hidden]. Stacking keeps the param-server shard count small.
    w: Param,
    u: Param,
    b: Param,
    // Per-step unroll caches from the last forward pass, reused across
    // steps (batch-major blobs).
    cache: Vec<StepCache>,
    h0: Blob,
    scratch: GruScratch,
}

#[derive(Default)]
struct StepCache {
    x: Blob,
    r: Blob,
    z: Blob,
    c: Blob,
    h: Blob,
}

/// Reusable forward/BPTT temporaries ([batch, h] unless noted).
#[derive(Default)]
struct GruScratch {
    /// `x W + b`, stacked r|z|c — [batch, 3h].
    pre: Blob,
    /// `h_prev U`, stacked — [batch, 3h].
    pre_rec: Blob,
    /// `r ⊙ h_prev`.
    rh: Blob,
    /// `(r ⊙ h_prev) Uc`.
    rec: Blob,
    /// Materialized candidate block `Uc = U[:, 2h..3h]` — [h, h].
    uc: Blob,
    dh: Blob,
    dh_next: Blob,
    dh_prev: Blob,
    dz: Blob,
    dc: Blob,
    dcpre: Blob,
    drh: Blob,
    dr: Blob,
    drpre: Blob,
    dzpre: Blob,
    /// Stacked pre-activation gradient — [batch, 3h].
    dpre: Blob,
    /// `dpre` with the candidate block zeroed — [batch, 3h].
    dpre_rz: Blob,
    /// Candidate-block weight gradient — [h, h].
    duc: Blob,
    /// Per-step input gradient — [batch, in_dim].
    dx_step: Blob,
}

impl GruLayer {
    pub fn new(name: &str, hidden: usize, steps: usize, init_std: f32) -> GruLayer {
        GruLayer {
            name: name.to_string(),
            hidden,
            steps,
            init_std,
            in_dim: 0,
            w: Param::new(&format!("{name}/w"), Blob::zeros(&[0])),
            u: Param::new(&format!("{name}/u"), Blob::zeros(&[0])),
            b: Param::new(&format!("{name}/b"), Blob::zeros(&[0])),
            cache: Vec::new(),
            h0: Blob::default(),
            scratch: GruScratch::default(),
        }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Size (or re-size after a batch change) every reusable buffer; no-op
    /// at steady state.
    fn ensure_buffers(&mut self, batch: usize) {
        let hd = self.hidden;
        if self.cache.len() != self.steps {
            self.cache.clear();
            self.cache.resize_with(self.steps, StepCache::default);
        }
        for sc in &mut self.cache {
            sc.x.resize(&[batch, self.in_dim]);
            sc.r.resize(&[batch, hd]);
            sc.z.resize(&[batch, hd]);
            sc.c.resize(&[batch, hd]);
            sc.h.resize(&[batch, hd]);
        }
        self.h0.resize(&[batch, hd]);
        self.h0.fill(0.0);
        let s = &mut self.scratch;
        for b3 in [&mut s.pre, &mut s.pre_rec, &mut s.dpre, &mut s.dpre_rz] {
            b3.resize(&[batch, 3 * hd]);
        }
        for b1 in [
            &mut s.rh,
            &mut s.rec,
            &mut s.dh,
            &mut s.dh_next,
            &mut s.dh_prev,
            &mut s.dz,
            &mut s.dc,
            &mut s.dcpre,
            &mut s.drh,
            &mut s.dr,
            &mut s.drpre,
            &mut s.dzpre,
        ] {
            b1.resize(&[batch, hd]);
        }
        s.duc.resize(&[hd, hd]);
        s.dx_step.resize(&[batch, self.in_dim]);
        self.refresh_uc();
    }

    /// Copy the candidate block `U[:, 2h..3h]` into the contiguous `uc`
    /// scratch (the recurrent candidate GEMMs need it materialized; `u`
    /// changes every SGD step so this runs once per forward).
    fn refresh_uc(&mut self) {
        let hd = self.hidden;
        self.scratch.uc.resize(&[hd, hd]);
        for r in 0..hd {
            self.scratch.uc.data_mut()[r * hd..(r + 1) * hd]
                .copy_from_slice(&self.u.data.data()[r * 3 * hd + 2 * hd..][..hd]);
        }
    }
}

impl Layer for GruLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Gru"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 2, "{}: Gru wants [batch, steps*dim]", self.name);
        assert_eq!(s[1] % self.steps, 0, "{}: cols not divisible by steps", self.name);
        self.in_dim = s[1] / self.steps;
        let hd = self.hidden;
        self.w = Param::new(
            &format!("{}/w", self.name),
            Blob::gaussian(&[self.in_dim, 3 * hd], self.init_std, rng),
        );
        self.u = Param::new(
            &format!("{}/u", self.name),
            Blob::gaussian(&[hd, 3 * hd], self.init_std, rng),
        );
        self.b = Param::new(&format!("{}/b", self.name), Blob::zeros(&[3 * hd])).with_wd_mult(0.0);
        vec![s[0], self.steps * hd]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let xseq = srcs[0];
        let batch = xseq.rows();
        let hd = self.hidden;
        self.ensure_buffers(batch);
        out.resize(&[batch, self.steps * hd]);
        for t in 0..self.steps {
            let (done, cur) = self.cache.split_at_mut(t);
            let sc = &mut cur[0];
            let h_prev: &Blob = if t == 0 { &self.h0 } else { &done[t - 1].h };
            step_slice_into(xseq, t, self.in_dim, self.steps, &mut sc.x);
            {
                // pre = x W + b ; pre_rec = h_prev U (candidate's recurrent
                // term handled separately through r⊙h below).
                let GruScratch { pre, pre_rec, .. } = &mut self.scratch;
                ops::matmul_into(&sc.x, &self.w.data, pre, 0.0);
                ops::add_row_vec(pre, &self.b.data);
                ops::matmul_into(h_prev, &self.u.data, pre_rec, 0.0);
                for bi in 0..batch {
                    let base = bi * 3 * hd;
                    for j in 0..hd {
                        let rv = pre.data()[base + j] + pre_rec.data()[base + j];
                        let zv = pre.data()[base + hd + j] + pre_rec.data()[base + hd + j];
                        sc.r.data_mut()[bi * hd + j] = ops::sigmoid_scalar(rv);
                        sc.z.data_mut()[bi * hd + j] = ops::sigmoid_scalar(zv);
                    }
                }
            }
            {
                // candidate: c = tanh(x Wc + (r⊙h_prev) Uc + bc)
                let GruScratch { rh, rec, uc, pre, .. } = &mut self.scratch;
                ops::zip_into(&sc.r, h_prev, rh, |a, b| a * b);
                ops::matmul_into(rh, uc, rec, 0.0);
                for bi in 0..batch {
                    for j in 0..hd {
                        let cpre =
                            pre.data()[bi * 3 * hd + 2 * hd + j] + rec.data()[bi * hd + j];
                        sc.c.data_mut()[bi * hd + j] = cpre.tanh();
                    }
                }
            }
            // h' = z⊙h_prev + (1-z)⊙c
            for i in 0..batch * hd {
                let zv = sc.z.data()[i];
                sc.h.data_mut()[i] =
                    zv * h_prev.data()[i] + (1.0 - zv) * sc.c.data()[i];
            }
            set_step(out, &sc.h, t, hd, self.steps);
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy_seq = grad_out.expect("Gru needs grad");
        let xseq = srcs[0];
        let batch = xseq.rows();
        let hd = self.hidden;
        let steps = self.steps;
        let in_dim = self.in_dim;
        self.scratch.dh_next.fill(0.0);

        for t in (0..steps).rev() {
            let (done, cur) = self.cache.split_at(t);
            let sc = &cur[0];
            let h_prev: &Blob = if t == 0 { &self.h0 } else { &done[t - 1].h };
            {
                let GruScratch { dh, dh_next, dh_prev, dz, dc, dcpre, .. } = &mut self.scratch;
                // Total gradient into h_t: from output at t + from step t+1.
                step_slice_into(dy_seq, t, hd, steps, dh);
                dh.add_assign(dh_next);
                // h = z⊙h_prev + (1-z)⊙c ; c = tanh(cpre)
                for i in 0..batch * hd {
                    let d = dh.data()[i];
                    let zv = sc.z.data()[i];
                    let cv = sc.c.data()[i];
                    dz.data_mut()[i] = d * (h_prev.data()[i] - cv);
                    dc.data_mut()[i] = d * (1.0 - zv);
                    dh_prev.data_mut()[i] = d * zv;
                    dcpre.data_mut()[i] = dc.data()[i] * (1.0 - cv * cv);
                }
            }
            {
                // cpre = x Wc + (r⊙h_prev) Uc + bc
                let GruScratch { rh, uc, dcpre, drh, duc, .. } = &mut self.scratch;
                ops::zip_into(&sc.r, h_prev, rh, |a, b| a * b);
                ops::matmul_nt_into(dcpre, uc, drh, 0.0);
                // dUc += rh^T dcpre
                ops::matmul_tn_into(rh, dcpre, duc, 0.0);
            }
            add_u_c(&mut self.u.grad, &self.scratch.duc, hd);
            {
                let GruScratch { dh_prev, dz, dr, drh, drpre, dzpre, dpre, dcpre, dpre_rz, .. } =
                    &mut self.scratch;
                for i in 0..batch * hd {
                    dr.data_mut()[i] = drh.data()[i] * h_prev.data()[i];
                    dh_prev.data_mut()[i] += drh.data()[i] * sc.r.data()[i];
                    // gate pre-activations
                    let rv = sc.r.data()[i];
                    let zv = sc.z.data()[i];
                    drpre.data_mut()[i] = dr.data()[i] * rv * (1.0 - rv);
                    dzpre.data_mut()[i] = dz.data()[i] * zv * (1.0 - zv);
                }
                // Assemble the stacked [batch, 3h] pre-activation gradient
                // (r|z|c); dpre_rz zeroes the candidate block (Uc was
                // handled above).
                for bi in 0..batch {
                    let base = bi * 3 * hd;
                    for j in 0..hd {
                        dpre.data_mut()[base + j] = drpre.data()[bi * hd + j];
                        dpre.data_mut()[base + hd + j] = dzpre.data()[bi * hd + j];
                        dpre.data_mut()[base + 2 * hd + j] = dcpre.data()[bi * hd + j];
                        dpre_rz.data_mut()[base + j] = drpre.data()[bi * hd + j];
                        dpre_rz.data_mut()[base + hd + j] = dzpre.data()[bi * hd + j];
                        dpre_rz.data_mut()[base + 2 * hd + j] = 0.0;
                    }
                }
            }
            // dW += x^T dpre ; db += colsum(dpre) ; dx_t = dpre W^T
            ops::matmul_tn_into(&sc.x, &self.scratch.dpre, &mut self.w.grad, 1.0);
            ops::sum_rows_into(&self.scratch.dpre, &mut self.b.grad, true);
            {
                let GruScratch { dpre, dx_step, .. } = &mut self.scratch;
                ops::matmul_nt_into(dpre, &self.w.data, dx_step, 0.0);
            }
            if let Some(dx) = &mut src_grads[0] {
                add_step(dx, &self.scratch.dx_step, t, in_dim, steps);
            }
            // dU(r,z) from recurrent terms: pre_rec = h_prev U.
            ops::matmul_tn_into(h_prev, &self.scratch.dpre_rz, &mut self.u.grad, 1.0);
            {
                let GruScratch { dpre_rz, dh_prev, .. } = &mut self.scratch;
                ops::matmul_nt_into(dpre_rz, &self.u.data, dh_prev, 1.0);
            }
            {
                let GruScratch { dh_next, dh_prev, .. } = &mut self.scratch;
                std::mem::swap(dh_next, dh_prev);
            }
        }
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.w, &self.u, &self.b]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.u, &mut self.b]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Gather step `t` of a `[batch, steps*dim]` sequence blob into a
/// `[batch, dim]` buffer (resized, overwritten).
fn step_slice_into(seq: &Blob, t: usize, dim: usize, steps: usize, out: &mut Blob) {
    let batch = seq.rows();
    out.resize(&[batch, dim]);
    for b in 0..batch {
        out.data_mut()[b * dim..(b + 1) * dim]
            .copy_from_slice(&seq.data()[b * steps * dim + t * dim..][..dim]);
    }
}

/// Overwrite step `t` of a sequence blob with `step`.
fn set_step(seq: &mut Blob, step: &Blob, t: usize, dim: usize, steps: usize) {
    let batch = step.rows();
    for b in 0..batch {
        seq.data_mut()[b * steps * dim + t * dim..][..dim]
            .copy_from_slice(&step.data()[b * dim..(b + 1) * dim]);
    }
}

/// Accumulate (`+=`) `step` into step `t` of a sequence blob (gradient
/// scatter into a shared workspace slot).
fn add_step(seq: &mut Blob, step: &Blob, t: usize, dim: usize, steps: usize) {
    let batch = step.rows();
    for b in 0..batch {
        let dst = &mut seq.data_mut()[b * steps * dim + t * dim..][..dim];
        for (d, s) in dst.iter_mut().zip(&step.data()[b * dim..(b + 1) * dim]) {
            *d += s;
        }
    }
}

/// Accumulate dUc into the candidate block of dU.
fn add_u_c(du: &mut Blob, duc: &Blob, hd: usize) {
    let cols = 3 * hd;
    for r in 0..hd {
        for c in 0..hd {
            du.data_mut()[r * cols + 2 * hd + c] += duc.data()[r * hd + c];
        }
    }
}

/// One-hot layer: char ids `[batch, steps]` → `[batch, steps*vocab]`.
pub struct OneHotLayer {
    name: String,
    vocab: usize,
    steps: usize,
}

impl OneHotLayer {
    pub fn new(name: &str, vocab: usize) -> OneHotLayer {
        OneHotLayer { name: name.to_string(), vocab, steps: 0 }
    }
}

impl Layer for OneHotLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "OneHot"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 2, "{}: OneHot wants [batch, steps]", self.name);
        self.steps = s[1];
        vec![s[0], self.steps * self.vocab]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let ids = srcs[0];
        let batch = ids.rows();
        out.resize(&[batch, self.steps * self.vocab]);
        out.fill(0.0);
        for b in 0..batch {
            for t in 0..self.steps {
                let id = ids.data()[b * self.steps + t] as usize;
                assert!(id < self.vocab, "{}: char id {id} >= vocab {}", self.name, self.vocab);
                out.data_mut()[b * self.steps * self.vocab + t * self.vocab + id] = 1.0;
            }
        }
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad: Option<&Blob>,
        _src_grads: &mut [Option<&mut Blob>],
    ) {
    }

    fn needs_src_grad(&self, _k: usize) -> bool {
        false // char ids are not differentiable
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};

    #[test]
    fn onehot_encodes() {
        let mut l = OneHotLayer::new("oh", 4);
        let out_shape = l.setup(&[&[2, 3]], &mut Rng::new(1));
        assert_eq!(out_shape, vec![2, 12]);
        let ids = Blob::from_vec(&[2, 3], vec![0., 1., 2., 3., 0., 1.]);
        let y = forward(&mut l, Phase::Train, &[&ids]);
        assert_eq!(y.sum(), 6.0);
        assert_eq!(y.data()[0], 1.0); // b0 t0 id0
        assert_eq!(y.data()[4 + 1], 1.0); // b0 t1 id1
        assert_eq!(y.data()[12 + 3], 1.0); // b1 t0 id3
    }

    #[test]
    fn gru_shapes() {
        let mut l = GruLayer::new("gru", 8, 5, 0.1);
        let out = l.setup(&[&[3, 5 * 4]], &mut Rng::new(2));
        assert_eq!(out, vec![3, 40]);
        assert_eq!(l.params().len(), 3);
        assert_eq!(l.w.data.shape(), &[4, 24]);
        assert_eq!(l.u.data.shape(), &[8, 24]);
    }

    #[test]
    fn gru_forward_bounded() {
        let mut l = GruLayer::new("gru", 6, 4, 0.5);
        l.setup(&[&[2, 4 * 3]], &mut Rng::new(3));
        let mut r = Rng::new(5);
        let x = Blob::from_vec(&[2, 12], r.uniform_vec(24, -1.0, 1.0));
        let y = forward(&mut l, Phase::Train, &[&x]);
        // GRU hidden state is a convex combination of tanh outputs → (-1, 1)
        assert!(y.data().iter().all(|&v| v.abs() < 1.0));
    }

    /// The steady-state unroll must not allocate: after the first forward/
    /// backward pair sized the caches, further steps reuse them.
    #[test]
    fn gru_steady_state_is_allocation_free() {
        let mut l = GruLayer::new("gru", 6, 4, 0.3);
        l.setup(&[&[2, 4 * 3]], &mut Rng::new(3));
        let mut r = Rng::new(5);
        let x = Blob::from_vec(&[2, 12], r.uniform_vec(24, -1.0, 1.0));
        let mut out = Blob::default();
        let mut dx = Blob::zeros(&[2, 12]);
        let dy = Blob::full(&[2, 24], 1.0);
        // Warm-up sizes every buffer.
        l.compute_feature(Phase::Train, &[&x], &mut out);
        {
            let mut slots = [Some(&mut dx)];
            l.compute_gradient(&[&x], &out, Some(&dy), &mut slots);
        }
        let before = Blob::alloc_count();
        for _ in 0..3 {
            l.compute_feature(Phase::Train, &[&x], &mut out);
            let mut slots = [Some(&mut dx)];
            l.compute_gradient(&[&x], &out, Some(&dy), &mut slots);
        }
        assert_eq!(Blob::alloc_count(), before, "GRU unroll must reuse its buffers");
    }

    /// Full BPTT gradient check: dL/dx and dL/dW numerically.
    #[test]
    fn gru_bptt_gradcheck() {
        let steps = 3;
        let in_dim = 2;
        let hd = 4;
        let batch = 2;
        let mut l = GruLayer::new("gru", hd, steps, 0.4);
        l.setup(&[&[batch, steps * in_dim]], &mut Rng::new(7));
        let mut r = Rng::new(11);
        let x = Blob::from_vec(&[batch, steps * in_dim], r.uniform_vec(batch * steps * in_dim, -1.0, 1.0));

        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let gs = backward(&mut l, &[&x], &y, Some(&dy));
        let dx = gs[0].clone().unwrap();
        let dw = l.w.grad.clone();
        let du = l.u.grad.clone();
        let db = l.b.grad.clone();

        let eps = 1e-2;
        let f_x = |l: &mut GruLayer, x: &Blob| forward(l, Phase::Train, &[x]).sum();
        for i in 0..x.len() {
            let mut p = x.clone();
            p.data_mut()[i] += eps;
            let mut m = x.clone();
            m.data_mut()[i] -= eps;
            let num = (f_x(&mut l, &p) - f_x(&mut l, &m)) / (2.0 * eps);
            assert!(
                (num - dx.data()[i]).abs() < 3e-2,
                "dx[{i}] numeric {num} vs {}",
                dx.data()[i]
            );
        }
        // dW
        for i in (0..l.w.data.len()).step_by((l.w.data.len() / 10).max(1)) {
            let orig = l.w.data.data()[i];
            l.w.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.w.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.w.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - dw.data()[i]).abs() < 3e-2, "dW[{i}] {num} vs {}", dw.data()[i]);
        }
        // dU
        for i in (0..l.u.data.len()).step_by((l.u.data.len() / 10).max(1)) {
            let orig = l.u.data.data()[i];
            l.u.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.u.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.u.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - du.data()[i]).abs() < 3e-2, "dU[{i}] {num} vs {}", du.data()[i]);
        }
        // db
        for i in 0..db.len() {
            let orig = l.b.data.data()[i];
            l.b.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.b.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.b.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - db.data()[i]).abs() < 3e-2, "db[{i}] {num} vs {}", db.data()[i]);
        }
    }

    #[test]
    fn step_slice_set_add_roundtrip() {
        let mut r = Rng::new(1);
        let seq = Blob::from_vec(&[2, 6], r.uniform_vec(12, -1.0, 1.0));
        let mut via_set = Blob::zeros(&[2, 6]);
        let mut via_add = Blob::zeros(&[2, 6]);
        let mut s = Blob::default();
        for t in 0..3 {
            step_slice_into(&seq, t, 2, 3, &mut s);
            set_step(&mut via_set, &s, t, 2, 3);
            add_step(&mut via_add, &s, t, 2, 3);
        }
        assert_eq!(seq.data(), via_set.data());
        assert_eq!(seq.data(), via_add.data());
    }
}
